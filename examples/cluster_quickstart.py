"""Cluster quickstart: the paper's system in ~90 lines.

Builds a 4-node cluster — one unified buffer pool per node, each owned by a
per-node ``MemoryManager`` (``node.memory``: eviction policy, spill store,
resident/pinned/spilled/reserved accounting and the ``reserve()`` /
``pressure_score()`` backpressure API). Stages a dataset as a sharded
locality set with chain replicas, runs a distributed hash-aggregation, joins
a co-partitioned replica pair with ZERO network bytes (the scheduler proves
nothing needs to move — paper §9.2.2), re-runs the aggregation over a
columnar-scheme set asserting bit-identical output, then kills a node and
recovers its shards from replicas with checksum verification.

The finale re-runs a shuffle on the **process data plane**
(``backend="proc"`` — one OS process per node, shared-memory page path,
docs/ARCHITECTURE.md §8), SIGKILLs a node mid-shuffle, and still drains a
byte-identical result before ``close()`` proves nothing leaked.

Run: PYTHONPATH=src python examples/cluster_quickstart.py
"""
import numpy as np

from repro.core.services import columnar_job_data_attrs
from repro.data.pipeline import cluster_aggregate, cluster_join
from repro.runtime.cluster import Cluster, cluster_hash_aggregate

REC = np.dtype([("key", np.int64), ("val", np.float64)])
ITEM = np.dtype([("key", np.int64), ("rid", np.int64), ("qty", np.float64)])


def main() -> None:
    cluster = Cluster(num_nodes=4, node_capacity=32 << 20,
                      page_size=1 << 17, replication_factor=1)

    rng = np.random.default_rng(0)
    records = np.zeros(200_000, REC)
    records["key"] = rng.integers(0, 5_000, len(records))
    records["val"] = rng.random(len(records))

    # --- distributed dataset + aggregation ---------------------------------
    sset = cluster.create_sharded_set("sales", records,
                                      key_fn=lambda r: r["key"])
    per_node = {n: info.num_records for n, info in sorted(sset.shards.items())}
    print(f"sharded {len(records)} records across 4 pools: {per_node}")

    keys, sums = cluster_aggregate(cluster, "sales_agg", records,
                                   "key", "val")
    print(f"group-by produced {len(keys)} groups; "
          f"shuffle moved {cluster.net_bytes / 1e6:.2f} MB across nodes")

    # --- co-partitioned join: the scheduler moves NOTHING ------------------
    # Both sides stage partitioned on the join key, so the statistics DB can
    # prove every matching key pair already shares a node: the shuffle is
    # elided outright and the join streams shard-locally through each pool.
    customers = np.zeros(5_000, REC)
    customers["key"] = np.arange(5_000)
    customers["val"] = rng.random(5_000)
    orders = np.zeros(60_000, ITEM)
    orders["key"] = rng.integers(0, 5_000, len(orders))
    orders["rid"] = np.arange(len(orders))
    orders["qty"] = rng.random(len(orders))
    base_net = cluster.net_bytes
    joined, report = cluster_join(cluster, "cust_orders",
                                  customers, orders, "key",
                                  replication_factor=0)
    assert report.shuffle_free and report.net_bytes == 0
    assert cluster.net_bytes == base_net           # zero bytes crossed nodes
    print(f"co-partitioned join matched {len(joined)} rows moving "
          f"{report.net_bytes} network bytes (plan: shuffle "
          f"{list(report.plan.shuffle_sides) or 'nothing'})")

    # each node's MemoryManager saw the join's build tables as reservations
    hwm = max(node.memory.pressure_report()["reserved_hwm"]
              for node in cluster.nodes.values())
    print(f"peak per-node staging during the join: {hwm / 1e3:.0f} KB "
          f"(reserve-charged, spills instead of OOM-ing when over budget)")

    # --- columnar variant: same query, same bytes, vectorized kernels ------
    # Opting a set into the columnar block layout (validity bitmap + one
    # region per field — docs/ARCHITECTURE.md §7) reroutes the shuffle and
    # aggregate through the fused partition/CRC kernels. Integer-valued
    # floats make the sums exact, so the schemes must match bit-for-bit.
    cents = np.zeros(len(records), REC)
    cents["key"] = records["key"]
    cents["val"] = np.floor(records["val"] * 100)
    row_k, row_v = cluster_aggregate(cluster, "sales_row", cents,
                                     "key", "val", force_shuffle=True)
    col_set = cluster.create_sharded_set(
        "sales_columnar", cents, key_fn=lambda r: r["key"],
        attrs_factory=columnar_job_data_attrs)
    col_k, col_v = cluster_hash_aggregate(cluster, col_set, "key", "val",
                                          force_shuffle=True)
    order = np.argsort(col_k)
    assert np.array_equal(row_k, col_k[order])
    assert np.array_equal(row_v, col_v[order])
    print(f"columnar aggregate over {len(cents)} records identical to the "
          f"row scheme ({len(col_k)} groups, bit-for-bit)")

    # --- kill a node, recover from replicas --------------------------------
    cluster.kill_node(2)
    survived = cluster.read_sharded(sset)  # scheduler reroutes dead-owner
    assert np.array_equal(np.sort(survived["key"]), np.sort(records["key"]))
    print("reads with node 2 down served from CRC-verified replicas")
    report = cluster.recover_node(2)
    assert report.ok, report.checksum_failures
    print(f"recovered node 2: {report.shards_recovered} shards, "
          f"{report.replicas_rebuilt} replicas re-replicated, "
          f"{report.bytes_transferred / 1e6:.2f} MB in "
          f"{report.seconds * 1e3:.1f} ms, checksums OK")

    restored = cluster.read_sharded(sset)
    assert np.array_equal(np.sort(restored["key"]), np.sort(records["key"]))
    print("restored dataset byte-identical to the original")

    # --- the same API on real OS processes ---------------------------------
    # backend="proc" forks one process per node: control messages ride a
    # socket, page payloads ride shared-memory arenas (zero pickling), and
    # a SIGKILL is a real kill — the shuffle below loses a node between map
    # and reduce and recovers byte-identically from chain replicas.
    proc = Cluster(num_nodes=4, backend="proc", node_capacity=32 << 20,
                   page_size=1 << 16, replication_factor=2)
    psset = proc.create_sharded_set("sales", records,
                                    key_fn=lambda r: r["key"])
    shuffle = proc.shuffle("agg", num_reducers=8, dtype=REC)
    shuffle.map_sharded(psset, key_field="key")
    shuffle.finish_maps()
    proc.kill_node(1)                    # SIGKILL, mid-shuffle
    shuffle.place_reducers_locally()
    drained = np.concatenate([shuffle.pull(r) for r in range(8)])
    assert np.array_equal(np.sort(drained, order=("key", "val")),
                          np.sort(records, order=("key", "val")))
    print("proc backend: node 1 SIGKILLed between map and reduce; "
          "replica re-execution drained a byte-identical shuffle")
    report = proc.close()
    assert report.ok, report
    print(f"proc backend closed clean: {len(report.orphan_processes)} "
          f"orphan processes, {len(report.leaked_segments)} leaked segments")


if __name__ == "__main__":
    main()
