"""Cluster quickstart: the paper's system in ~60 lines.

Builds a 4-node cluster (one unified buffer pool per node), stages a dataset
as a sharded locality set with chain replicas, runs a distributed
hash-aggregation (shuffle by key hash -> per-node hash service), then kills a
node and recovers its shards from replicas with checksum verification.

Run: PYTHONPATH=src python examples/cluster_quickstart.py
"""
import numpy as np

from repro.data.pipeline import cluster_aggregate
from repro.runtime.cluster import Cluster

REC = np.dtype([("key", np.int64), ("val", np.float64)])


def main() -> None:
    cluster = Cluster(num_nodes=4, node_capacity=32 << 20,
                      page_size=1 << 17, replication_factor=1)

    rng = np.random.default_rng(0)
    records = np.zeros(200_000, REC)
    records["key"] = rng.integers(0, 5_000, len(records))
    records["val"] = rng.random(len(records))

    # --- distributed dataset + aggregation ---------------------------------
    sset = cluster.create_sharded_set("sales", records,
                                      key_fn=lambda r: r["key"])
    per_node = {n: info.num_records for n, info in sorted(sset.shards.items())}
    print(f"sharded {len(records)} records across 4 pools: {per_node}")

    keys, sums = cluster_aggregate(cluster, "sales_agg", records,
                                   "key", "val")
    print(f"group-by produced {len(keys)} groups; "
          f"shuffle moved {cluster.net_bytes / 1e6:.2f} MB across nodes")

    # --- kill a node, recover from replicas --------------------------------
    cluster.kill_node(2)
    try:
        cluster.read_sharded(sset)
    except Exception as e:
        print(f"read with node 2 down fails as expected: {e}")
    report = cluster.recover_node(2)
    assert report.ok, report.checksum_failures
    print(f"recovered node 2: {report.shards_recovered} shards, "
          f"{report.replicas_rebuilt} replicas re-replicated, "
          f"{report.bytes_transferred / 1e6:.2f} MB in "
          f"{report.seconds * 1e3:.1f} ms, checksums OK")

    restored = cluster.read_sharded(sset)
    assert np.array_equal(np.sort(restored["key"]), np.sort(records["key"]))
    print("restored dataset byte-identical to the original")


if __name__ == "__main__":
    main()
