"""The paper's flagship application (§9.2.1): k-means over Pangea storage.

  PYTHONPATH=src python examples/kmeans_pangea.py [--points 200000]

Input points are a write-through locality set; the derived points-with-norms
are a write-back set (exactly the paper's setup). Each iteration scans the
sets through the buffer pool with the data-aware paging policy; compute is
jitted JAX. Compare with the layered baseline in benchmarks/bench_kmeans.py.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BufferPool
from repro.core.attributes import AttributeSet, DurabilityType
from repro.core.services import SequentialWriter, get_page_iterators


@jax.jit
def assign_update(points, norms, centroids):
    # ||x-c||^2 = ||x||^2 - 2 x.c + ||c||^2 — the norms set saves a pass
    xc = points @ centroids.T
    c2 = (centroids ** 2).sum(-1)
    d = norms[:, None] - 2 * xc + c2[None, :]
    assign = jnp.argmin(d, axis=1)
    onehot = jax.nn.one_hot(assign, centroids.shape[0], dtype=points.dtype)
    sums = onehot.T @ points
    counts = onehot.sum(0)[:, None]
    return sums / jnp.maximum(counts, 1.0), assign


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--points", type=int, default=200_000)
    ap.add_argument("--dim", type=int, default=10)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--pool-mb", type=int, default=256)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    pts = rng.normal(size=(args.points, args.dim)).astype(np.float32)
    pool = BufferPool(args.pool_mb << 20)
    pdt = np.dtype((np.float32, (args.dim,)))

    t0 = time.perf_counter()
    inp = pool.create_set("points", 1 << 20,
                          AttributeSet(durability=DurabilityType.WRITE_THROUGH))
    w = SequentialWriter(pool, inp, pdt)
    w.append_batch(pts)
    w.close()
    norms_ls = pool.create_set("norms", 1 << 20)   # write-back derived data
    nw = SequentialWriter(pool, norms_ls, np.dtype(np.float32))
    for it in get_page_iterators(pool, inp, pdt, 1):
        for recs in it:
            nw.append_batch((recs ** 2).sum(1))
    nw.close()
    print(f"init (load + norms): {time.perf_counter()-t0:.3f}s")

    cents = jnp.asarray(pts[:args.k])
    for i in range(args.iters):
        t1 = time.perf_counter()
        pchunks, nchunks = [], []
        for it in get_page_iterators(pool, inp, pdt, 1):
            for recs in it:
                pchunks.append(jnp.asarray(recs))
        for it in get_page_iterators(pool, norms_ls, np.dtype(np.float32), 1):
            for recs in it:
                nchunks.append(jnp.asarray(recs))
        points = jnp.concatenate(pchunks)
        norms = jnp.concatenate(nchunks)
        cents, assign = assign_update(points, norms, cents)
        cents.block_until_ready()
        print(f"iter {i}: {time.perf_counter()-t1:.3f}s "
              f"(pool resident {pool.resident_bytes/2**20:.0f} MB, "
              f"spilled {pool.stats['spill_bytes']/2**20:.0f} MB)")
    print("cluster sizes:", np.bincount(np.asarray(assign),
                                        minlength=args.k))


if __name__ == "__main__":
    main()
