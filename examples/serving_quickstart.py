"""Serving-tier quickstart: paged-KV decode over the monolithic pool.

Builds a 4-node cluster and a ``ServingTier`` on top of it: sequences
shard across nodes by session affinity, prefills are admitted through
the cluster's admission front end (refused ones divert to idle nodes),
and each shard's ``PagedKVCache`` spills HBM -> host -> remote node as
sequences outgrow their page pool (docs/ARCHITECTURE.md §9).

Mid-stream, the node holding a session is killed: the session fails
over to its replica and keeps decoding — the script asserts the
committed KV pages survive byte-identically and that no reservation
leaked on any surviving node.

Run: PYTHONPATH=src python examples/serving_quickstart.py
"""
import numpy as np

from repro.runtime.cluster import Cluster
from repro.runtime.serving import ServingTier


def main() -> None:
    cluster = Cluster(num_nodes=4, node_capacity=8 << 20,
                      page_size=1 << 14, replication_factor=1,
                      admission=True)
    # 4 HBM slots + a 2 KiB host budget per shard: a long sequence pushes
    # slabs through all three spill levels
    tier = ServingTier(cluster, hbm_pages_per_node=4,
                       host_budget_bytes=2048)

    # --- continuous-batching admission -------------------------------------
    plan = tier.admit({1: 10, 2: 6, 3: 8})
    homes = {s: sess.node for s, sess in sorted(tier.sessions.items())}
    print(f"admitted 3 sequences; session homes {homes}, "
          f"{len(plan.diversions)} diverted off pressured nodes")

    tier.decode([1, 2, 3], steps=8)
    shard = tier._shards[tier.sessions[1].node]
    print(f"decoded 8 steps/seq; spill stats on seq 1's shard: "
          f"{shard.store.stats}")

    # --- kill the primary mid-stream ---------------------------------------
    victim = tier.sessions[1].node
    pre = [s.copy() for s in tier.sequence_slabs(1)]
    pre_len = tier.sessions[1].length
    cluster.kill_node(victim)
    print(f"killed node {victim} (home of seq 1) mid-stream")

    tier.decode([1, 2, 3], steps=4)
    assert tier.stats["failovers"] >= 1
    now = tier.sequence_slabs(1)
    for k in range(pre_len // tier.page_tokens):
        assert now[k].tobytes() == pre[k].tobytes(), "KV page diverged"
    assert all(tier.verify(s) for s in (1, 2, 3))
    print(f"seq 1 resumed on node {tier.sessions[1].node}: committed pages "
          f"byte-identical, all sequences verify against the KV oracle")

    # --- attention over the restored pool ----------------------------------
    out = tier.attend([1, 2, 3], impl="xla")
    print(f"decode attention over the serving pool: "
          f"{sorted((s, v.shape) for s, v in out.items())}")

    for s in (1, 2, 3):
        tier.finish(s)
    for nid, rep in cluster.pressure_report().items():
        assert rep["reserved"] == 0, (nid, rep)
    tier.close()
    cluster.shutdown()
    print("clean: no leaked reservations on any surviving node")


if __name__ == "__main__":
    main()
