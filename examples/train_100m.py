"""End-to-end training driver (deliverable b): a ~100M-parameter LM trained
for a few hundred steps on synthetic data through the full stack — buffer-
pool data pipeline, AdamW, async heterogeneous-layout checkpoints, straggler
timer, simulated crash + restart.

  PYTHONPATH=src python examples/train_100m.py --steps 300
  PYTHONPATH=src python examples/train_100m.py --steps 40 --quick   # CI-ish

The model is an OLMo-family config scaled to ~100M params (8L, d=512,
ff=2048, vocab=32768).
"""
import argparse
import shutil
import tempfile

from repro.configs import get_config
from repro.launch.train import run_training
from repro.models.model import count_params


def config_100m():
    return get_config("olmo-1b").with_(
        n_layers=14, d_model=512, n_heads=8, kv_heads=8, head_dim=64,
        d_ff=3072, vocab=32768, remat="none",
        compute_dtype="float32")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--quick", action="store_true",
                    help="smaller batch/seq for a fast sanity run")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--simulate-failure", action="store_true",
                    help="crash mid-run, then restart from checkpoint")
    args = ap.parse_args()

    cfg = config_100m()
    if args.quick:
        args.batch_size, args.seq_len = 4, 64
    print(f"model: {count_params(cfg)/1e6:.1f}M params "
          f"({cfg.n_layers}L d={cfg.d_model} ff={cfg.d_ff} "
          f"vocab={cfg.vocab})")

    ckdir = args.ckpt_dir or tempfile.mkdtemp(prefix="train100m_")
    try:
        if args.simulate_failure:
            try:
                run_training(cfg, steps=args.steps,
                             batch_size=args.batch_size,
                             seq_len=args.seq_len, ckpt_dir=ckdir,
                             ckpt_every=20, log_every=20,
                             fail_at_step=args.steps // 2)
            except RuntimeError as e:
                print(f"!! {e} — restarting from checkpoint")
        res = run_training(cfg, steps=args.steps, batch_size=args.batch_size,
                           seq_len=args.seq_len, ckpt_dir=ckdir,
                           ckpt_every=20, log_every=20)
        if res.restored_from is not None:
            print(f"(restored from step {res.restored_from})")
        print(f"finished: {res.steps} steps, "
              f"loss {res.losses[0]:.3f} -> {res.losses[-1]:.3f}, "
              f"{res.tokens_per_s:.0f} tok/s")
    finally:
        if args.ckpt_dir is None:
            shutil.rmtree(ckdir, ignore_errors=True)


if __name__ == "__main__":
    main()
