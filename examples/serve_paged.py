"""Batched serving over the Pangea paged KV cache.

  PYTHONPATH=src python examples/serve_paged.py [--requests 12]

A deliberately small HBM page budget forces the Eq.-1 paging policy to
offload cold sequences' KV pages to the host store and fetch them back on
their next decode turn — watch the offload/fetch counters.
"""
import argparse

import numpy as np

from repro.configs import smoke_config
from repro.launch.serve import Request, ServeLoop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--hbm-pages", type=int, default=8)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab, args.prompt_len,
                                    dtype=np.int32),
                    max_new_tokens=args.new_tokens)
            for i in range(args.requests)]
    loop = ServeLoop(cfg, batch_slots=3,
                     max_len=args.prompt_len + args.new_tokens + 8,
                     hbm_pages=args.hbm_pages)
    out = loop.run(reqs)
    print(f"served {len(out)} requests "
          f"({loop.stats['decode_tokens']} decode tokens, "
          f"{loop.stats['decode_tok_per_s']:.1f} tok/s)")
    print(f"KV paging: {loop.stats['offloads']} offloads, "
          f"{loop.stats['fetches']} fetches, "
          f"{loop.stats['offload_bytes']/2**20:.1f} MB moved")
    sample = list(out.items())[0]
    print(f"request {sample[0]} generated: {sample[1]}")


if __name__ == "__main__":
    main()
