"""Quickstart: the public API in ~60 lines.

  PYTHONPATH=src python examples/quickstart.py

Builds a small LM, trains a few steps on synthetic data staged through the
Pangea buffer pool, checkpoints (two heterogeneous layouts), restores, and
greedily decodes a few tokens through the prefill/decode path.
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.launch.train import run_training
from repro.models.model import build_model
from repro.checkpoint import CheckpointManager


def main() -> None:
    cfg = smoke_config("qwen3-0.6b")
    print(f"arch={cfg.name} (reduced): {cfg.n_layers}L d={cfg.d_model}")

    with tempfile.TemporaryDirectory() as ckdir:
        # -- train (data flows through the unified buffer pool) --
        result = run_training(cfg, steps=10, batch_size=8, seq_len=32,
                              ckpt_dir=ckdir, ckpt_every=5, log_every=5)
        print(f"trained {result.steps} steps; "
              f"loss {result.losses[0]:.3f} -> {result.losses[-1]:.3f}")

        # -- restore from the checkpoint (row OR col layout both work) --
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        mgr = CheckpointManager(ckdir, layouts=("row", "col"), num_shards=4)
        from repro.optim.train_state import make_train_state
        state = mgr.restore(make_train_state(params, cfg.opt_state_dtype))
        params = jax.tree.map(jnp.asarray, state.params)
        print(f"restored checkpoint at step {mgr.latest_step()}")

        # -- greedy decode --
        prompt = jnp.asarray(
            np.random.default_rng(0).integers(0, cfg.vocab, (1, 8)),
            jnp.int32)
        logits, cache = model.prefill(params, {"tokens": prompt}, max_len=16)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out = [int(tok[0, 0])]
        for t in range(8, 12):
            logits, cache = model.decode_step(params, {"tokens": tok},
                                              cache, t)
            tok = jnp.argmax(logits[:, :, :], axis=-1).astype(jnp.int32)
            out.append(int(tok[0, 0]))
        print("generated token ids:", out)


if __name__ == "__main__":
    main()
