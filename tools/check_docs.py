"""Docs link check: every relative markdown link/image in the given files
must resolve to a real file or directory (external http(s)/mailto links are
skipped — CI must not depend on the network). Exits non-zero listing every
broken link.

Usage::

    python tools/check_docs.py README.md docs/ARCHITECTURE.md
"""
from __future__ import annotations

import os
import re
import sys

# [text](target), ![alt](target) — target up to an optional #fragment;
# inline code spans are stripped first so `[x](y)` examples don't count
LINK = re.compile(r"!?\[[^\]]*\]\(([^)#\s]+)(?:#[^)]*)?\)")
CODE = re.compile(r"`[^`]*`|```.*?```", re.S)


def broken_links(path: str) -> list:
    base = os.path.dirname(os.path.abspath(path))
    with open(path, encoding="utf-8") as f:
        text = CODE.sub("", f.read())
    bad = []
    for m in LINK.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        if not os.path.exists(os.path.normpath(os.path.join(base, target))):
            bad.append(target)
    return bad


def main(argv=None) -> int:
    paths = (argv if argv is not None else sys.argv[1:]) or ["README.md"]
    failed = False
    for path in paths:
        if not os.path.exists(path):
            print(f"MISSING FILE: {path}")
            failed = True
            continue
        bad = broken_links(path)
        for target in bad:
            print(f"{path}: broken link -> {target}")
        failed = failed or bool(bad)
        if not bad:
            print(f"{path}: links OK")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
