"""Offline page-log checker: walk a directory tree, fsck every node page log
found, and print one JSON report per log (plus a summary line). Read-only —
unlike replay, it never truncates a torn tail, it just reports it.

Usage::

    PYTHONPATH=src python tools/pagelog_fsck.py <root> [<root> ...]

Exit status is 0 when every log is clean (no CRC failures, no torn tail),
1 otherwise — CI uploads the output as the durable-tier health artifact.
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.pagelog import LOG_FILENAME, fsck  # noqa: E402


def find_logs(root: str):
    for dirpath, _dirnames, filenames in os.walk(root):
        if LOG_FILENAME in filenames:
            yield dirpath


def main(argv=None) -> int:
    roots = (argv if argv is not None else sys.argv[1:]) or ["."]
    reports = {}
    for root in roots:
        for log_dir in sorted(find_logs(root)):
            reports[log_dir] = fsck(log_dir)
    for log_dir, rep in reports.items():
        print(json.dumps({"log": log_dir, **rep}, sort_keys=True))
    clean = all(r["clean"] for r in reports.values())
    gens = sum(r.get("generation", 0) for r in reports.values())
    stale = sum(1 for r in reports.values() if r.get("stale_compact_tmp"))
    amps = [r["amplification"] for r in reports.values()
            if "amplification" in r]
    worst = max(amps) if amps else 0.0
    print(f"# {len(reports)} page log(s), "
          f"{sum(r['records'] for r in reports.values())} records, "
          f"{gens} compaction generation(s), worst amplification {worst}, "
          f"{stale} stale compaction tmp file(s), "
          f"{'all clean' if clean else 'PROBLEMS FOUND'}")
    return 0 if clean else 1


if __name__ == "__main__":
    sys.exit(main())
