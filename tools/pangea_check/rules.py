"""Rule implementations for pangea-check.

One AST pass per file.  The checker is deliberately *intra-procedural* and
heuristic where full proof would need dataflow (R2/R5 escape analysis): a
grant or descriptor counts as handled when it is context-managed, explicitly
released/freed, or *handed off* (returned, stored into a container/attribute,
or passed to another call — ownership moved, the receiver is now
responsible).  The runtime sanitizer (``core/sanitizer.py``) covers what the
lexical pass cannot see across calls; together they gate CI.

Waiver syntax (counted against the CI budget, stale waivers are errors)::

    something_suspicious()   # pangea: allow(R3): one-line justification
"""
from __future__ import annotations

import ast
import os
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

# files (by posix-path suffix) structurally exempt from a rule
PICKLE_ESCAPE_FILES = ("repro/runtime/rpc.py",)       # R1's counted hatch
BARE_LOCK_HOME = ("repro/core/sanitizer.py",)         # R4's tower bottom

_WAIVER_RE = re.compile(
    r"#\s*pangea:\s*allow\(\s*(R\d+)\s*\)\s*:\s*(\S.*)")

BLOCKING_ATTRS = {
    "sleep", "fsync", "fdatasync", "sendall", "recv", "recv_into",
    "accept", "connect", "select", "wait", "wait_for", "result", "join",
}
BLOCKING_NAMES = {"send_msg", "recv_msg", "sleep"}

_LOCKISH_TAILS = ("lock", "mutex", "cv", "cond", "idle")


@dataclass
class Finding:
    rule: str
    path: str
    line: int
    message: str
    waived: bool = False
    waiver_reason: str = ""

    def __str__(self) -> str:
        w = "  [waived: " + self.waiver_reason + "]" if self.waived else ""
        return f"{self.path}:{self.line}: {self.rule} {self.message}{w}"


@dataclass
class Waiver:
    rule: str
    path: str
    line: int
    reason: str
    used: bool = False


@dataclass
class CheckResult:
    findings: List[Finding] = field(default_factory=list)   # unwaived
    waived: List[Finding] = field(default_factory=list)
    stale_waivers: List[Waiver] = field(default_factory=list)
    files_checked: int = 0

    @property
    def waivers_used(self) -> int:
        return len(self.waived)


def _is_lockish_name(name: str) -> bool:
    n = name.lower().lstrip("_")
    if n.endswith("clock"):
        return False
    return n in _LOCKISH_TAILS or any(
        n == t or n.endswith("_" + t) or n.endswith(t)
        for t in _LOCKISH_TAILS)


def _lockish_expr(node: ast.expr) -> Optional[str]:
    """If this with-item context looks like a lock/condition, return its
    source text (used for the own-condition wait exemption)."""
    if isinstance(node, ast.Attribute) and _is_lockish_name(node.attr):
        return ast.unparse(node)
    if isinstance(node, ast.Name) and _is_lockish_name(node.id):
        return ast.unparse(node)
    return None


def _func_name(call: ast.Call) -> Tuple[Optional[str], Optional[ast.expr]]:
    """(terminal name, receiver expr or None) of a call's function."""
    f = call.func
    if isinstance(f, ast.Attribute):
        return f.attr, f.value
    if isinstance(f, ast.Name):
        return f.id, None
    return None, None


class _FileChecker:
    def __init__(self, path: str, tree: ast.AST, source: str):
        self.path = path
        self.posix = path.replace(os.sep, "/")
        self.tree = tree
        self.source_lines = source.splitlines()
        self.findings: List[Finding] = []

    def add(self, rule: str, node: ast.AST, message: str) -> None:
        self.findings.append(
            Finding(rule, self.path, getattr(node, "lineno", 0), message))

    def _exempt(self, suffixes: Sequence[str]) -> bool:
        return any(self.posix.endswith(s) for s in suffixes)

    # -- R1 -------------------------------------------------------------------
    def check_pickle(self) -> None:
        if self._exempt(PICKLE_ESCAPE_FILES):
            return
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.name.split(".")[0] in ("pickle", "cPickle", "dill"):
                        self.add("R1", node,
                                 f"[no-pickle] import of {a.name!r} outside "
                                 f"runtime/rpc.py's counted escape hatch")
            elif isinstance(node, ast.ImportFrom):
                if (node.module or "").split(".")[0] in ("pickle", "dill"):
                    self.add("R1", node,
                             f"[no-pickle] from-import of {node.module!r} "
                             f"outside runtime/rpc.py")

    # -- R4 -------------------------------------------------------------------
    def check_bare_locks(self) -> None:
        if self._exempt(BARE_LOCK_HOME):
            return
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            name, recv = _func_name(node)
            if name not in ("Lock", "RLock", "Condition"):
                continue
            recv_src = ast.unparse(recv) if recv is not None else ""
            if recv_src in ("threading", "multiprocessing") or recv is None:
                self.add("R4", node,
                         f"[bare-lock] {recv_src + '.' if recv_src else ''}"
                         f"{name}() constructed outside core/sanitizer.py — "
                         f"use tracked_lock()/tracked_rlock()/"
                         f"tracked_condition() so the sanitizer sees it")

    # -- R6 / R7 --------------------------------------------------------------
    def check_excepts(self) -> None:
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if node.type is None:
                self.add("R6", node,
                         "[bare-except] bare `except:` hides the failure "
                         "class (KeyboardInterrupt included) — name the "
                         "exceptions")
                continue
            names: Set[str] = set()
            for t in ([node.type.elts] if isinstance(node.type, ast.Tuple)
                      else [[node.type]]):
                for e in t:
                    if isinstance(e, ast.Name):
                        names.add(e.id)
            if "ImportError" in names or "ModuleNotFoundError" in names:
                body_trivial = all(
                    isinstance(s, ast.Pass)
                    or (isinstance(s, ast.Expr)
                        and isinstance(s.value, ast.Constant))
                    for s in node.body)
                if body_trivial:
                    self.add("R7", node,
                             "[swallowed-importerror] `except ImportError: "
                             "pass` silently downgrades a missing "
                             "dependency — record the fallback or re-raise")

    # -- R3 -------------------------------------------------------------------
    def check_blocking_in_lock(self) -> None:
        self._walk_locks(self.tree, [])

    def _walk_locks(self, node: ast.AST, lock_stack: List[str]) -> None:
        for child in ast.iter_child_nodes(node):
            self._visit_lock_node(child, lock_stack)

    def _visit_lock_node(self, node: ast.AST, lock_stack: List[str]) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            # deferred bodies run outside this lock region
            self._walk_locks(node, [])
            return
        if isinstance(node, ast.With):
            locks = [s for item in node.items
                     if (s := _lockish_expr(item.context_expr))]
            for item in node.items:
                self._visit_lock_node(item.context_expr, lock_stack)
            inner = lock_stack + locks
            for stmt in node.body:
                self._visit_lock_node(stmt, inner)
            return
        if isinstance(node, ast.Call) and lock_stack:
            self._check_blocking_call(node, lock_stack)
        self._walk_locks(node, lock_stack)

    def _check_blocking_call(self, call: ast.Call,
                             lock_stack: List[str]) -> None:
        name, recv = _func_name(call)
        if name is None:
            return
        if recv is None:
            if name in BLOCKING_NAMES:
                self.add("R3", call,
                         f"[blocking-in-lock] {name}() called while holding "
                         f"{lock_stack[-1]}")
            return
        if name not in BLOCKING_ATTRS:
            return
        recv_src = ast.unparse(recv)
        if name in ("wait", "wait_for") and recv_src in lock_stack:
            return  # waiting on the condition you hold releases it
        if name == "result":
            t = next((kw.value for kw in call.keywords
                      if kw.arg == "timeout"),
                     call.args[0] if call.args else None)
            if isinstance(t, ast.Constant) and t.value == 0:
                return  # non-blocking poll
        if name == "join" and (isinstance(recv, ast.Constant)
                               or recv_src.endswith("path")):
            return  # str.join / os.path.join
        self.add("R3", call,
                 f"[blocking-in-lock] {recv_src}.{name}(...) called while "
                 f"holding {lock_stack[-1]}")

    # -- R2 / R5 (escape analysis) -------------------------------------------
    @staticmethod
    def _walk_scope(root: ast.AST):
        """Yield ``root``'s nodes without descending into nested function
        scopes — each function's grants are checked in its own pass."""
        stack = list(ast.iter_child_nodes(root))
        while stack:
            node = stack.pop()
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
                continue
            yield node
            stack.extend(ast.iter_child_nodes(node))

    def check_leaks(self) -> None:
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._check_function_leaks(node)
        # module-level discarded grants
        self._check_body_leaks(self.tree)

    @staticmethod
    def _grant_kind(call: ast.Call) -> Optional[Tuple[str, str]]:
        """(rule, label) when this call mints a tracked resource."""
        name, recv = _func_name(call)
        if name in ("reserve", "try_reserve") and recv is not None:
            return "R2", f"{ast.unparse(recv)}.{name}()"
        if name == "put" and recv is not None:
            r = ast.unparse(recv).lower()
            if any(k in r for k in ("arena", "inbox", "outbox")):
                return "R5", f"{ast.unparse(recv)}.put()"
        return None

    def _check_body_leaks(self, scope: ast.AST) -> None:
        for node in self._walk_scope(scope):
            if isinstance(node, ast.Expr) and isinstance(node.value, ast.Call):
                kind = self._grant_kind(node.value)
                if kind is not None:
                    rule, label = kind
                    what = ("reservation" if rule == "R2"
                            else "frame descriptor")
                    self.add(rule, node,
                             f"[{'reservation-leak' if rule == 'R2' else 'arena-frame-leak'}] "
                             f"{label} result discarded — the {what} can "
                             f"never be released")

    def _check_function_leaks(self, fn: ast.AST) -> None:
        self._check_body_leaks(fn)   # discarded-result form
        # assigned-name form: name must be released/freed/with'd/handed off
        grants: List[Tuple[str, str, str, ast.Assign]] = []
        for node in self._walk_scope(fn):
            if not isinstance(node, ast.Assign) or len(node.targets) != 1:
                continue
            tgt = node.targets[0]
            if not isinstance(tgt, ast.Name):
                continue
            if not isinstance(node.value, ast.Call):
                continue
            kind = self._grant_kind(node.value)
            if kind is not None:
                grants.append((kind[0], kind[1], tgt.id, node))
        for rule, label, var, assign in grants:
            if not self._escapes(fn, var, assign, rule):
                what, verb = (("reservation", "release()") if rule == "R2"
                              else ("frame descriptor", "free()"))
                tag = ("reservation-leak" if rule == "R2"
                       else "arena-frame-leak")
                self.add(rule, assign,
                         f"[{tag}] {what} {var!r} from {label} is neither "
                         f"context-managed nor {verb}'d nor handed off on "
                         f"any path")

    def _escapes(self, fn: ast.AST, var: str, assign: ast.Assign,
                 rule: str) -> bool:
        """Does ``var`` reach a release/free, a ``with`` item, or a handoff
        (return/yield/call-argument/container/attribute store) anywhere in
        the function?"""
        for node in ast.walk(fn):
            if node is assign:
                continue
            if isinstance(node, ast.With):
                for item in node.items:
                    ce = item.context_expr
                    if isinstance(ce, ast.Name) and ce.id == var:
                        return True
            if isinstance(node, ast.Call):
                name, recv = _func_name(node)
                if (isinstance(recv, ast.Name) and recv.id == var
                        and name in ("release", "free", "close")):
                    return True
                for arg in list(node.args) + [kw.value for kw in node.keywords]:
                    for sub in ast.walk(arg):
                        if isinstance(sub, ast.Name) and sub.id == var:
                            return True
            if isinstance(node, (ast.Return, ast.Yield)) \
                    and node.value is not None:
                for sub in ast.walk(node.value):
                    if isinstance(sub, ast.Name) and sub.id == var:
                        return True
            if isinstance(node, ast.Assign) and node.value is not assign.value:
                stores_elsewhere = any(
                    isinstance(t, (ast.Attribute, ast.Subscript, ast.Tuple))
                    for t in node.targets)
                if stores_elsewhere:
                    for sub in ast.walk(node.value):
                        if isinstance(sub, ast.Name) and sub.id == var:
                            return True
        return False

    # -- driver ---------------------------------------------------------------
    def run(self) -> List[Finding]:
        self.check_pickle()
        self.check_bare_locks()
        self.check_excepts()
        self.check_blocking_in_lock()
        self.check_leaks()
        return self.findings


def _collect_waivers(source: str, path: str) -> Dict[Tuple[str, int], Waiver]:
    """Waivers keyed by (rule, line).  A waiver covers findings on its own
    line and on the line below (so it can sit above a long statement)."""
    out: Dict[Tuple[str, int], Waiver] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        m = _WAIVER_RE.search(line)
        if m:
            out[(m.group(1), i)] = Waiver(m.group(1), path, i,
                                          m.group(2).strip())
    return out


def check_file(path: str) -> Tuple[List[Finding], List[Waiver]]:
    with open(path, "r", encoding="utf-8") as f:
        source = f.read()
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return ([Finding("R0", path, e.lineno or 0,
                         f"[parse-error] {e.msg}")], [])
    findings = _FileChecker(path, tree, source).run()
    waivers = _collect_waivers(source, path)
    for f_ in findings:
        for delta in (0, -1):
            w = waivers.get((f_.rule, f_.line + delta))
            if w is not None:
                f_.waived = True
                f_.waiver_reason = w.reason
                w.used = True
                break
    return findings, list(waivers.values())


def iter_py_files(paths: Sequence[str]) -> List[str]:
    files: List[str] = []
    for p in paths:
        if os.path.isfile(p):
            files.append(p)
            continue
        for root, dirs, names in os.walk(p):
            dirs[:] = [d for d in dirs
                       if d not in ("__pycache__", ".git", ".hypothesis")]
            files.extend(os.path.join(root, n) for n in sorted(names)
                         if n.endswith(".py"))
    return files


def check_paths(paths: Sequence[str]) -> CheckResult:
    result = CheckResult()
    for path in iter_py_files(paths):
        findings, waivers = check_file(path)
        result.files_checked += 1
        for f_ in findings:
            (result.waived if f_.waived else result.findings).append(f_)
        result.stale_waivers.extend(w for w in waivers if not w.used)
    return result


def run_check(paths: Sequence[str]) -> CheckResult:
    """Programmatic entry point (tests use this)."""
    return check_paths(paths)
