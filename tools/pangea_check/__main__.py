"""CLI: ``python -m tools.pangea_check src tests --strict``.

Exit status 0 only when every finding is waived, the number of used waivers
stays within ``WAIVER_BUDGET``, and no waiver is stale (present in the
source but matching no finding — suppressions must not outlive the code
they excused).
"""
from __future__ import annotations

import argparse
import json
import sys

from . import RULES
from .rules import check_paths

# The CI-asserted waiver budget.  Raising this number is a reviewed change:
# every unit of budget is a named, justified exception to an invariant.
WAIVER_BUDGET = 10


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="pangea_check",
        description="invariant lint for the Pangea concurrent data plane")
    ap.add_argument("paths", nargs="+", help="files or directories to scan")
    ap.add_argument("--strict", action="store_true",
                    help="fail on unwaived findings, budget overrun, or "
                         "stale waivers")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable output")
    ap.add_argument("--max-waivers", type=int, default=WAIVER_BUDGET,
                    help=f"waiver budget (default {WAIVER_BUDGET})")
    args = ap.parse_args(argv)

    result = check_paths(args.paths)
    over_budget = result.waivers_used > args.max_waivers

    if args.as_json:
        print(json.dumps({
            "files_checked": result.files_checked,
            "findings": [vars(f) for f in result.findings],
            "waived": [vars(f) for f in result.waived],
            "stale_waivers": [vars(w) for w in result.stale_waivers],
            "waiver_budget": args.max_waivers,
            "waivers_used": result.waivers_used,
        }, indent=2))
    else:
        for f in result.findings:
            print(f)
        for f in result.waived:
            print(f)
        for w in result.stale_waivers:
            print(f"{w.path}:{w.line}: stale waiver for {w.rule} "
                  f"({w.reason!r}) — matches no finding, remove it")
        print(f"pangea-check: {result.files_checked} files, "
              f"{len(result.findings)} finding(s), "
              f"{result.waivers_used}/{args.max_waivers} waivers used, "
              f"{len(result.stale_waivers)} stale")
        if result.findings and not args.strict:
            by_rule = {}
            for f in result.findings:
                by_rule.setdefault(f.rule, 0)
                by_rule[f.rule] += 1
            for rule, n in sorted(by_rule.items()):
                print(f"  {rule} x{n}: {RULES.get(rule, '?')}")

    if args.strict and (result.findings or over_budget
                        or result.stale_waivers):
        if over_budget:
            print(f"pangea-check: waiver budget exceeded "
                  f"({result.waivers_used} > {args.max_waivers})",
                  file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
