"""pangea-check: AST-based invariant lint for the concurrent data plane.

See ``tools/pangea_check/README.md`` for the rule table (R1-R7) and the
waiver syntax.  Programmatic entry point: :func:`run_check`.
"""
from .rules import Finding, Waiver, check_file, check_paths, run_check  # noqa: F401

RULES = {
    "R1": "no-pickle: pickle only inside runtime/rpc.py's counted escape hatch",
    "R2": "reservation-leak: reserve()/try_reserve() grants must be context-"
          "managed, released, or handed off",
    "R3": "blocking-in-lock: no blocking call (sleep/fsync/socket/wait/"
          "future-result) inside a `with <lock>:` body",
    "R4": "bare-lock: no threading.Lock/RLock/Condition outside "
          "core/sanitizer.py — use tracked_lock()/tracked_condition()",
    "R5": "arena-frame-leak: arena put() descriptors must reach free() or a "
          "descriptor handoff",
    "R6": "bare-except: `except:` hides the failure class",
    "R7": "swallowed-importerror: `except ImportError: pass` silently "
          "downgrades a missing dependency (the PR-7 dispatch bug class)",
}
