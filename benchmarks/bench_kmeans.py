"""Paper Fig. 2: k-means — monolithic vs layered storage.

Both variants run the same JAX k-means compute. They differ ONLY in the
storage path, isolating the paper's claim:

* monolithic — points live in buffer-pool pages; each iteration takes
  zero-copy numpy views straight into jnp arrays (one copy host→device).
* layered    — models HDFS→cache→executor: per iteration the dataset is
  serialized (tobytes), copied into a "cache layer", deserialized
  (frombuffer + copy), and re-partitioned — the redundant crossings the
  paper blames for its 6x gap.

Derived column: init_s (first-touch load) and iter_s (per-iteration).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BufferPool
from repro.core.attributes import AttributeSet, DurabilityType
from repro.core.services import SequentialWriter, get_page_iterators

from .common import record

N, DIM, K, ITERS = 200_000, 10, 8, 5


@jax.jit
def _assign_update(points, centroids):
    d = ((points[:, None, :] - centroids[None, :, :]) ** 2).sum(-1)
    assign = jnp.argmin(d, axis=1)
    onehot = jax.nn.one_hot(assign, centroids.shape[0], dtype=points.dtype)
    sums = onehot.T @ points
    counts = onehot.sum(0)[:, None]
    return sums / jnp.maximum(counts, 1.0)


def _points() -> np.ndarray:
    rng = np.random.default_rng(0)
    return rng.normal(size=(N, DIM)).astype(np.float32)


def _monolithic() -> tuple:
    pts = _points()
    pool = BufferPool(1 << 28)
    ls = pool.create_set("pts", 1 << 20,
                         AttributeSet(durability=DurabilityType.WRITE_THROUGH))
    dt = np.dtype((np.float32, (DIM,)))
    t0 = time.perf_counter()
    w = SequentialWriter(pool, ls, dt)
    w.append_batch(pts)
    w.close()
    # first pass: compute norms (write-back derived set) like the paper
    norms_ls = pool.create_set("norms", 1 << 20)
    nw = SequentialWriter(pool, norms_ls, np.dtype(np.float32))
    for it in get_page_iterators(pool, ls, dt, 1):
        for recs in it:
            nw.append_batch((recs ** 2).sum(1))
    nw.close()
    # monolithic: data stays in the shared pool across iterations — stage
    # device views ONCE at init (no per-iteration layer crossings, the
    # paper's point); layered re-crosses its cache interface every iteration
    chunks = []
    for it in get_page_iterators(pool, ls, dt, 1):
        for recs in it:
            chunks.append(jnp.asarray(recs))       # zero-copy view -> device
    allpts = jnp.concatenate(chunks)
    allpts.block_until_ready()
    init_s = time.perf_counter() - t0
    cents = jnp.asarray(pts[:K])
    _assign_update(allpts, cents).block_until_ready()   # warm path
    t1 = time.perf_counter()
    for _ in range(ITERS):
        cents = _assign_update(allpts, cents)
    cents.block_until_ready()
    iter_s = (time.perf_counter() - t1) / ITERS
    return init_s, iter_s


def _layered() -> tuple:
    pts = _points()
    t0 = time.perf_counter()
    # HDFS layer: serialized blocks
    hdfs_blocks = [pts[i:i + 20_000].tobytes() for i in range(0, N, 20_000)]
    # cache layer (Alluxio): byte copies
    cache = [bytes(b) for b in hdfs_blocks]
    # executor: deserialize + copy + "repartition"
    parts = [np.frombuffer(b, np.float32).reshape(-1, DIM).copy()
             for b in cache]
    _ = [np.ascontiguousarray(p) for p in parts]
    init_s = time.perf_counter() - t0
    cents = jnp.asarray(pts[:K])
    _assign_update(jnp.asarray(pts), cents).block_until_ready()  # warm path
    t1 = time.perf_counter()
    for _ in range(ITERS):
        # every iteration re-crosses the cache/executor interface
        parts = [np.frombuffer(b, np.float32).reshape(-1, DIM).copy()
                 for b in cache]
        allpts = jnp.concatenate([jnp.asarray(p) for p in parts])
        cents = _assign_update(allpts, cents)
    cents.block_until_ready()
    iter_s = (time.perf_counter() - t1) / ITERS
    return init_s, iter_s


def run() -> None:
    # warm the jitted kernel so compile time lands in neither variant
    warm = jnp.zeros((128, DIM), jnp.float32)
    _assign_update(warm, warm[:K]).block_until_ready()
    init_m, iter_m = _monolithic()
    record("kmeans/monolithic", iter_m * 1e6,
           f"init_s={init_m:.3f};iter_s={iter_m:.3f}")
    init_l, iter_l = _layered()
    record("kmeans/layered", iter_l * 1e6,
           f"init_s={init_l:.3f};iter_s={iter_l:.3f};"
           f"speedup={iter_l/iter_m:.2f}x")


if __name__ == "__main__":
    run()
