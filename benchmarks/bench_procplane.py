"""Process data plane vs in-process backend: end-to-end durable shuffle
pipelines (ingest with write-through fsync -> shuffle -> drain) timed
wall-clock on both backends, min-of-N.

On a box with few cores the process backend cannot win on CPU — forked node
processes add RPC framing and shm copies on top of the same arithmetic.
What it *can* win is blocked time: every node process issues its own
``fsync`` / spill I/O / admission waits, so durable appends that the
in-process backend serializes through one thread overlap across nodes.
The two configs bracket that claim:

* **overlap** — replicated durable ingest plus an in-memory shuffle.  The
  fsync stream (primary + replica page appends) dominates; proc overlaps
  them across the four node processes.
* **overcap** — an over-capacity pipeline (node capacity far below the
  working set, admission on).  Spill, refault, and admission stalls
  dominate; proc overlaps those too.

A third row SIGKILLs a node between map and reduce and requires the
shuffle output to come back byte-identical through replica re-execution,
with the arena/process audit clean on close.
"""
from __future__ import annotations

import os
import subprocess
import sys
import tempfile
import time

import numpy as np

from repro.runtime.cluster import Cluster

from .common import record, scaled, smoke_mode

PAIR = np.dtype([("key", np.int64), ("val", np.float64)])
NUM_NODES = 4
NUM_REDUCERS = 8
PAGE = 1 << 13
OVERLAP_N = 800_000
OVERCAP_N = 800_000
SIGKILL_N = 150_000


def _pairs(n: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    recs = np.zeros(n, PAIR)
    recs["key"] = rng.integers(0, 1 << 30, n)
    recs["val"] = rng.random(n)
    return recs


def _cluster(backend: str, tmp: str, *, cap: int, rf: int,
             page_size: int = PAGE) -> Cluster:
    kw = dict(node_capacity=cap, page_size=page_size, replication_factor=rf,
              pagelog_dir=os.path.join(tmp, "log"), pagelog_fsync="always",
              spill_dir=os.path.join(tmp, "spill"), admission=True)
    if backend == "proc":
        return Cluster(NUM_NODES, backend="proc", **kw)
    return Cluster(NUM_NODES, **kw)


def _pipeline(c: Cluster, recs: np.ndarray, proc: bool) -> float:
    """Durable ingest -> shuffle -> drain; returns elapsed seconds."""
    t0 = time.perf_counter()
    sset = c.create_sharded_set("pts", recs, key_fn=lambda r: r["key"])
    sh = c.shuffle("sh", NUM_REDUCERS, PAIR)
    if proc:
        sh.map_sharded(sset, key_field="key")
    else:
        sh.map_sharded(sset, key_fn=lambda r: r["key"])
    sh.finish_maps()
    sh.place_reducers_locally()
    n = sum(len(sh.pull(r)) for r in range(NUM_REDUCERS))
    elapsed = time.perf_counter() - t0
    if n != len(recs):
        raise AssertionError(f"pipeline dropped records: {n} != {len(recs)}")
    return elapsed


def _config(label: str):
    """(records, node_capacity, replication_factor) for one config —
    shared by the parent and the measurement subprocess."""
    if label == "overlap":
        return scaled(OVERLAP_N), 64 << 20, 2
    # keep overcap over capacity at smoke sizes too: cap ~= 1/6 of the
    # working set (full size: 800k * 16B / 6 ~= 2 MiB per node)
    n = scaled(OVERCAP_N)
    return n, max(256 << 10, n * PAIR.itemsize // 6), 1


def _measure_once(label: str, backend: str) -> float:
    n, cap, rf = _config(label)
    with tempfile.TemporaryDirectory() as tmp:
        c = _cluster(backend, tmp, cap=cap, rf=rf)
        recs = _pairs(n)
        elapsed = _pipeline(c, recs, backend == "proc")
        c.close() if backend == "proc" else c.shutdown()
    return elapsed


def _best_of(backend: str, label: str, *, repeats: int) -> float:
    """Min-of-N wall clock, each rep in a fresh interpreter.  Running
    in-process would tax whichever backend runs later in the suite: the
    driver heap the earlier benchmarks fattened makes every proc-backend
    fork pay COW faults, and skews the in-process allocator too."""
    best = None
    for _ in range(repeats):
        out = subprocess.run(
            [sys.executable, "-m", "benchmarks.bench_procplane",
             "--rep", label, backend],
            capture_output=True, text=True, check=True)
        elapsed = None
        for line in out.stdout.splitlines():
            if line.startswith("ELAPSED "):
                elapsed = float(line.split()[1])
        if elapsed is None:
            raise RuntimeError(
                f"measurement subprocess returned no timing: {out.stdout!r} "
                f"{out.stderr!r}")
        best = elapsed if best is None or elapsed < best else best
    return best


def run_pipelines() -> None:
    repeats = 1 if smoke_mode() else 3
    for label in ("overlap", "overcap"):
        n, cap, rf = _config(label)
        t_in = _best_of("inproc", label, repeats=repeats)
        t_pr = _best_of("proc", label, repeats=repeats)
        gain = t_in / t_pr
        base = f"shuffle/cluster4node/procplane/{label}"
        record(f"{base}/inproc", t_in * 1e6, f"elapsed={t_in:.3f}s",
               elapsed_s=t_in, records=n, node_capacity=cap,
               replication_factor=rf)
        record(f"{base}/proc", t_pr * 1e6, f"elapsed={t_pr:.3f}s",
               elapsed_s=t_pr, records=n, node_capacity=cap,
               replication_factor=rf)
        record(f"{base}/gain", (t_in - t_pr) * 1e6,
               f"gain={gain:.2f}x;proc_wins={gain > 1.0}",
               gain=round(gain, 3), proc_wins=bool(gain > 1.0))


def run_sigkill() -> None:
    """SIGKILL a node between map and reduce; replica re-execution must
    deliver the same partition bytes, and close() must reap every process
    and unlink every arena segment."""
    n = scaled(SIGKILL_N)
    with tempfile.TemporaryDirectory() as tmp:
        c = _cluster("proc", tmp, cap=32 << 20, rf=2, page_size=1 << 14)
        recs = _pairs(n, seed=7)
        sset = c.create_sharded_set("pts", recs, key_fn=lambda r: r["key"])

        def drain(sh):
            parts = []
            for r in range(NUM_REDUCERS):
                parts.append(np.sort(sh.pull(r), order=("key", "val")))
                sh.release_reducer(r)
            return parts

        ref_sh = c.shuffle("ref", NUM_REDUCERS, PAIR)
        ref_sh.map_sharded(sset, key_field="key")
        ref_sh.finish_maps()
        ref_sh.place_reducers_locally()
        ref = drain(ref_sh)

        t0 = time.perf_counter()
        sh = c.shuffle("kill", NUM_REDUCERS, PAIR)
        sh.map_sharded(sset, key_field="key")
        sh.finish_maps()
        c.kill_node(1)                      # between map and reduce
        sh.place_reducers_locally()
        out = drain(sh)
        elapsed = time.perf_counter() - t0

        identical = all(np.array_equal(a, b) for a, b in zip(ref, out))
        report = c.close()
    record("recovery/cluster4node/procplane/sigkill", elapsed * 1e6,
           f"byte_identical={identical};clean_close={report.ok}",
           elapsed_s=elapsed, byte_identical=bool(identical),
           recovered_ok=bool(identical and report.ok), records=n)


def run() -> None:
    run_pipelines()
    run_sigkill()


if __name__ == "__main__":
    if len(sys.argv) == 4 and sys.argv[1] == "--rep":
        print(f"ELAPSED {_measure_once(sys.argv[2], sys.argv[3]):.6f}")
    else:
        run()
