"""Paper Fig. 5: single-node-failure recovery latency via heterogeneous
replication, for 10/20/30 worker nodes, plus the conflicting-object ratio
(expected N/K)."""
from __future__ import annotations

import numpy as np

from repro.core import (PartitionScheme, expected_conflicts, fail_node,
                        partition_set, random_dispatch, recover_target_shard,
                        register_replica)

from .common import record, timeit

REC = np.dtype([("okey", np.int64), ("pkey", np.int64)])
N = 600_000


def run() -> None:
    rng = np.random.default_rng(0)
    recs = np.zeros(N, REC)
    recs["okey"] = rng.permutation(N)
    recs["pkey"] = rng.integers(0, 10_000, N)
    for nodes in (10, 20, 30):
        src = random_dispatch("lineitem", recs, nodes, seed=nodes)
        scheme = PartitionScheme("okey", lambda r: r["okey"], 10 * nodes,
                                 nodes)
        tgt = partition_set(src, "lineitem_pt", scheme)
        reg = register_replica(src, tgt, scheme)
        ratio = reg.num_conflicting / N

        def recover():
            import copy
            reg2 = copy.copy(reg)
            reg2.target = partition_set(src, "t2", scheme)
            fail_node(reg2.target, 1)
            recover_target_shard(reg2, 1)

        t = timeit(recover, repeats=3)
        record(f"recovery/nodes{nodes}", t * 1e6,
               f"conflict_ratio={ratio:.4f};expected={1/nodes:.4f}")


if __name__ == "__main__":
    run()
