"""Paper Fig. 5: single-node-failure recovery latency via heterogeneous
replication, for 10/20/30 worker nodes, plus the conflicting-object ratio
(expected N/K) — and the same scenario through the real cluster backend:
kill one node's entire buffer pool and re-materialize its shards from chain
replicas with checksum verification."""
from __future__ import annotations

import numpy as np

from repro.core import (PartitionScheme, expected_conflicts, fail_node,
                        partition_set, random_dispatch, recover_target_shard,
                        register_replica)
from repro.runtime.cluster import Cluster

from .common import record, scaled, timeit

REC = np.dtype([("okey", np.int64), ("pkey", np.int64)])
N = 600_000
CLUSTER_N = 200_000


def run() -> None:
    rng = np.random.default_rng(0)
    recs = np.zeros(scaled(N), REC)
    N_ = len(recs)
    recs["okey"] = rng.permutation(N_)
    recs["pkey"] = rng.integers(0, 10_000, N_)
    for nodes in (10, 20, 30):
        src = random_dispatch("lineitem", recs, nodes, seed=nodes)
        scheme = PartitionScheme("okey", lambda r: r["okey"], 10 * nodes,
                                 nodes)
        tgt = partition_set(src, "lineitem_pt", scheme)
        reg = register_replica(src, tgt, scheme)
        ratio = reg.num_conflicting / N_

        def recover():
            import copy
            reg2 = copy.copy(reg)
            reg2.target = partition_set(src, "t2", scheme)
            fail_node(reg2.target, 1)
            recover_target_shard(reg2, 1)

        t = timeit(recover, repeats=3)
        record(f"recovery/nodes{nodes}", t * 1e6,
               f"conflict_ratio={ratio:.4f};expected={1/nodes:.4f}",
               conflict_ratio=ratio, expected_ratio=1 / nodes)
    run_cluster()
    run_degrade()


def run_cluster() -> None:
    """Kill-one-node recovery through per-node buffer pools: the recovery
    time is real work (paged reads on replica holders, sequential writes into
    the replacement pool, CRC verification)."""
    rng = np.random.default_rng(1)
    n = scaled(CLUSTER_N)
    recs = np.zeros(n, REC)
    recs["okey"] = rng.permutation(n)
    recs["pkey"] = rng.integers(0, 10_000, n)
    for nodes in (4, 8):
        cluster = Cluster(nodes, node_capacity=64 << 20, page_size=1 << 18,
                          replication_factor=1)
        sset = cluster.create_sharded_set("lineitem", recs,
                                          key_fn=lambda r: r["okey"])
        victim = nodes // 2
        shard_bytes = sset.shards[victim].num_records * REC.itemsize
        cluster.kill_node(victim)
        report = cluster.recover_node(victim)
        assert report.ok, report.checksum_failures
        mbps = report.bytes_transferred / max(report.seconds, 1e-9) / 1e6
        record(f"recovery/cluster{nodes}node", report.seconds * 1e6,
               f"shard_mb={shard_bytes/1e6:.2f};"
               f"moved_mb={report.bytes_transferred/1e6:.2f};"
               f"mb_per_s={mbps:.0f};checksums_ok={report.ok}",
               recovery_s=report.seconds,
               bytes_transferred=report.bytes_transferred,
               checksums_ok=report.ok)
        cluster.shutdown()


def run_degrade() -> None:
    """Unrecoverable loss: no replacement node, so the cluster shrinks via
    elastic remesh and re-shards every set over the survivors."""
    rng = np.random.default_rng(2)
    n = scaled(CLUSTER_N)
    recs = np.zeros(n, REC)
    recs["okey"] = rng.permutation(n)
    recs["pkey"] = rng.integers(0, 10_000, n)
    for nodes in (4, 8):
        cluster = Cluster(nodes, node_capacity=64 << 20, page_size=1 << 18,
                          replication_factor=1)
        cluster.create_sharded_set("lineitem", recs,
                                   key_fn=lambda r: r["okey"])
        cluster.kill_node(nodes // 2)
        report = cluster.remesh_degrade()
        assert report.ok, report.lost
        record(f"recovery/degrade{nodes}to{nodes-1}node",
               report.seconds * 1e6,
               f"moved_mb={report.bytes_transferred/1e6:.2f};"
               f"resharded={len(report.resharded)}",
               degrade_s=report.seconds,
               bytes_transferred=report.bytes_transferred,
               surviving_nodes=len(report.node_ids))
        cluster.shutdown()


if __name__ == "__main__":
    run()
