"""Paper Fig. 5: single-node-failure recovery latency via heterogeneous
replication, for 10/20/30 worker nodes, plus the conflicting-object ratio
(expected N/K) — and the same scenario through the real cluster backend:
kill one node's entire buffer pool and re-materialize its shards from chain
replicas with checksum verification.

PR 6 adds the durable-tier rows: warm recovery (the revived node replays its
local page log — zero network bytes) against the cold baseline (its disk
died too, every byte pulled from replicas), and an aggregate-RAM-exceeding
scan that completes byte-identically because write-through sets page against
the log instead of failing."""
from __future__ import annotations

import os
import shutil
import tempfile

import numpy as np

from repro.core import (PartitionScheme, expected_conflicts, fail_node,
                        partition_set, random_dispatch, recover_target_shard,
                        register_replica)
from repro.runtime.cluster import Cluster

from .common import record, scaled, timeit

REC = np.dtype([("okey", np.int64), ("pkey", np.int64)])
N = 600_000
CLUSTER_N = 200_000
WARM_N = 150_000
OVERCAP_N = 400_000


def run() -> None:
    rng = np.random.default_rng(0)
    recs = np.zeros(scaled(N), REC)
    N_ = len(recs)
    recs["okey"] = rng.permutation(N_)
    recs["pkey"] = rng.integers(0, 10_000, N_)
    for nodes in (10, 20, 30):
        src = random_dispatch("lineitem", recs, nodes, seed=nodes)
        scheme = PartitionScheme("okey", lambda r: r["okey"], 10 * nodes,
                                 nodes)
        tgt = partition_set(src, "lineitem_pt", scheme)
        reg = register_replica(src, tgt, scheme)
        ratio = reg.num_conflicting / N_

        def recover():
            import copy
            reg2 = copy.copy(reg)
            reg2.target = partition_set(src, "t2", scheme)
            fail_node(reg2.target, 1)
            recover_target_shard(reg2, 1)

        t = timeit(recover, repeats=3)
        record(f"recovery/nodes{nodes}", t * 1e6,
               f"conflict_ratio={ratio:.4f};expected={1/nodes:.4f}",
               conflict_ratio=ratio, expected_ratio=1 / nodes)
    run_cluster()
    run_degrade()
    run_warm_recovery()
    run_overcap_scan()


def run_cluster() -> None:
    """Kill-one-node recovery through per-node buffer pools: the recovery
    time is real work (paged reads on replica holders, sequential writes into
    the replacement pool, CRC verification)."""
    rng = np.random.default_rng(1)
    n = scaled(CLUSTER_N)
    recs = np.zeros(n, REC)
    recs["okey"] = rng.permutation(n)
    recs["pkey"] = rng.integers(0, 10_000, n)
    for nodes in (4, 8):
        cluster = Cluster(nodes, node_capacity=64 << 20, page_size=1 << 18,
                          replication_factor=1)
        sset = cluster.create_sharded_set("lineitem", recs,
                                          key_fn=lambda r: r["okey"])
        victim = nodes // 2
        shard_bytes = sset.shards[victim].num_records * REC.itemsize
        cluster.kill_node(victim)
        report = cluster.recover_node(victim)
        assert report.ok, report.checksum_failures
        mbps = report.bytes_transferred / max(report.seconds, 1e-9) / 1e6
        record(f"recovery/cluster{nodes}node", report.seconds * 1e6,
               f"shard_mb={shard_bytes/1e6:.2f};"
               f"moved_mb={report.bytes_transferred/1e6:.2f};"
               f"mb_per_s={mbps:.0f};checksums_ok={report.ok}",
               recovery_s=report.seconds,
               bytes_transferred=report.bytes_transferred,
               checksums_ok=report.ok)
        cluster.shutdown()


def run_degrade() -> None:
    """Unrecoverable loss: no replacement node, so the cluster shrinks via
    elastic remesh and re-shards every set over the survivors."""
    rng = np.random.default_rng(2)
    n = scaled(CLUSTER_N)
    recs = np.zeros(n, REC)
    recs["okey"] = rng.permutation(n)
    recs["pkey"] = rng.integers(0, 10_000, n)
    for nodes in (4, 8):
        cluster = Cluster(nodes, node_capacity=64 << 20, page_size=1 << 18,
                          replication_factor=1)
        cluster.create_sharded_set("lineitem", recs,
                                   key_fn=lambda r: r["okey"])
        cluster.kill_node(nodes // 2)
        report = cluster.remesh_degrade()
        assert report.ok, report.lost
        record(f"recovery/degrade{nodes}to{nodes-1}node",
               report.seconds * 1e6,
               f"moved_mb={report.bytes_transferred/1e6:.2f};"
               f"resharded={len(report.resharded)}",
               degrade_s=report.seconds,
               bytes_transferred=report.bytes_transferred,
               surviving_nodes=len(report.node_ids))
        cluster.shutdown()


def _records(n: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    recs = np.zeros(n, REC)
    recs["okey"] = rng.permutation(n)
    recs["pkey"] = rng.integers(0, 10_000, n)
    return recs


def _pagelog_root() -> str:
    """CI sets BENCH_PAGELOG_DIR so the logs survive the run and the fsck
    report can be uploaded as an artifact; otherwise use a temp dir."""
    root = os.environ.get("BENCH_PAGELOG_DIR")
    if root:
        os.makedirs(root, exist_ok=True)
        return root
    return tempfile.mkdtemp(prefix="bench-pagelog-")


def run_warm_recovery() -> None:
    """PR 6 headline: recover the same killed node twice — once *warm* (its
    page log survived, shards adopt from local disk and only CRC-verify) and
    once *cold* (the disk died with the machine: log wiped, every byte pulled
    from replica holders). Warm must move zero network bytes and finish
    faster than cold."""
    # keep a meaningful floor in smoke mode: the warm-vs-cold margin is the
    # per-byte difference (local disk replay vs wire copy), so too-small
    # shards would drown it in fixed revive/engine overheads; recovery
    # itself is milliseconds, so each mode runs 3 times and the median
    # counts — a single kill/recover pair is scheduler-noise territory
    recs = _records(scaled(WARM_N, floor=60_000), 11)
    root = _pagelog_root()
    results = {}
    for mode in ("cold", "warm"):
        reports, nets = [], []
        for rep in range(3):
            cluster = Cluster(
                4, node_capacity=64 << 20, page_size=1 << 16,
                replication_factor=1,
                pagelog_dir=os.path.join(root, f"warmbench-{mode}{rep}"))
            sset = cluster.create_sharded_set("lineitem", recs,
                                              key_fn=lambda r: r["okey"])
            expect = np.sort(cluster.read_sharded(sset),
                             order=["okey", "pkey"])
            victim = 2
            cluster.kill_node(victim)
            if mode == "cold":
                # the machine's disk is gone too: wipe the log first
                shutil.rmtree(cluster._node_pagelog_dir(victim),
                              ignore_errors=True)
            base_net = cluster.net_bytes
            report = cluster.recover_node(victim)
            assert report.ok, report.checksum_failures
            back = np.sort(cluster.read_sharded(sset),
                           order=["okey", "pkey"])
            assert np.array_equal(
                expect.view(np.uint8).reshape(len(expect), -1),
                back.view(np.uint8).reshape(len(back), -1))
            reports.append(report)
            nets.append(cluster.net_bytes - base_net)
            cluster.shutdown()
        reports.sort(key=lambda r: r.seconds)
        results[mode] = (reports[1], nets[0])  # median time; nets identical
    cold, warm = results["cold"], results["warm"]
    assert warm[1] == 0, f"warm recovery moved {warm[1]} net bytes"
    assert warm[0].warm_shards >= 1
    assert warm[0].seconds < cold[0].seconds, \
        f"warm {warm[0].seconds:.4f}s not faster than cold {cold[0].seconds:.4f}s"
    for mode, (report, net) in results.items():
        record(f"recovery/warm_vs_cold/{mode}", report.seconds * 1e6,
               f"net_mb={net/1e6:.2f};warm_shards={report.warm_shards};"
               f"warm_replicas={report.warm_replicas}",
               recovery_s=report.seconds, net_bytes=net,
               warm_shards=report.warm_shards,
               warm_replicas=report.warm_replicas,
               byte_identical=True)
    gain = cold[0].seconds / max(warm[0].seconds, 1e-9)
    record("recovery/warm_vs_cold/gain", warm[0].seconds * 1e6,
           f"cold_over_warm={gain:.2f}x;warm_net_bytes={warm[1]}",
           cold_over_warm=gain, warm_wins=bool(gain > 1.0))


def run_overcap_scan() -> None:
    """A dataset larger than the cluster's aggregate pool RAM written as a
    write-through sharded set: its pages overflow into the durable page logs
    (the long-lived tier, deliberately not pressure), and a full scan reads
    back byte-identically — the monolithic pool degrades to disk instead of
    failing."""
    recs = _records(scaled(OVERCAP_N, floor=40_000), 13)
    data_bytes = recs.nbytes
    nodes = 4
    # primaries + factor-1 replicas = 2x data across 4 nodes; cap each node
    # well below its 2x-data/4 share so the aggregate arena cannot hold it
    # (floor: a few pages of workspace so streaming writers can still pin)
    capacity = max(4 << 16, data_bytes // 8)
    cluster = Cluster(nodes, node_capacity=capacity, page_size=1 << 16,
                      replication_factor=1,
                      pagelog_dir=os.path.join(_pagelog_root(), "overcap"))
    sset = cluster.create_sharded_set("lineitem", recs,
                                      key_fn=lambda r: r["okey"])
    import time
    t0 = time.perf_counter()
    back = cluster.read_sharded(sset)
    scan_s = time.perf_counter() - t0
    identical = bool(np.array_equal(
        np.sort(recs, order=["okey", "pkey"])
        .view(np.uint8).reshape(len(recs), -1),
        np.sort(back, order=["okey", "pkey"])
        .view(np.uint8).reshape(len(back), -1)))
    assert identical
    log_bytes = sum(node.memory.stats["log_bytes"]
                    for node in cluster.nodes.values())
    overcommit = (2 * data_bytes) / (nodes * capacity)
    record("recovery/overcap_scan", scan_s * 1e6,
           f"data_mb={data_bytes/1e6:.1f};overcommit={overcommit:.1f}x;"
           f"log_mb={log_bytes/1e6:.1f};byte_identical={identical}",
           scan_s=scan_s, data_bytes=data_bytes,
           aggregate_capacity=nodes * capacity,
           overcommit=overcommit, log_bytes=log_bytes,
           byte_identical=identical)
    cluster.shutdown()


if __name__ == "__main__":
    run()
