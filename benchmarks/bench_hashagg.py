"""Paper Table 5: key-value aggregation — Pangea hash service (in-page
open-addressing partitions + spill/re-aggregate) vs a Python-dict baseline
(the STL-unordered-map stand-in) and a vectorized np.unique oracle."""
from __future__ import annotations

import numpy as np

from repro.core import BufferPool, HashService

from .common import record, timeit


def _pangea(keys, vals) -> None:
    pool = BufferPool(8 << 20)
    hs = HashService(pool, "agg", num_root_partitions=16, page_size=1 << 17)
    for i in range(0, len(keys), 100_000):
        hs.insert(keys[i:i + 100_000], vals[i:i + 100_000])
    hs.finalize()


def _dict_baseline(keys, vals) -> None:
    agg = {}
    for k, v in zip(keys.tolist(), vals.tolist()):
        agg[k] = agg.get(k, 0.0) + v


def _np_oracle(keys, vals) -> None:
    uk, inv = np.unique(keys, return_inverse=True)
    out = np.zeros(len(uk))
    np.add.at(out, inv, vals)


def run() -> None:
    rng = np.random.default_rng(0)
    for n in (200_000, 1_000_000):
        keys = rng.integers(0, n // 4, n)
        vals = rng.random(n)
        tp = timeit(lambda: _pangea(keys, vals))
        record(f"hashagg/pangea/n{n}", tp * 1e6, f"keys_per_s={n/tp:.0f}")
        td = timeit(lambda: _dict_baseline(keys, vals))
        record(f"hashagg/pydict/n{n}", td * 1e6,
               f"keys_per_s={n/td:.0f};pangea_speedup={td/tp:.2f}x")
        to = timeit(lambda: _np_oracle(keys, vals))
        record(f"hashagg/np_unique/n{n}", to * 1e6, f"keys_per_s={n/to:.0f}")


if __name__ == "__main__":
    run()
