"""Paper Fig. 3 / 8 / 9: page-replacement policy comparison.

Workloads (all with a working set ~4x the pool, real spill I/O counted):
  * seq   — write a write-back set sequentially, then scan it 5x
            (Fig. 8b read-after-write; LRU evicts pages about to be read)
  * seqwt — same with a write-through set (Fig. 8a)
  * shuffle — concurrent-write partitions then partition reads (Fig. 9)
  * kmeans — two sets: write-through input + write-back derived (norms),
             5 scan iterations over both (Fig. 3's workload shape)

Derived column reports spill+fetch GB moved (lower = better paging).
"""
from __future__ import annotations

import numpy as np

from repro.core import BufferPool
from repro.core.attributes import AttributeSet, DurabilityType
from repro.core.services import SequentialWriter, ShuffleService, read_all

from .common import record, timeit

PAIR = np.dtype([("key", np.int64), ("val", np.float64)])
POOL = 2 << 20
N = 500_000  # ~8 MB of records -> working set ~4x the pool (paper regime)


def _wt_attrs():
    return AttributeSet(durability=DurabilityType.WRITE_THROUGH)


def _run_seq(policy: str, write_through: bool) -> dict:
    pool = BufferPool(POOL, policy=policy)
    attrs = _wt_attrs() if write_through else None
    ls = pool.create_set("data", 1 << 16, attrs)
    w = SequentialWriter(pool, ls, PAIR)
    recs = np.zeros(N, PAIR)
    recs["key"] = np.arange(N)
    w.append_batch(recs)
    w.close()
    total = 0
    for _ in range(5):
        total += int(read_all(pool, ls, PAIR)["val"].sum())
    return pool.stats


def _run_shuffle(policy: str) -> dict:
    pool = BufferPool(POOL, policy=policy)
    sh = ShuffleService(pool, "s", 4, PAIR, page_size=1 << 17)
    recs = np.zeros(N, PAIR)
    recs["key"] = np.arange(N)
    for wid in range(4):
        sh.shuffle_batch(wid, recs[wid::4], key_fn=lambda r: r["key"])
    sh.finish_writes()
    for p in range(4):
        sh.read_partition(p)
    return pool.stats


def _run_kmeans_storage(policy: str) -> dict:
    pool = BufferPool(POOL, policy=policy)
    inp = pool.create_set("input", 1 << 16, _wt_attrs())
    w = SequentialWriter(pool, inp, PAIR)
    recs = np.zeros(N // 2, PAIR)
    recs["key"] = np.arange(N // 2)
    w.append_batch(recs)
    w.close()
    norms = pool.create_set("norms", 1 << 16)  # write-back derived data
    w2 = SequentialWriter(pool, norms, PAIR)
    w2.append_batch(recs)
    w2.close()
    for _ in range(5):
        read_all(pool, norms, PAIR)
        read_all(pool, inp, PAIR)
    return pool.stats


def _run_refresh_memo(num_sets: int, full_refresh: bool):
    """PR-5 eviction-decision cost: churn ``num_sets`` locality sets through
    a 4x-overcommitted pool. ``full_refresh=True`` simulates the pre-PR-5
    behavior (every registered set re-keyed on every ``pick_victims``);
    the memoized heap re-keys only dirtied sets, so decision cost stops
    scaling with the number of registered sets."""
    from repro.core.paging import PagingSystem
    orig_pick = PagingSystem.pick_victims
    if full_refresh:
        def old_pick(self, clock):
            self.refresh(clock)
            return orig_pick(self, clock)
        PagingSystem.pick_victims = old_pick
    try:
        pool = BufferPool(1 << 20)
        sets = [pool.create_set(f"s{i}", 1 << 12) for i in range(num_sets)]
        for _ in range(4):
            for ls in sets:
                p = pool.new_page(ls)
                pool.unpin(p, dirty=True)
        return {"evictions": pool.stats["evictions"],
                "rekeys": pool.paging.rekeys}
    finally:
        PagingSystem.pick_victims = orig_pick


def run() -> None:
    for workload, fn in (("seq_wb", lambda p: _run_seq(p, False)),
                         ("seq_wt", lambda p: _run_seq(p, True)),
                         ("shuffle", _run_shuffle),
                         ("kmeans", _run_kmeans_storage)):
        for policy in ("data-aware", "freq-aware", "lru", "mru"):
            stats = {}

            def go(policy=policy, fn=fn):
                stats.update(fn(policy))

            t = timeit(go, repeats=3)
            moved = (stats.get("spill_bytes", 0)
                     + stats.get("fetch_bytes", 0)) / 2**20
            record(f"paging/{workload}/{policy}", t * 1e6,
                   f"io_mb={moved:.1f}")

    # PR-5 heap memoization: the ROADMAP's data-aware wall-clock loss was
    # the full Eq.-1 re-key per eviction decision; show decision cost no
    # longer scaling with registered-set count
    for num_sets in (64, 256):
        runs = {}
        for mode in ("memoized", "full_refresh"):
            stats = {}

            def go(mode=mode, num_sets=num_sets):
                stats.update(_run_refresh_memo(num_sets,
                                               mode == "full_refresh"))

            runs[mode] = (timeit(go, repeats=3), dict(stats))
        (tm, sm), (tf, sf) = runs["memoized"], runs["full_refresh"]
        record(f"paging/refresh_memo/sets{num_sets}", tm * 1e6,
               f"speedup={tf/tm:.2f}x;rekeys={sm['rekeys']}"
               f";rekeys_full={sf['rekeys']}",
               seconds_memoized=tm, seconds_full_refresh=tf,
               rekeys_memoized=sm["rekeys"],
               rekeys_full_refresh=sf["rekeys"],
               evictions=sm["evictions"])


if __name__ == "__main__":
    run()
