"""Paper Fig. 3 / 8 / 9: page-replacement policy comparison.

Workloads (all with a working set ~4x the pool, real spill I/O counted):
  * seq   — write a write-back set sequentially, then scan it 5x
            (Fig. 8b read-after-write; LRU evicts pages about to be read)
  * seqwt — same with a write-through set (Fig. 8a)
  * shuffle — concurrent-write partitions then partition reads (Fig. 9)
  * kmeans — two sets: write-through input + write-back derived (norms),
             5 scan iterations over both (Fig. 3's workload shape)

Derived column reports spill+fetch GB moved (lower = better paging).
"""
from __future__ import annotations

import numpy as np

from repro.core import BufferPool
from repro.core.attributes import AttributeSet, DurabilityType
from repro.core.services import SequentialWriter, ShuffleService, read_all

from .common import record, timeit

PAIR = np.dtype([("key", np.int64), ("val", np.float64)])
POOL = 2 << 20
N = 500_000  # ~8 MB of records -> working set ~4x the pool (paper regime)


def _wt_attrs():
    return AttributeSet(durability=DurabilityType.WRITE_THROUGH)


def _run_seq(policy: str, write_through: bool) -> dict:
    pool = BufferPool(POOL, policy=policy)
    attrs = _wt_attrs() if write_through else None
    ls = pool.create_set("data", 1 << 16, attrs)
    w = SequentialWriter(pool, ls, PAIR)
    recs = np.zeros(N, PAIR)
    recs["key"] = np.arange(N)
    w.append_batch(recs)
    w.close()
    total = 0
    for _ in range(5):
        total += int(read_all(pool, ls, PAIR)["val"].sum())
    return pool.stats


def _run_shuffle(policy: str) -> dict:
    pool = BufferPool(POOL, policy=policy)
    sh = ShuffleService(pool, "s", 4, PAIR, page_size=1 << 17)
    recs = np.zeros(N, PAIR)
    recs["key"] = np.arange(N)
    for wid in range(4):
        sh.shuffle_batch(wid, recs[wid::4], key_fn=lambda r: r["key"])
    sh.finish_writes()
    for p in range(4):
        sh.read_partition(p)
    return pool.stats


def _run_kmeans_storage(policy: str) -> dict:
    pool = BufferPool(POOL, policy=policy)
    inp = pool.create_set("input", 1 << 16, _wt_attrs())
    w = SequentialWriter(pool, inp, PAIR)
    recs = np.zeros(N // 2, PAIR)
    recs["key"] = np.arange(N // 2)
    w.append_batch(recs)
    w.close()
    norms = pool.create_set("norms", 1 << 16)  # write-back derived data
    w2 = SequentialWriter(pool, norms, PAIR)
    w2.append_batch(recs)
    w2.close()
    for _ in range(5):
        read_all(pool, norms, PAIR)
        read_all(pool, inp, PAIR)
    return pool.stats


def run() -> None:
    for workload, fn in (("seq_wb", lambda p: _run_seq(p, False)),
                         ("seq_wt", lambda p: _run_seq(p, True)),
                         ("shuffle", _run_shuffle),
                         ("kmeans", _run_kmeans_storage)):
        for policy in ("data-aware", "freq-aware", "lru", "mru"):
            stats = {}

            def go(policy=policy, fn=fn):
                stats.update(fn(policy))

            t = timeit(go, repeats=3)
            moved = (stats.get("spill_bytes", 0)
                     + stats.get("fetch_bytes", 0)) / 2**20
            record(f"paging/{workload}/{policy}", t * 1e6,
                   f"io_mb={moved:.1f}")


if __name__ == "__main__":
    run()
