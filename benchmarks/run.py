"""Benchmark harness (deliverable d) — one benchmark per paper table/figure
plus the roofline summary. Prints ``name,us_per_call,derived`` CSV."""
from __future__ import annotations

import sys


def main() -> None:
    from . import (bench_hashagg, bench_kmeans, bench_paging, bench_recovery,
                   bench_replicas, bench_seqrw, bench_shuffle)
    from . import roofline

    print("name,us_per_call,derived")
    bench_paging.run()        # Fig. 3 / 8 / 9
    bench_seqrw.run()         # Fig. 6 / 7
    bench_shuffle.run()       # Table 4
    bench_hashagg.run()       # Table 5
    bench_kmeans.run()        # Fig. 2
    bench_replicas.run()      # Fig. 4
    bench_recovery.run()      # Fig. 5
    print("\n# roofline (per-device terms from the dry-run; see "
          "EXPERIMENTS.md)")
    roofline.run(write_csv=True)


if __name__ == "__main__":
    main()
