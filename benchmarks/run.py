"""Benchmark harness — one benchmark per paper table/figure plus the
roofline summary. Prints ``name,us_per_call,derived`` CSV and writes the
schema-versioned ``BENCH_cluster.json`` artifact (cluster shuffle placement,
net bytes, recovery/degrade times) so the perf trajectory accumulates across
PRs. The serving-tier rows land in their own ``BENCH_serving.json``
(written by ``benchmarks/bench_serving.py``, schema v1).

Usage::

    PYTHONPATH=src python -m benchmarks.run                  # full suite
    PYTHONPATH=src python -m benchmarks.run --suite cluster  # cluster only
    BENCH_SMOKE=1 ... python -m benchmarks.run --smoke       # CI smoke sizes
"""
from __future__ import annotations

import argparse
import os
import sys

CLUSTER_PREFIXES = ["shuffle/cluster", "recovery/cluster", "recovery/degrade",
                    "recovery/warm_vs_cold", "recovery/overcap_scan",
                    "join/cluster", "roofline/fused_partition_crc"]


def main(argv=None) -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--smoke", action="store_true",
                        help="shrink problem sizes (same as BENCH_SMOKE=1)")
    parser.add_argument("--suite", choices=["all", "cluster"], default="all",
                        help="'cluster' runs only the distributed shuffle / "
                             "recovery benchmarks behind BENCH_cluster.json")
    parser.add_argument("--json-out", default="BENCH_cluster.json",
                        help="path for the cluster results artifact")
    args = parser.parse_args(argv)
    if args.smoke:
        os.environ["BENCH_SMOKE"] = "1"

    from . import (bench_join, bench_procplane, bench_recovery,
                   bench_serving, bench_shuffle)
    from .common import write_results_json

    print("name,us_per_call,derived")
    if args.suite == "all":
        from . import (bench_hashagg, bench_kmeans, bench_paging,
                       bench_replicas, bench_seqrw)
        from . import roofline
        bench_paging.run()        # Fig. 3 / 8 / 9
        bench_seqrw.run()         # Fig. 6 / 7
        bench_shuffle.run()       # Table 4 + scheduler placement
        bench_hashagg.run()       # Table 5
        bench_join.run()          # §9.2.2 distributed join plans
        bench_kmeans.run()        # Fig. 2
        bench_replicas.run()      # Fig. 4
        bench_recovery.run()      # Fig. 5 + elastic degrade
        bench_procplane.run()     # process data plane vs in-process
        bench_serving.run()       # paged-KV serving tier -> BENCH_serving.json
        print("\n# roofline (per-device terms from the dry-run; see "
              "EXPERIMENTS.md)")
        roofline.run(write_csv=True)
        roofline.run_fused()
    else:
        from . import roofline
        bench_shuffle.run()
        bench_join.run()
        bench_recovery.run()
        bench_procplane.run()
        bench_serving.run()
        roofline.run_fused()
    write_results_json(args.json_out, prefixes=CLUSTER_PREFIXES)


if __name__ == "__main__":
    main()
