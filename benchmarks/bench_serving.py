"""Serving-tier benchmark: sustained decode throughput and p99 decode
latency under over-capacity load, admission-on vs always-grant, plus the
SIGKILL-mid-decode failover row on both backends.

The load is deliberately skewed: every sequence's session affinity hashes
to the same node, so always-grant piles the whole working set onto one
HBM page pool and thrashes its offload/restore path, while admission
control diverts refused prefills to idle nodes and keeps decode tails
resident.

Writes ``BENCH_serving.json`` — its own artifact with its own schema
(v1), separate from ``BENCH_cluster.json``.

Usage::

    PYTHONPATH=src python -m benchmarks.bench_serving            # full
    PYTHONPATH=src python -m benchmarks.bench_serving --smoke    # CI sizes
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import List

import numpy as np

from .common import record, smoke_mode

# v1: overcap rows (serving/cluster4node/overcap/{admission_on,always_grant,
# admission_gain}: p99 decode latency ms + sustained tokens/s, min-of-3) and
# failover rows (serving/cluster4node/failover/sigkill/{inproc,proc}:
# SIGKILL mid-decode, session resumes on the replica byte-identically)
SCHEMA_VERSION = 1

_ROWS: List[dict] = []

# big enough that one slab is a meaningful charge against a small node
GEOM = dict(num_layers=4, page_tokens=8, kv_heads=4, head_dim=16)


def _row(name: str, us_per_call: float, derived: str = "", **metrics) -> None:
    _ROWS.append({"name": name, "us_per_call": us_per_call,
                  "derived": derived, **metrics})
    record(name, us_per_call, derived, **metrics)


def _mk_cluster(backend: str, **kw):
    from repro.runtime.cluster import Cluster
    kw.setdefault("node_capacity", 8 << 20)
    kw.setdefault("page_size", 1 << 14)
    kw.setdefault("replication_factor", 1)
    kw.setdefault("admission", True)
    if backend == "proc":
        return Cluster(4, backend="proc", **kw)
    return Cluster(4, **kw)


def _teardown(cluster, backend: str) -> None:
    if backend == "proc":
        cluster.close()
    else:
        cluster.shutdown()


def _skewed_ids(tier, n: int) -> List[int]:
    """n sequence ids whose session affinity all lands on one node."""
    hot = tier._affinity(0)
    ids, s = [], 0
    while len(ids) < n:
        if tier._affinity(s) == hot:
            ids.append(s)
        s += 1
    return ids


def _overcap_once(admission: bool, n: int, steps: int, hbm: int, cap: int):
    """One over-capacity run: admit n skewed sequences, decode steps rounds,
    return (p99 decode-step latency seconds, sustained tokens/s, diversions).

    cap is sized so host-slab charges trip the hot node's watermark after
    ~n/4 sequences: admission then diverts the rest and every shard's
    decode tails fit in HBM, while always-grant restores a tail page from
    host memory on nearly every step."""
    from repro.runtime.serving import ServingTier
    # timeout 0: required-urgency grants force through immediately instead
    # of parking on the saturated node — the bench measures spill thrash,
    # not the configurable backpressure sleep
    cluster = _mk_cluster("inproc", node_capacity=cap,
                          pressure_watermark=0.5, admission=admission,
                          admission_timeout_s=0.0)
    tier = ServingTier(cluster, hbm_pages_per_node=hbm, host_budget_bytes=None,
                       replicate=False, **GEOM)
    try:
        ids = _skewed_ids(tier, n)
        # sequences arrive one at a time (continuous batching): each probe
        # sees the charges of every prefill already admitted
        diversions = 0
        for sid in ids:
            plan = tier.admit({sid: 2 * GEOM["page_tokens"]})
            diversions += len(plan.diversions)
        import gc

        import jax
        lat = []
        # GC pauses are common-mode noise several ms wide — exactly the
        # scale of the p99 signal under measurement
        gc.disable()
        try:
            t0 = time.perf_counter()
            for _ in range(steps):
                for sid in ids:
                    s0 = time.perf_counter()
                    tier.decode([sid], steps=1)
                    # steps must pay for their own device work: without the
                    # block, async dispatch shifts restore costs onto
                    # whichever step reads next and the percentiles lie
                    jax.block_until_ready(
                        tier._shards[tier.sessions[sid].node].cache.kv)
                    lat.append(time.perf_counter() - s0)
            total = time.perf_counter() - t0
        finally:
            gc.enable()
            gc.collect()
        for sid in ids:
            tier.finish(sid)
        return (float(np.percentile(lat, 99)), n * steps / total, diversions)
    finally:
        tier.close()
        _teardown(cluster, "inproc")


def _bench_overcap() -> None:
    n = 8 if smoke_mode() else 24
    steps = 16 if smoke_mode() else 32
    reps = 3
    hbm = 4 if smoke_mode() else 8
    cap = (64 << 10) if smoke_mode() else (160 << 10)
    results = {}
    for label, admission in (("always_grant", False), ("admission_on", True)):
        p99s, tputs, divs = [], [], []
        for _ in range(reps):
            p99, tput, div = _overcap_once(admission, n, steps, hbm, cap)
            p99s.append(p99)
            tputs.append(tput)
            divs.append(div)
        p99, tput = min(p99s), max(tputs)      # min-of-N wall clock
        results[label] = p99
        _row(f"serving/cluster4node/overcap/{label}", p99 * 1e6,
             f"{tput:.0f} tok/s",
             p99_decode_ms=p99 * 1e3, throughput_tok_s=tput,
             sequences=n, decode_steps=steps, diversions=max(divs))
    gain = results["always_grant"] / max(results["admission_on"], 1e-12)
    _row("serving/cluster4node/overcap/admission_gain",
         results["admission_on"] * 1e6, f"{gain:.2f}x p99",
         p99_speedup=gain,
         admission_wins=bool(results["admission_on"]
                             < results["always_grant"]))


def _failover_once(backend: str):
    """SIGKILL the primary mid-decode; the session must resume on its
    replica byte-identically. Returns (recovery seconds, byte_identical)."""
    from repro.runtime.serving import ServingTier
    cluster = _mk_cluster(backend)
    tier = ServingTier(cluster, hbm_pages_per_node=8, **GEOM)
    try:
        tier.admit({1: 2 * GEOM["page_tokens"]})
        tier.decode([1], steps=4)
        pre = [s.copy() for s in tier.sequence_slabs(1)]
        pre_len = tier.sessions[1].length
        t0 = time.perf_counter()
        cluster.kill_node(tier.sessions[1].node)   # SIGKILL on proc
        tier.decode([1], steps=4)
        recovery = time.perf_counter() - t0
        now = tier.sequence_slabs(1)
        full = pre_len // tier.page_tokens
        ok = (tier.verify(1) and tier.stats["failovers"] >= 1
              and all(now[k].tobytes() == pre[k].tobytes()
                      for k in range(full)))
        tier.finish(1)
        return recovery, ok
    finally:
        tier.close()
        _teardown(cluster, backend)


def _bench_failover() -> None:
    for backend in ("inproc", "proc"):
        recovery, ok = _failover_once(backend)
        _row(f"serving/cluster4node/failover/sigkill/{backend}",
             recovery * 1e6,
             "byte_identical" if ok else "DIVERGED",
             byte_identical=ok, recovery_s=recovery)


def write_results_json(path: str = "BENCH_serving.json") -> dict:
    doc = {
        "schema_version": SCHEMA_VERSION,
        "generated_by": "benchmarks/bench_serving.py",
        "smoke": smoke_mode(),
        "results": _ROWS,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    print(f"# wrote {path} ({len(_ROWS)} rows, schema v{SCHEMA_VERSION})")
    return doc


def run(json_out: str = "BENCH_serving.json") -> None:
    _bench_overcap()
    _bench_failover()
    write_results_json(json_out)


def main(argv=None) -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--smoke", action="store_true",
                        help="shrink problem sizes (same as BENCH_SMOKE=1)")
    parser.add_argument("--json-out", default="BENCH_serving.json")
    args = parser.parse_args(argv)
    if args.smoke:
        os.environ["BENCH_SMOKE"] = "1"
    print("name,us_per_call,derived")
    run(args.json_out)


if __name__ == "__main__":
    main()
