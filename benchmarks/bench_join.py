"""Paper §9.2.2: the distributed equi-join under the three scheduler plans —
co-partitioned (shuffle elided outright, net_bytes == 0), one side shuffled
(only the non-co side moves, routed by the co side's storage scheme), and
both sides shuffled (the layered-stack worst case the monolithic design
avoids). Keys are zipf-skewed, which is what makes the byte accounting
interesting: hot keys concentrate matching rows, so "which side moves"
dominates the wire cost.

Runnable standalone (the CI docs job does)::

    PYTHONPATH=src python -m benchmarks.bench_join --smoke
"""
from __future__ import annotations

import numpy as np

from repro.data.pipeline import cluster_join
from repro.runtime.cluster import Cluster

from .common import record, scaled, timeit

BUILD = np.dtype([("key", np.int64), ("rid", np.int64), ("bval", np.float64)])
PROBE = np.dtype([("key", np.int64), ("rid", np.int64), ("pval", np.float64)])
NODES = 4

# mode -> the partition field each side is staged on ("key" = co-partitioned)
MODES = {
    "copartitioned": ("key", "key"),
    "one_side_shuffled": ("key", "rid"),
    "both_shuffled": ("rid", "rid"),
}


def _sides(nb: int, np_: int, seed: int = 0):
    """Star-join shape: the build side is a dimension table (unique keys),
    the probe side a zipf-skewed fact table over twice that key range (half
    the probes miss), so output size stays O(probe) while the hot keys still
    concentrate bytes on single nodes."""
    rng = np.random.default_rng(seed)
    key_range = nb * 2
    build = np.zeros(nb, BUILD)
    build["key"] = rng.permutation(key_range)[:nb]
    build["rid"] = np.arange(nb)
    build["bval"] = rng.random(nb)
    probe = np.zeros(np_, PROBE)
    probe["key"] = rng.zipf(1.3, np_).astype(np.int64) % key_range
    probe["rid"] = np.arange(np_)
    probe["pval"] = rng.random(np_)
    return build, probe


def _run_mode(mode: str, build: np.ndarray, probe: np.ndarray):
    bfield, pfield = MODES[mode]
    cluster = Cluster(NODES, node_capacity=64 << 20, page_size=1 << 17,
                      replication_factor=0)
    out, report = cluster_join(
        cluster, f"bench.{mode}", build, probe, "key",
        build_partition_field=bfield, probe_partition_field=pfield)
    cluster.shutdown()
    return {"net_bytes": report.net_bytes,
            "shuffled_bytes": sum(report.shuffled_bytes.values()),
            "output_rows": len(out),
            "shuffle_sides": len(report.plan.shuffle_sides)}


def run() -> None:
    for np_ in (scaled(60_000), scaled(240_000)):
        nb = np_ // 4
        build, probe = _sides(nb, np_)
        n = nb + np_
        stats = {}
        for mode in MODES:
            last = []
            t = timeit(lambda: last.append(_run_mode(mode, build, probe)))
            s = last[-1]
            stats[mode] = (t, s)
            record(f"join/cluster{NODES}node/{mode}/n{n}", t * 1e6,
                   f"recs_per_s={n/t:.0f};net_mb={s['net_bytes']/1e6:.2f};"
                   f"rows={s['output_rows']}",
                   recs_per_s=n / t, mode=mode, **s)
        (tc, sc) = stats["copartitioned"]
        (t1, s1) = stats["one_side_shuffled"]
        (t2, s2) = stats["both_shuffled"]
        record(f"join/cluster{NODES}node/movement_gain/n{n}", 0.0,
               f"co_net={sc['net_bytes']};"
               f"one_side_ratio={s1['net_bytes']/max(1, s2['net_bytes']):.3f}",
               net_bytes_copartitioned=sc["net_bytes"],
               net_bytes_one_side=s1["net_bytes"],
               net_bytes_both=s2["net_bytes"],
               copartitioned_is_free=bool(sc["net_bytes"] == 0),
               seconds_copartitioned=tc, seconds_one_side=t1,
               seconds_both=t2)


def main(argv=None) -> None:
    import argparse
    import json
    import os
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--smoke", action="store_true",
                        help="shrink problem sizes (same as BENCH_SMOKE=1)")
    parser.add_argument("--json-out", default="BENCH_cluster.json",
                        help="cluster artifact to refresh the join rows in")
    args = parser.parse_args(argv)
    if args.smoke:
        os.environ["BENCH_SMOKE"] = "1"
    from .common import ROWS, SCHEMA_VERSION, smoke_mode
    print("name,us_per_call,derived")
    run()
    # refresh ONLY the join rows inside the shared cluster artifact — the
    # shuffle/recovery trajectory other suites accumulated must survive a
    # standalone join run (the CI docs job runs exactly this)
    doc = {"schema_version": SCHEMA_VERSION,
           "generated_by": "benchmarks/run.py", "smoke": smoke_mode(),
           "results": []}
    if os.path.exists(args.json_out):
        with open(args.json_out) as f:
            old = json.load(f)
        doc["smoke"] = old.get("smoke", doc["smoke"])
        doc["results"] = [r for r in old.get("results", [])
                          if not r["name"].startswith("join/cluster")]
    doc["results"] += [r for r in ROWS
                       if r["name"].startswith("join/cluster")]
    with open(args.json_out, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    print(f"# refreshed join rows in {args.json_out} "
          f"({len(doc['results'])} rows, schema v{SCHEMA_VERSION})")


if __name__ == "__main__":
    main()
