"""Paper Fig. 6 / 7: sequential read/write for transient + persistent data.

Pangea path: buffer-pool locality sets (write-back = Fig. 6 transient,
write-through = Fig. 7 persistent), real file spill store.
Baseline ("OS-like"): plain per-record numpy allocation with whole-file
write/read via numpy save — the copy-through-every-layer strawman the paper
measures against.
"""
from __future__ import annotations

import os
import tempfile

import numpy as np

from repro.core import BufferPool, SpillStore
from repro.core.attributes import AttributeSet, DurabilityType
from repro.core.services import SequentialWriter, read_all

from .common import record, timeit

REC = np.dtype([("payload", np.uint8, (80,))])  # paper: 80-byte objects
N = 60_000
POOL = 2 << 20  # working set ~4.8MB > pool


def _pangea(write_through: bool, tmp: str) -> None:
    pool = BufferPool(POOL, SpillStore(directory=tmp))
    attrs = (AttributeSet(durability=DurabilityType.WRITE_THROUGH)
             if write_through else None)
    ls = pool.create_set("objs", 1 << 16, attrs)
    w = SequentialWriter(pool, ls, REC)
    data = np.zeros(N, REC)
    data["payload"][:] = np.arange(80, dtype=np.uint8)
    w.append_batch(data)
    w.close()
    for _ in range(5):
        out = read_all(pool, ls, REC)
        out["payload"].sum()


def _baseline(tmp: str) -> None:
    # allocate record-by-record batches, persist whole array, re-read per scan
    chunks = [np.zeros(1000, REC) for _ in range(N // 1000)]
    path = os.path.join(tmp, "objs.npy")
    np.save(path, np.concatenate(chunks))
    for _ in range(5):
        arr = np.load(path)
        arr["payload"].sum()


def run() -> None:
    with tempfile.TemporaryDirectory() as tmp:
        t = timeit(lambda: _pangea(False, tmp))
        record("seqrw/transient/pangea", t * 1e6,
               f"objs_per_s={5*N/t:.0f}")
    with tempfile.TemporaryDirectory() as tmp:
        t = timeit(lambda: _pangea(True, tmp))
        record("seqrw/persistent/pangea", t * 1e6,
               f"objs_per_s={5*N/t:.0f}")
    with tempfile.TemporaryDirectory() as tmp:
        t = timeit(lambda: _baseline(tmp))
        record("seqrw/persistent/baseline_fullfile", t * 1e6,
               f"objs_per_s={5*N/t:.0f}")


if __name__ == "__main__":
    run()
