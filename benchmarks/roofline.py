"""Roofline analysis (deliverable g) — reads the dry-run records and derives
the three per-device roofline terms for every (arch × shape × mesh) cell.

  compute_term_s   = HLO dot FLOPs / 197e12   (bf16 MXU peak per chip)
  memory_term_s    = HLO HBM bytes / 819e9    (fusion-boundary traffic model,
                     trip-count-scaled; see launch/hlo_analysis.py)
  collective_term_s= (2*AR + AG + RS + A2A + CP bytes) / 50e9
                     (ring cost: all-reduce moves ~2x its payload; the
                     (n-1)/n factor ~0.94 at 16-way is folded in as 1)

plus MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*D (prefill) /
2*N_active*B (decode step) and the usefulness ratio MODEL/HLO flops per
device. An analytic HBM floor (params+opt+activation boundaries+KV) is
reported alongside the HLO-derived traffic so over-materialization shows up
as the gap between the two.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12     # bf16 / chip (TPU v5e-class target)
HBM_BW = 819e9          # B/s
LINK_BW = 50e9          # B/s per ICI link

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "dryrun")
OUT_CSV = os.path.join(os.path.dirname(__file__), "..", "results",
                       "roofline.csv")

DTYPE_BYTES = {"float32": 4, "bfloat16": 2}


def _chips(mesh: str) -> int:
    out = 1
    for p in mesh.split("x"):
        out *= int(p)
    return out


_ACTIVE_CACHE: Dict[str, int] = {}


def _active(arch: str) -> int:
    if arch not in _ACTIVE_CACHE:
        from repro.configs import get_config
        from repro.models.model import active_params
        _ACTIVE_CACHE[arch] = active_params(get_config(arch))
    return _ACTIVE_CACHE[arch]


def model_flops_global(rec: Dict, cfg=None) -> float:
    """6*N_active*D (train), 2*N_active*D (prefill), 2*N_active*B (decode)."""
    n = _active(rec["arch"])  # recomputed (records may predate count fixes)
    kind = rec["kind"]
    from repro.configs.base import ALL_SHAPES
    shape = next(s for s in ALL_SHAPES if s.name == rec["shape"])
    tokens = shape.global_batch * shape.seq_len
    if kind == "train":
        return 6.0 * n * tokens
    if kind == "prefill":
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # one decode step


def analytic_hbm_floor(rec: Dict) -> float:
    """Per-device lower bound on HBM traffic for the step."""
    from repro.configs import get_config
    from repro.configs.base import ALL_SHAPES
    from repro.models.model import count_params
    cfg = get_config(rec["arch"])
    shape = next(s for s in ALL_SHAPES if s.name == rec["shape"])
    chips = _chips(rec["mesh"])
    P = count_params(cfg)
    cb = DTYPE_BYTES[cfg.compute_dtype]
    ob = DTYPE_BYTES[cfg.opt_state_dtype]
    if rec["kind"] == "train":
        # weights: fwd+bwd+remat reads (3x, compute dtype) + opt: p r/w f32,
        # m/v r/w, grad read f32
        w = P * (3 * cb + 2 * 4 + 4 * ob + 4)
        # activation layer boundaries: save + 2 reads
        acts = (cfg.n_layers * shape.global_batch * shape.seq_len
                * cfg.d_model * cb * 3)
        logits = (shape.global_batch * shape.seq_len * cfg.vocab * cb * 3)
        return (w + acts + logits) / chips
    if rec["kind"] == "prefill":
        w = P * cb
        acts = (cfg.n_layers * shape.global_batch * shape.seq_len
                * cfg.d_model * cb * 2)
        kv = rec.get("memory", {}).get("output_bytes", 0)
        return (w + acts) / chips + kv
    # decode: weights + whole KV cache read once
    w = P * cb
    kv_bytes = rec.get("memory", {}).get("argument_bytes", 0)
    return w / chips + kv_bytes * 0.5  # ~half the args are the cache


def terms(rec: Dict) -> Dict[str, float]:
    hlo = rec["hlo"]
    cb = hlo.get("collective_bytes", {})
    ar = cb.get("all-reduce", 0.0)
    others = sum(v for k, v in cb.items() if k != "all-reduce")
    return {
        "compute_s": hlo["dot_flops"] / PEAK_FLOPS,
        "memory_s": hlo["hbm_bytes"] / HBM_BW,
        "collective_s": (2 * ar + others) / LINK_BW,
    }


ADVICE = {
    "compute": "compute-bound: raise MXU utilization (bigger tiles, bf16 "
               "everywhere) or shard more model dims",
    "memory": "HBM-bound: cut materialization (fused kernels, tighter remat "
              "policy, smaller logits dtype) or up arithmetic intensity",
    "collective": "collective-bound: reshard to remove TP all-reduces "
                  "(pure-DP / sequence-parallel / 2D), overlap with compute, "
                  "or compress",
}


def analyze(pattern: str = "*.json") -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, pattern))):
        rec = json.load(open(path))
        if rec.get("status") != "ok":
            continue
        t = terms(rec)
        dom = max(t, key=t.get).replace("_s", "")
        mf = model_flops_global(rec) / _chips(rec["mesh"])
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            "kind": rec["kind"], **{k: round(v, 4) for k, v in t.items()},
            "dominant": dom,
            "model_tflops_dev": round(mf / 1e12, 3),
            "useful_ratio": round(mf / max(rec["hlo"]["dot_flops"], 1), 3),
            "hbm_floor_s": round(analytic_hbm_floor(rec) / HBM_BW, 4),
            "mem_per_dev_gib": round(rec.get("memory", {}).get(
                "per_device_total", 0) / 2**30, 2),
            "step_s_bound": round(max(t.values()), 4),
            "roofline_frac": round(
                (mf / PEAK_FLOPS) / max(max(t.values()), 1e-12), 4),
            "advice": ADVICE[dom],
            "file": os.path.basename(path),
        })
    return rows


def _is_variant(row: Dict) -> bool:
    """Tagged records (hillclimb variants) vs the plain baselines."""
    base = f"{row['arch']}_{row['shape']}_{row['mesh']}.json"
    return row["file"] != base


def run_fused(n: int = 20000, num_partitions: int = 4) -> Dict:
    """Host roofline for the fused hash-partition + incremental-CRC pass
    (PR 7) — the cluster shuffle's map-side kernel, measured against this
    machine's memory-bound ceiling rather than the TPU terms above.

    Traffic model (bytes per record, every array pass counted once —
    the kernel is a chain of streaming passes, so its floor is the time
    those bytes take at memcpy speed):

      hash:    key read + hash write + 2 in-place mix passes  8+8+2*16 = 48
      narrow:  hash read -> uint8 partition-id write           8+1     =  9
      plan:    stable radix argsort (2 counting reads + int64
               order write) + bincount read                    10+1    = 11
      gather:  order read + column read + landed write         8+2*w
      crc:     landed bytes read                               w

    with ``w`` the record width. The ceiling is measured, not assumed: a
    straight ``np.copyto`` of a pool-sized buffer gives this host's
    streaming bandwidth. ``roofline_frac = (bytes/bw) / t_kernel``."""
    import time

    import numpy as np

    from repro.core.columnar import fused_partition_crc
    from repro.runtime.cluster import dispatch_impl, partition_crc_impl

    from .common import record

    rec_dtype = np.dtype([("key", np.int64), ("payload", np.uint8, (10,))])
    w = rec_dtype.itemsize
    rng = np.random.default_rng(0)
    keys = rng.zipf(1.3, n).astype(np.int64)
    cols = {"key": keys,
            "payload": rng.integers(0, 255, (n, 10)).astype(np.uint8)}

    def best(fn, reps):
        fn(); fn()
        times = []
        for _ in range(reps):
            t0 = time.perf_counter()
            fn()
            times.append(time.perf_counter() - t0)
        return min(times)

    t_kernel = best(lambda: fused_partition_crc(keys, cols, rec_dtype,
                                                num_partitions), reps=30)
    src = np.empty(32 << 20, np.uint8)
    src[:] = 7
    dst = np.empty_like(src)
    bw = len(src) / best(lambda: np.copyto(dst, src), reps=10)
    bytes_per_rec = 48 + 9 + 11 + (8 + 2 * w) + w
    moved = n * bytes_per_rec
    achieved = moved / t_kernel
    frac = (moved / bw) / t_kernel
    row = {"n": n, "bytes_per_record": bytes_per_rec,
           "achieved_gbps": achieved / 1e9, "ceiling_gbps": bw / 1e9,
           "roofline_frac": frac, "kernel_us": t_kernel * 1e6,
           "dispatch_impl": dispatch_impl(),
           "partition_crc_impl": partition_crc_impl()}
    record("roofline/fused_partition_crc", t_kernel * 1e6,
           f"achieved_gbps={achieved/1e9:.2f};ceiling_gbps={bw/1e9:.2f};"
           f"frac={frac:.3f}", **row)
    return row


def run(write_csv: bool = True) -> List[Dict]:
    rows = analyze()
    if not rows:
        print("roofline: no dry-run records found (run repro.launch.dryrun)")
        return rows
    cols = ["arch", "shape", "mesh", "compute_s", "memory_s", "collective_s",
            "dominant", "model_tflops_dev", "useful_ratio", "hbm_floor_s",
            "mem_per_dev_gib", "roofline_frac"]
    base_rows = [r for r in rows if not _is_variant(r)]
    var_rows = [r for r in rows if _is_variant(r)]
    if write_csv:
        os.makedirs(os.path.dirname(OUT_CSV), exist_ok=True)
        with open(OUT_CSV, "w") as f:
            f.write(",".join(cols + ["variant"]) + "\n")
            for r in rows:
                tag = (r["file"].rsplit(".", 1)[0]
                       .replace(f"{r['arch']}_{r['shape']}_{r['mesh']}", "")
                       .lstrip("_") or "baseline")
                f.write(",".join(str(r[c]) for c in cols) + f",{tag}\n")
    print(",".join(cols))
    for r in base_rows:
        print(",".join(str(r[c]) for c in cols))
    if var_rows:
        print("# hillclimb variants (EXPERIMENTS.md §Perf):")
        for r in var_rows:
            tag = (r["file"].rsplit(".", 1)[0]
                   .replace(f"{r['arch']}_{r['shape']}_{r['mesh']}", "")
                   .lstrip("_"))
            print(",".join(str(r[c]) for c in cols) + f",{tag}")
    return rows


if __name__ == "__main__":
    run()
