"""Paper Fig. 4 (TPC-H co-partitioning): join latency using a co-partitioned
heterogeneous replica (query optimizer picks it from the statistics catalog
→ node-local joins, no shuffle) vs the random-placement source sets (full
re-shuffle of both sides before the join)."""
from __future__ import annotations

import numpy as np

from repro.core import (PartitionScheme, StatisticsDB, partition_set,
                        random_dispatch, register_replica)

from .common import record, timeit

LINEITEM = np.dtype([("okey", np.int64), ("pkey", np.int64),
                     ("qty", np.float64)])
ORDERS = np.dtype([("okey", np.int64), ("ckey", np.int64)])
NODES = 10


def _tables(n_li=400_000, n_ord=100_000):
    rng = np.random.default_rng(0)
    li = np.zeros(n_li, LINEITEM)
    li["okey"] = rng.integers(0, n_ord, n_li)
    li["pkey"] = rng.integers(0, 20_000, n_li)
    li["qty"] = rng.random(n_li)
    orders = np.zeros(n_ord, ORDERS)
    orders["okey"] = np.arange(n_ord)
    orders["ckey"] = rng.integers(0, 5_000, n_ord)
    return li, orders


def _local_join(li_shard, ord_shard) -> float:
    """Node-local hash join on okey; returns aggregated qty."""
    idx = {}
    for k in ord_shard["okey"].tolist():
        idx[k] = True
    mask = np.fromiter((k in idx for k in li_shard["okey"].tolist()),
                       bool, len(li_shard))
    return float(li_shard["qty"][mask].sum())


def run() -> None:
    li, orders = _tables()
    li_src = random_dispatch("lineitem", li, NODES, seed=1)
    ord_src = random_dispatch("orders", orders, NODES, seed=2)
    stats = StatisticsDB()
    scheme_li = PartitionScheme("okey", lambda r: r["okey"], 100, NODES)
    scheme_ord = PartitionScheme("okey", lambda r: r["okey"], 100, NODES)
    li_pt = partition_set(li_src, "lineitem_okey", scheme_li)
    ord_pt = partition_set(ord_src, "orders_okey", scheme_ord)
    register_replica(li_src, li_pt, scheme_li, stats, "lineitem")
    register_replica(ord_src, ord_pt, scheme_ord, stats, "orders")

    def copartitioned():
        # optimizer consults the catalog, finds matching partitionings
        best_li = stats.best_replica("lineitem", "okey")
        best_ord = stats.best_replica("orders", "okey")
        assert best_li.partition_key == best_ord.partition_key == "okey"
        return sum(_local_join(li_pt.shards[n], ord_pt.shards[n])
                   for n in range(NODES))

    def shuffled():
        # no usable replica: re-partition BOTH sides at query time (the
        # Spark repartition+partitionBy path), then join locally
        li2 = partition_set(li_src, "tmp_li", scheme_li)
        ord2 = partition_set(ord_src, "tmp_ord", scheme_ord)
        return sum(_local_join(li2.shards[n], ord2.shards[n])
                   for n in range(NODES))

    a = copartitioned()
    b = shuffled()
    assert abs(a - b) < 1e-6 * max(abs(a), 1)
    tc = timeit(copartitioned)
    ts = timeit(shuffled)
    record("replicas/join_copartitioned", tc * 1e6, "")
    record("replicas/join_shuffle", ts * 1e6, f"speedup={ts/tc:.2f}x")
    run_cluster()


def run_cluster(n: int = 200_000) -> None:
    """Replication cost through real pools: write amplification and network
    bytes of chain-replicating every shard at factor 0/1/2 on a 4-node
    cluster (factor >= 1 is what buys kill-one-node recovery)."""
    from repro.runtime.cluster import Cluster

    rng = np.random.default_rng(2)
    recs = np.zeros(n, LINEITEM)
    recs["okey"] = rng.integers(0, n, n)
    recs["pkey"] = rng.integers(0, 20_000, n)
    recs["qty"] = rng.random(n)
    for factor in (0, 1, 2):
        last = []

        def write():
            cluster = Cluster(4, node_capacity=64 << 20, page_size=1 << 18,
                              replication_factor=factor)
            cluster.create_sharded_set("li", recs,
                                       key_fn=lambda r: r["okey"])
            last.append(cluster)

        t = timeit(write)
        record(f"replicas/cluster_write_rf{factor}", t * 1e6,
               f"mb_per_s={recs.nbytes/t/1e6:.0f};"
               f"net_mb={last[-1].net_bytes/1e6:.2f}")


if __name__ == "__main__":
    run()
