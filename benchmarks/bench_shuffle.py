"""Paper Table 4: shuffle write/read — Pangea shuffle service (one locality
set per partition, virtual shuffle buffers) vs the Spark-like baseline
(numWorkers × numPartitions separate spill buffers, concatenated at read),
plus the distributed shuffle through a real N-node cluster of buffer pools
(map-side job-data pages, reducer pull over the node-to-node path)."""
from __future__ import annotations

import numpy as np

from repro.core import BufferPool
from repro.core.services import ShuffleService
from repro.runtime.cluster import Cluster, ClusterShuffle

from .common import record, timeit

REC = np.dtype([("key", np.int64), ("payload", np.uint8, (10,))])
WORKERS, PARTS = 4, 4
NODES = 4


def _pangea(n: int) -> None:
    pool = BufferPool(8 << 20)
    sh = ShuffleService(pool, "s", PARTS, REC, page_size=1 << 18)
    rng = np.random.default_rng(0)
    recs = np.zeros(n, REC)
    recs["key"] = rng.integers(0, 1 << 40, n)
    for wid in range(WORKERS):
        sh.shuffle_batch(wid, recs[wid::WORKERS], key_fn=lambda r: r["key"])
    sh.finish_writes()
    for p in range(PARTS):
        part = sh.read_partition(p)
        part["payload"].sum()


def _sparklike(n: int) -> None:
    """Each (worker, partition) writes its own spill file (the Spark
    numCores x numPartitions model: allocate on heap, serialize to file);
    reading a partition re-reads and concatenates WORKERS files."""
    import tempfile
    import os
    rng = np.random.default_rng(0)
    recs = np.zeros(n, REC)
    recs["key"] = rng.integers(0, 1 << 40, n)
    with tempfile.TemporaryDirectory() as tmp:
        for w in range(WORKERS):
            mine = recs[w::WORKERS]
            parts = mine["key"] % PARTS
            for p in range(PARTS):
                sel = mine[parts == p]
                chunks = [sel[i:i + 512].copy()          # heap alloc
                          for i in range(0, len(sel), 512)]
                with open(os.path.join(tmp, f"{w}_{p}.bin"), "wb") as f:
                    for c in chunks:                      # serialize
                        f.write(c.tobytes())
        for p in range(PARTS):
            streams = []
            for w in range(WORKERS):
                with open(os.path.join(tmp, f"{w}_{p}.bin"), "rb") as f:
                    streams.append(np.frombuffer(f.read(), REC))
            part = np.concatenate(streams)
            part["payload"].sum()


def _cluster_shuffle(n: int) -> Cluster:
    """End-to-end distributed shuffle on a real 4-node cluster: shard the
    records, map-side partition into each node's local pool, reducers pull
    every partition across the transfer path."""
    cluster = Cluster(NODES, node_capacity=64 << 20, page_size=1 << 18)
    rng = np.random.default_rng(0)
    recs = np.zeros(n, REC)
    recs["key"] = rng.integers(0, 1 << 40, n)
    sset = cluster.create_sharded_set("src", recs, key_fn=lambda r: r["key"])
    sh = ClusterShuffle(cluster, "sh", num_reducers=NODES, dtype=REC)
    sh.map_sharded(sset, key_fn=lambda r: r["key"])
    sh.finish_maps()
    for r in range(NODES):
        part = sh.pull(r)
        part["payload"].sum()
        sh.release_reducer(r)
    return cluster


def run() -> None:
    for n in (100_000, 400_000):
        tp = timeit(lambda: _pangea(n))
        tb = timeit(lambda: _sparklike(n))
        record(f"shuffle/pangea/n{n}", tp * 1e6,
               f"recs_per_s={n/tp:.0f}")
        record(f"shuffle/sparklike/n{n}", tb * 1e6,
               f"recs_per_s={n/tb:.0f};speedup={tb/tp:.2f}x")
        last = []
        tc = timeit(lambda: last.append(_cluster_shuffle(n)))
        record(f"shuffle/cluster{NODES}node/n{n}", tc * 1e6,
               f"recs_per_s={n/tc:.0f};net_mb={last[-1].net_bytes/1e6:.2f}")


if __name__ == "__main__":
    run()
