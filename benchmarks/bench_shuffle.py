"""Paper Table 4: shuffle write/read — Pangea shuffle service (one locality
set per partition, virtual shuffle buffers) vs the Spark-like baseline
(numWorkers × numPartitions separate spill buffers, concatenated at read),
plus the distributed shuffle through a real N-node cluster of buffer pools:
the ``r % N`` reducer-placement baseline vs the scheduler's locality-aware
placement (reducer on the byte-heaviest map node, overlapped async pulls),
and the co-partitioned aggregation that elides the shuffle entirely
(net_bytes == 0). The over-capacity configuration (pool < map output) drives
cross-node shuffle spill through the per-node MemoryManagers and compares the
paper's data-aware eviction against global LRU (spill bytes, page faults,
wall time)."""
from __future__ import annotations

import time

import numpy as np

from repro.core import BufferPool
from repro.core.replication import record_content_checksum
from repro.core.services import ShuffleService, columnar_job_data_attrs
from repro.runtime.cluster import (Cluster, ClusterShuffle,
                                   cluster_hash_aggregate)

from .common import record, scaled, timeit

REC = np.dtype([("key", np.int64), ("payload", np.uint8, (10,))])
PAIR = np.dtype([("key", np.int64), ("val", np.float64)])
WORKERS, PARTS = 4, 4
NODES = 4


def _pangea(n: int) -> None:
    pool = BufferPool(8 << 20)
    sh = ShuffleService(pool, "s", PARTS, REC, page_size=1 << 18)
    rng = np.random.default_rng(0)
    recs = np.zeros(n, REC)
    recs["key"] = rng.integers(0, 1 << 40, n)
    for wid in range(WORKERS):
        sh.shuffle_batch(wid, recs[wid::WORKERS], key_fn=lambda r: r["key"])
    sh.finish_writes()
    for p in range(PARTS):
        part = sh.read_partition(p)
        part["payload"].sum()


def _sparklike(n: int) -> None:
    """Each (worker, partition) writes its own spill file (the Spark
    numCores x numPartitions model: allocate on heap, serialize to file);
    reading a partition re-reads and concatenates WORKERS files."""
    import tempfile
    import os
    rng = np.random.default_rng(0)
    recs = np.zeros(n, REC)
    recs["key"] = rng.integers(0, 1 << 40, n)
    with tempfile.TemporaryDirectory() as tmp:
        for w in range(WORKERS):
            mine = recs[w::WORKERS]
            parts = mine["key"] % PARTS
            for p in range(PARTS):
                sel = mine[parts == p]
                chunks = [sel[i:i + 512].copy()          # heap alloc
                          for i in range(0, len(sel), 512)]
                with open(os.path.join(tmp, f"{w}_{p}.bin"), "wb") as f:
                    for c in chunks:                      # serialize
                        f.write(c.tobytes())
        for p in range(PARTS):
            streams = []
            for w in range(WORKERS):
                with open(os.path.join(tmp, f"{w}_{p}.bin"), "rb") as f:
                    streams.append(np.frombuffer(f.read(), REC))
            part = np.concatenate(streams)
            part["payload"].sum()


def _cluster_shuffle(n: int, locality: bool) -> Cluster:
    """End-to-end distributed shuffle on a real 4-node cluster: shard the
    records, map-side partition into each node's local pool, reducers pull
    every partition across the transfer path. ``locality=True`` routes the
    pulls through the scheduler: reducer placement by map-output bytes and
    overlapped async pulls via the transfer engine."""
    cluster = Cluster(NODES, node_capacity=64 << 20, page_size=1 << 18,
                      replication_factor=0)
    rng = np.random.default_rng(0)
    recs = np.zeros(n, REC)
    # zipf-skewed keys: hot keys concentrate a partition's map output on the
    # node storing them, which is exactly the locality placement's win; the
    # r % N baseline ships those bytes anyway
    recs["key"] = rng.zipf(1.3, n).astype(np.int64)
    sset = cluster.create_sharded_set("src", recs, key_fn=lambda r: r["key"])
    sh = ClusterShuffle(cluster, "sh", num_reducers=NODES, dtype=REC)
    sh.map_sharded(sset, key_fn=lambda r: r["key"])
    sh.finish_maps()
    if locality:
        sh.place_reducers_locally()
        futs = [sh.pull_async(r) for r in range(NODES)]
        for r, fut in enumerate(futs):
            fut.result()["payload"].sum()
            sh.release_reducer(r)
    else:
        for r in range(NODES):
            sh.pull(r)["payload"].sum()
            sh.release_reducer(r)
    cluster.shutdown()
    return cluster


def _datapath_shuffle(n: int, columnar: bool, iters: int = 9):
    """The shuffle *datapath* — map -> seal -> drain on a warm 4-node
    cluster — isolating per-record cost from cluster construction and source
    staging (which the ``baseline``/``locality`` rows keep in scope). Setup
    per iteration (untimed): the cluster, the staged source shards in the
    requested storage scheme, the ``ClusterShuffle``, and its per-node
    services (whose construction pre-provisions the per-partition landing
    pages, the paper's §8 virtual shuffle buffers — a provisioning cost,
    not a per-record one). The timed region maps all four shards, seals the
    writers, and drains all four reducers: the columnar scheme streams
    staged column blocks through the fused route->plan->gather->CRC landing
    and pulls raw column blocks; the row scheme routes and materializes
    records. Reported time is the best of ``iters`` fresh shuffles (min —
    the standard microbenchmark statistic under a noisy scheduler).

    Returns ``(seconds, checksums)`` where ``checksums[r]`` is reducer
    ``r``'s order-independent content fingerprint, computed OUTSIDE the
    timed region on the last iteration — for the columnar scheme from a
    materialized re-pull with ``verify=True``, so every reported run has
    CRC-verified its shuffle output before the byte-identity comparison."""
    rng = np.random.default_rng(0)
    recs = np.zeros(n, REC)
    recs["key"] = rng.zipf(1.3, n).astype(np.int64)
    times = []
    checksums = []
    for it in range(iters + 1):                  # iteration 0 is warm-up
        cluster = Cluster(NODES, node_capacity=64 << 20, page_size=1 << 18,
                          replication_factor=0)
        sset = cluster.create_sharded_set(
            "src", recs, key_fn=lambda r: r["key"],
            attrs_factory=columnar_job_data_attrs if columnar else None)
        sh = ClusterShuffle(cluster, "sh", num_reducers=NODES, dtype=REC,
                            columnar=columnar)
        for nid in cluster.alive_node_ids():
            sh._service(nid)                     # provision landing pages
        pulled = []
        t0 = time.perf_counter()
        for s in sorted(sset.shards):
            sh.map_shard(sset, s, key_fn=lambda r: r["key"],
                         key_field="key")
        sh.finish_maps()
        total = 0
        if columnar:
            for r in range(NODES):
                total += sh.pull_columns(r, materialize=False,
                                         verify=False)[1]
        else:
            for r in range(NODES):
                part = sh.pull(r)
                total += len(part)
                pulled.append(part)
        dt = time.perf_counter() - t0
        assert total == n, (total, n)
        if it > 0:
            times.append(dt)
        if it == iters:                          # verify the reported run
            if columnar:
                pulled = [sh.pull(r) for r in range(NODES)]  # CRC-checked
            checksums = [record_content_checksum(p) for p in pulled]
        for r in range(NODES):
            sh.release_reducer(r)
        cluster.shutdown()
    return min(times), checksums


def _over_capacity_shuffle(n: int, policy: str):
    """ISSUE-3 acceptance workload: total map output >= 2x per-node pool
    capacity, so map-side job data and the already-consumed source shards
    must page through the eviction policy, and reducer pulls fault spilled
    map output back in. Compares the paper's data-aware policy against the
    global-LRU baseline on the same over-committed cluster."""
    total_bytes = n * PAIR.itemsize
    cap = max(256 << 10, total_bytes // 4)       # >= 2x over-commit when full
    cluster = Cluster(NODES, node_capacity=cap, page_size=1 << 14,
                      replication_factor=0, policy=policy)
    rng = np.random.default_rng(0)
    recs = np.zeros(n, PAIR)
    recs["key"] = rng.integers(0, 1 << 40, n)
    recs["val"] = rng.random(n)
    sset = cluster.create_sharded_set("src", recs, key_fn=lambda r: r["key"])
    cluster_hash_aggregate(cluster, sset, "key", "val", num_reducers=NODES,
                           hash_page_size=1 << 14, force_shuffle=True)
    spill = sum(node.memory.stats["spill_bytes"]
                for node in cluster.nodes.values())
    fetch = sum(node.memory.stats["fetch_bytes"]
                for node in cluster.nodes.values())
    faults = sum(node.pool.spill.read_ops for node in cluster.nodes.values())
    # eviction-decision cost: heap re-keys (memoized since PR 5 — only
    # attribute-dirtied sets, not a full Eq.-1 refresh per decision)
    rekeys = sum(node.pool.paging.rekeys for node in cluster.nodes.values())
    cluster.shutdown()
    return {"spill_bytes": spill, "fetch_bytes": fetch, "faults": faults,
            "rekeys": rekeys, "net_bytes": cluster.net_bytes,
            "node_capacity": cap, "overcommit": total_bytes / cap}


def _admission_shuffle(n: int, admission: bool):
    """PR-5 acceptance workload: one node is short on headroom (cold resident
    ballast) while zipf-skewed keys concentrate the shuffle's byte-locality
    there. Always-grant placement pins reducers to the byte-heaviest node
    anyway and pays in destination spill; admission-controlled placement
    observes the refusal past the deadline and re-routes those reducers to
    the next-best byte-locality candidates. Returns the sorted pulled keys
    (byte-identity across modes) plus the pull-phase spill/fault deltas,
    the diversions, and the admission counters."""
    # the cluster as a whole has headroom (aggregate capacity >= 4x the
    # data); only the ballasted hot node is short — over-capacity locally,
    # not globally, which is exactly when re-routing has somewhere to go
    cap = max(512 << 10, n * PAIR.itemsize)
    cluster = Cluster(NODES, node_capacity=cap, page_size=1 << 14,
                      replication_factor=0, admission=admission,
                      admission_deadline_s=0.02)
    rng = np.random.default_rng(0)
    recs = np.zeros(n, PAIR)
    recs["key"] = rng.zipf(1.3, n).astype(np.int64)
    recs["val"] = rng.random(n)
    sset = cluster.create_sharded_set("src", recs, key_fn=lambda r: r["key"])
    sh = ClusterShuffle(cluster, "sh", num_reducers=NODES, dtype=PAIR)
    sh.map_sharded(sset, key_fn=lambda r: r["key"])
    sh.finish_maps()
    # ballast the byte-heaviest node past its watermark (7/8 of remaining
    # headroom puts occupancy >= 0.875 of capacity, above the 0.85
    # watermark): it will refuse admission of any reducer partition while
    # staying fully functional
    hot = max(cluster.alive_node_ids(), key=lambda nid: sum(
        cluster.stats.shuffle_partition_bytes("sh", r).get(nid, 0)
        for r in range(NODES)))
    headroom = cap - cluster.nodes[hot].memory.resident_bytes
    ballast = np.zeros(max(1, (headroom * 7 // 8) // PAIR.itemsize), PAIR)
    cluster.nodes[hot].write_records("ballast", ballast, PAIR, 1 << 14)
    spill0 = {nid: cluster.nodes[nid].memory.stats["spill_bytes"]
              for nid in cluster.alive_node_ids()}
    faults0 = sum(node.pool.spill.read_ops
                  for node in cluster.nodes.values() if node.alive)
    # placement timed separately: with admission on it includes deadline
    # waits on refusing nodes, which must not masquerade as data-path cost
    t0 = time.perf_counter()
    sh.place_reducers_locally()
    place_seconds = time.perf_counter() - t0
    t0 = time.perf_counter()
    keys_out = []
    for r in range(NODES):
        keys_out.append(np.sort(sh.pull(r)["key"]).copy())
        sh.release_reducer(r)
    pull_seconds = time.perf_counter() - t0
    spill = sum(cluster.nodes[nid].memory.stats["spill_bytes"] - s0
                for nid, s0 in spill0.items())
    faults = sum(node.pool.spill.read_ops
                 for node in cluster.nodes.values() if node.alive) - faults0
    refused = sum(node.memory.admission.refused
                  for node in cluster.nodes.values() if node.alive)
    keys = np.sort(np.concatenate(keys_out))
    out = {"keys": keys, "spill_bytes": spill, "faults": faults,
           "diversions": dict(sh.diversions), "refused": refused,
           "hot_node": hot, "pull_seconds": pull_seconds,
           "place_seconds": place_seconds,
           "net_bytes": cluster.net_bytes, "node_capacity": cap}
    cluster.shutdown()
    return out


def _co_partitioned_agg(n: int) -> Cluster:
    """The §9.2.2 co-partitioned case: input staged partitioned on the
    aggregation key, so the scheduler elides the shuffle (net_bytes == 0)."""
    cluster = Cluster(NODES, node_capacity=64 << 20, page_size=1 << 18,
                      replication_factor=0)
    rng = np.random.default_rng(0)
    recs = np.zeros(n, PAIR)
    recs["key"] = rng.integers(0, n // 8 or 1, n)
    recs["val"] = rng.random(n)
    sset = cluster.create_sharded_set("co", recs, key_fn=lambda r: r["key"],
                                      partition_key="key")
    cluster_hash_aggregate(cluster, sset, "key", "val")
    cluster.shutdown()
    return cluster


def run() -> None:
    # columnar vs row-oriented shuffle datapath (PR 7): identical cluster
    # shape, keys, and drain pattern; only the storage scheme differs. The
    # byte-identity assert is the acceptance gate — the columnar run's
    # output has already been CRC-verified inside _datapath_shuffle. Runs
    # first: the datapath rows are the only clock-frequency-sensitive
    # measurement in the suite, so they get the cold (unthrottled) CPU.
    for n in (scaled(100_000), scaled(400_000)):
        tc, sums_col = _datapath_shuffle(n, columnar=True)
        tr, sums_row = _datapath_shuffle(n, columnar=False)
        assert sums_col == sums_row, \
            f"columnar shuffle output diverged from row scheme at n={n}"
        record(f"shuffle/cluster{NODES}node/columnar/n{n}", tc * 1e6,
               f"recs_per_s={n/tc:.0f};speedup_vs_rowpath={tr/tc:.2f}x",
               recs_per_s=n / tc, scheme="columnar", crc_verified=True,
               byte_identical=True, stat="min_of_9")
        record(f"shuffle/cluster{NODES}node/rowpath/n{n}", tr * 1e6,
               f"recs_per_s={n/tr:.0f}",
               recs_per_s=n / tr, scheme="row", stat="min_of_9")

    for n in (scaled(100_000), scaled(400_000)):
        tp = timeit(lambda: _pangea(n))
        tb = timeit(lambda: _sparklike(n))
        record(f"shuffle/pangea/n{n}", tp * 1e6,
               f"recs_per_s={n/tp:.0f}", recs_per_s=n / tp)
        record(f"shuffle/sparklike/n{n}", tb * 1e6,
               f"recs_per_s={n/tb:.0f};speedup={tb/tp:.2f}x",
               recs_per_s=n / tb, pangea_speedup=tb / tp)
        runs = {}
        for locality in (False, True):
            last = []
            tc = timeit(lambda: last.append(_cluster_shuffle(n, locality)))
            runs[locality] = (tc, last[-1].net_bytes)
            tag = "locality" if locality else "baseline"
            record(f"shuffle/cluster{NODES}node/{tag}/n{n}", tc * 1e6,
                   f"recs_per_s={n/tc:.0f};net_mb={last[-1].net_bytes/1e6:.2f}",
                   recs_per_s=n / tc, net_bytes=last[-1].net_bytes,
                   placement=tag)
        (tb_c, net_base), (tl_c, net_loc) = runs[False], runs[True]
        saved = net_base - net_loc
        record(f"shuffle/cluster{NODES}node/locality_gain/n{n}", 0.0,
               f"net_saved_mb={saved/1e6:.2f};"
               f"net_ratio={net_loc/max(net_base, 1):.3f}",
               net_bytes_baseline=net_base, net_bytes_locality=net_loc,
               net_bytes_saved=saved)
        last = []
        ta = timeit(lambda: last.append(_co_partitioned_agg(n)))
        record(f"shuffle/cluster{NODES}node/copartitioned_agg/n{n}", ta * 1e6,
               f"recs_per_s={n/ta:.0f};net_bytes={last[-1].net_bytes}",
               recs_per_s=n / ta, net_bytes=last[-1].net_bytes)

    # over-capacity shuffle: pool < map output, data-aware vs global LRU
    n = scaled(200_000)
    over = {}
    for policy in ("data-aware", "lru"):
        stats = []
        t = timeit(lambda: stats.append(_over_capacity_shuffle(n, policy)))
        s = stats[-1]
        over[policy] = (t, s)
        record(f"shuffle/cluster{NODES}node/overcap/{policy}/n{n}", t * 1e6,
               f"spill_mb={s['spill_bytes']/1e6:.2f};"
               f"faults={s['faults']};"
               f"overcommit={s['overcommit']:.1f}x",
               recs_per_s=n / t, policy=policy, **s)
    (td, sd), (tl, sl) = over["data-aware"], over["lru"]
    # with the memoized Eq.-1 heap (PR 5) the fault win should show up as a
    # wall-clock win too, not just a fault-count win — both are recorded
    record(f"shuffle/cluster{NODES}node/overcap_gain/n{n}", 0.0,
           f"fault_ratio={sd['faults']/max(1, sl['faults']):.3f};"
           f"time_ratio={td/tl:.3f}",
           faults_data_aware=sd["faults"], faults_lru=sl["faults"],
           spill_bytes_data_aware=sd["spill_bytes"],
           spill_bytes_lru=sl["spill_bytes"],
           seconds_data_aware=td, seconds_lru=tl,
           time_win=bool(td < tl),
           data_aware_wins=bool(sd["faults"] < sl["faults"] or td < tl))

    # admission-controlled vs always-grant over-capacity shuffle (PR 5):
    # same data, same cluster shape; with admission on, reducers planned
    # onto the refusing hot node are re-routed and its spill drops
    n = scaled(160_000)
    adm = {flag: _admission_shuffle(n, flag) for flag in (False, True)}
    identical = bool(np.array_equal(adm[True]["keys"], adm[False]["keys"]))
    for flag in (False, True):
        s = adm[flag]
        tag = "on" if flag else "off"
        record(f"shuffle/cluster{NODES}node/admission/{tag}/n{n}",
               s["pull_seconds"] * 1e6,
               f"spill_mb={s['spill_bytes']/1e6:.2f};"
               f"diverted={len(s['diversions'])};refused={s['refused']}",
               spill_bytes=s["spill_bytes"], faults=s["faults"],
               diverted=len(s["diversions"]),
               diversions={str(k): list(v)
                           for k, v in s["diversions"].items()},
               refused=s["refused"], hot_node=s["hot_node"],
               place_seconds=s["place_seconds"],
               net_bytes=s["net_bytes"], node_capacity=s["node_capacity"],
               admission=flag)
    son, soff = adm[True], adm[False]
    record(f"shuffle/cluster{NODES}node/admission_gain/n{n}", 0.0,
           f"spill_ratio={son['spill_bytes']/max(1, soff['spill_bytes']):.3f};"
           f"diverted={len(son['diversions'])};identical={identical}",
           spill_bytes_admission=son["spill_bytes"],
           spill_bytes_always_grant=soff["spill_bytes"],
           diverted=len(son["diversions"]), refused=son["refused"],
           byte_identical=identical,
           admission_wins=bool(
               son["spill_bytes"] <= soff["spill_bytes"]
               and len(son["diversions"]) > 0))


if __name__ == "__main__":
    run()
