"""Shared benchmark helpers: CSV rows, structured metrics for the JSON
artifact, wall-clock timing, and smoke mode (BENCH_SMOKE=1 shrinks problem
sizes so CI can run the suite as a correctness smoke test)."""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List, Optional

# v2: overcap shuffle rows (spill_bytes / fetch_bytes / faults / overcommit
# / data_aware_wins) joined the cluster artifact
# v3: distributed-join rows (join/cluster*: net_bytes per scheduler plan,
# copartitioned_is_free, movement_gain) joined the cluster artifact
# v4: admission-control rows (shuffle/cluster*/admission*: admission-on vs
# always-grant destination spill/faults, diversions, refused/throttled
# counters, admission_wins) joined the cluster artifact
# v5: durable-tier rows (recovery/warm_vs_cold/*: warm page-log recovery vs
# cold replica pulls, recovery/overcap_scan: a scan over a set larger than
# aggregate pool RAM completing byte-identically through the page log)
# joined the cluster artifact
# v6: columnar datapath rows (shuffle/cluster*/columnar + the paired
# rowpath control: map->seal->drain time under each storage scheme,
# CRC-verified byte-identical output) and the fused partition+CRC roofline
# row (roofline/fused_partition_crc: achieved GB/s vs the memory-bound
# ceiling) joined the cluster artifact
# v7: process-data-plane rows (shuffle/cluster4node/procplane/{overlap,
# overcap}/{inproc,proc,gain}: durable end-to-end pipelines timed wall-clock
# min-of-N on both backends, and recovery/cluster4node/procplane/sigkill:
# a node process SIGKILLed between map and reduce with byte-identical
# output via replica re-execution) joined the cluster artifact
SCHEMA_VERSION = 7

ROWS: List[dict] = []


def smoke_mode() -> bool:
    return os.environ.get("BENCH_SMOKE", "") not in ("", "0")


def scaled(n: int, floor: int = 2_000) -> int:
    """Problem size under the current mode: full, or ~1/20th in smoke mode."""
    return max(floor, n // 20) if smoke_mode() else n


def record(name: str, us_per_call: float, derived: str = "",
           **metrics) -> None:
    """Print the legacy CSV line and keep a structured row. ``metrics``
    keyword pairs (throughput, net_bytes, seconds, ...) land in the JSON
    artifact written by ``benchmarks/run.py``."""
    ROWS.append({"name": name, "us_per_call": us_per_call,
                 "derived": derived, **metrics})
    print(f"{name},{us_per_call:.1f},{derived}")


def timeit(fn: Callable, *, repeats: int = 3) -> float:
    """Median wall time of fn() in seconds."""
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def write_results_json(path: str, prefixes: Optional[List[str]] = None,
                       extra: Optional[Dict] = None) -> dict:
    """Write recorded rows (optionally filtered by name prefix) as a
    schema-versioned JSON document so the perf trajectory accumulates across
    PRs."""
    rows = [r for r in ROWS
            if prefixes is None or any(r["name"].startswith(p)
                                       for p in prefixes)]
    doc = {
        "schema_version": SCHEMA_VERSION,
        "generated_by": "benchmarks/run.py",
        "smoke": smoke_mode(),
        "results": rows,
        **(extra or {}),
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    print(f"# wrote {path} ({len(rows)} rows, schema v{SCHEMA_VERSION})")
    return doc
