"""Runtime lock-order / blocking-while-holding sanitizer (PR 10).

The static half of ``pangea-check`` (``tools/pangea_check``) proves lexical
invariants; this module is the dynamic half.  Every lock in the data plane is
constructed through :func:`tracked_lock` / :func:`tracked_rlock` /
:func:`tracked_condition` (rule R4 forbids bare ``threading.Lock()`` anywhere
else), which makes the concurrency surface *observable*:

* **Lock-order graph** — under ``PANGEA_SANITIZE=1`` every acquire records a
  ``held -> acquired`` edge at *name* granularity (one name per lock class,
  e.g. ``"buffer_pool"``), so two code paths that nest the same two lock
  classes in opposite orders show up as a cycle in
  ``sanitizer_report()["cycles"]`` — a potential deadlock — even when the
  test run never actually deadlocked.  Acquiring two *different instances*
  of the same name while one is held is a self-cycle and reported too
  (reentrant re-acquires of one RLock instance are not edges).
* **Blocking-while-holding** — the repo's real blocking primitives
  (``os.fsync`` in the page log, socket send/recv in the RPC layer, future
  waits) are instrumented with :func:`blocking_region`; entering one while
  any tracked lock is held is recorded.  Waiting on a condition variable's
  *own* lock is the one sanctioned blocking-under-lock pattern — the wait
  releases the lock — so :class:`TrackedCondition` suspends its lock's hold
  frame for the duration of the wait.
* **Hold times** — per lock name, the longest observed hold (with the
  acquire site), so "who serializes the data plane" is a measurement.

Everything is a no-op unless sanitizing is enabled (``PANGEA_SANITIZE=1`` in
the environment, or :func:`enable` from a test); the disabled fast path is a
single module-global boolean check per acquire.

This file is the only module allowed to construct bare ``threading`` locks
(it is the bottom of the tower — its own registry lock cannot be tracked by
itself).
"""
from __future__ import annotations

import os
import sys
import threading
import time
from contextlib import contextmanager
from typing import Dict, List, Optional, Set, Tuple

# the sanitizer's own state lock: the one primitive the tracked tower is
# built on, exempt from R4 by construction
_STATE_LOCK = threading.Lock()

_ENABLED = os.environ.get("PANGEA_SANITIZE", "") not in ("", "0")

_TLS = threading.local()

# (held_name, acquired_name) -> first observed "file:line" site
_edges: Dict[Tuple[str, str], str] = {}
# op -> list of {"op", "held", "site"} events (bounded)
_blocking_events: List[Dict[str, object]] = []
# name -> (max_hold_seconds, acquire site)
_hold_times: Dict[str, Tuple[float, str]] = {}
_acquires: Dict[str, int] = {}

_MAX_EVENTS = 256


def enable(flag: bool = True) -> None:
    """Turn sanitizing on/off at runtime (tests use this instead of the
    ``PANGEA_SANITIZE`` environment variable)."""
    global _ENABLED
    _ENABLED = bool(flag)


def enabled() -> bool:
    return _ENABLED


def reset() -> None:
    """Clear every recorded edge/event/hold — each test asserts its own
    deltas, never another test's residue."""
    with _STATE_LOCK:
        _edges.clear()
        _blocking_events.clear()
        _hold_times.clear()
        _acquires.clear()


def _caller_site(skip_self: bool = True) -> str:
    """``file:line`` of the nearest frame outside this module."""
    f = sys._getframe(1)
    me = __file__
    while f is not None and skip_self and f.f_code.co_filename == me:
        f = f.f_back
    if f is None:  # pragma: no cover - interpreter teardown
        return "?"
    return f"{os.path.basename(f.f_code.co_filename)}:{f.f_lineno}"


class _Frame:
    __slots__ = ("lock", "name", "t0", "site", "depth")

    def __init__(self, lock, name: str, t0: float, site: str):
        self.lock = lock
        self.name = name
        self.t0 = t0
        self.site = site
        self.depth = 1


def _held_stack() -> List[_Frame]:
    stack = getattr(_TLS, "stack", None)
    if stack is None:
        stack = _TLS.stack = []
    return stack


def held_lock_names() -> List[str]:
    """Names of the tracked locks the calling thread currently holds."""
    return [f.name for f in _held_stack()]


def _note_attempt(lock, reentrant: bool) -> None:
    """Record order edges from every held lock to the one being acquired.
    Called *before* the real acquire so blocked attempts still contribute
    their intended order."""
    stack = _held_stack()
    if not stack:
        return
    if reentrant and any(fr.lock is lock for fr in stack):
        return  # same-instance RLock re-acquire: not an ordering event
    site = _caller_site()
    with _STATE_LOCK:
        for fr in stack:
            if fr.lock is lock:
                continue
            _edges.setdefault((fr.name, lock.name), site)


def _push_hold(lock) -> None:
    stack = _held_stack()
    if isinstance(lock, TrackedRLock):
        for fr in stack:
            if fr.lock is lock:
                fr.depth += 1
                return
    stack.append(_Frame(lock, lock.name, time.monotonic(), _caller_site()))
    with _STATE_LOCK:
        _acquires[lock.name] = _acquires.get(lock.name, 0) + 1


def _pop_hold(lock) -> None:
    stack = _held_stack()
    for i in range(len(stack) - 1, -1, -1):
        fr = stack[i]
        if fr.lock is lock:
            fr.depth -= 1
            if fr.depth == 0:
                stack.pop(i)
                dt = time.monotonic() - fr.t0
                with _STATE_LOCK:
                    best = _hold_times.get(fr.name)
                    if best is None or dt > best[0]:
                        _hold_times[fr.name] = (dt, fr.site)
            return
    # releasing a lock this thread never tracked (enable() flipped mid-hold)


class TrackedLock:
    """``threading.Lock`` with sanitizer bookkeeping. Drop-in: ``acquire`` /
    ``release`` / context manager / ``locked``."""

    _reentrant = False

    def __init__(self, name: str, _raw=None):
        self.name = name
        self._raw = _raw if _raw is not None else threading.Lock()

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        if not _ENABLED:
            return self._raw.acquire(blocking, timeout)
        _note_attempt(self, self._reentrant)
        got = self._raw.acquire(blocking, timeout)
        if got:
            _push_hold(self)
        return got

    def release(self) -> None:
        if _ENABLED:
            _pop_hold(self)
        self._raw.release()

    def locked(self) -> bool:
        return self._raw.locked()

    def __enter__(self) -> "TrackedLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<{type(self).__name__} {self.name!r}>"


class TrackedRLock(TrackedLock):
    """Reentrant tracked lock: same-instance re-acquires bump a depth count
    instead of recording order edges or new hold frames."""

    _reentrant = True

    def __init__(self, name: str):
        super().__init__(name, _raw=threading.RLock())


class TrackedCondition:
    """Condition variable over a tracked lock.

    ``wait``/``wait_for`` *suspend* the lock's hold frame for the duration —
    waiting on your own condition releases the lock, which is exactly why it
    is the sanctioned exception to the no-blocking-under-lock rule (R3) —
    then restore it on wakeup, so lock-order and hold-time accounting stay
    truthful across waits.
    """

    def __init__(self, name: str, lock: Optional[TrackedLock] = None):
        self.name = name
        self.lock = lock if lock is not None else TrackedRLock(f"{name}.lock")
        self._cond = threading.Condition(self.lock._raw)

    # -- lock interface ------------------------------------------------------
    def acquire(self, *args, **kwargs) -> bool:
        return self.lock.acquire(*args, **kwargs)

    def release(self) -> None:
        self.lock.release()

    def __enter__(self) -> "TrackedCondition":
        self.lock.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.lock.release()

    # -- waiting -------------------------------------------------------------
    def _suspend(self) -> Optional[_Frame]:
        if not _ENABLED:
            return None
        stack = _held_stack()
        for i in range(len(stack) - 1, -1, -1):
            if stack[i].lock is self.lock:
                return stack.pop(i)
        return None

    def _resume(self, frame: Optional[_Frame]) -> None:
        if frame is not None:
            frame.t0 = time.monotonic()   # a fresh hold starts at wakeup
            _held_stack().append(frame)

    def wait(self, timeout: Optional[float] = None) -> bool:
        frame = self._suspend()
        try:
            # own lock's frame is suspended; anything still held is a
            # genuine blocking-while-holding
            note_blocking(f"cond.wait({self.name})")
            return self._cond.wait(timeout)
        finally:
            self._resume(frame)

    def wait_for(self, predicate, timeout: Optional[float] = None):
        frame = self._suspend()
        try:
            note_blocking(f"cond.wait({self.name})")
            return self._cond.wait_for(predicate, timeout)
        finally:
            self._resume(frame)

    def notify(self, n: int = 1) -> None:
        self._cond.notify(n)

    def notify_all(self) -> None:
        self._cond.notify_all()


def tracked_lock(name: str) -> TrackedLock:
    """The only sanctioned way to make a mutex (R4): a named, sanitized
    ``threading.Lock``."""
    return TrackedLock(name)


def tracked_rlock(name: str) -> TrackedRLock:
    return TrackedRLock(name)


def tracked_condition(name: str,
                      lock: Optional[TrackedLock] = None) -> TrackedCondition:
    return TrackedCondition(name, lock)


# -- blocking-while-holding ---------------------------------------------------
@contextmanager
def blocking_region(op: str, allow: Tuple[str, ...] = ()):
    """Mark a genuinely blocking primitive (fsync, socket round-trip, future
    wait).  Entered while the thread holds any tracked lock not named in
    ``allow``, the event is recorded — the runtime analogue of static rule
    R3.  ``allow`` names locks whose holding is the *point* (e.g. page-log
    compaction excludes writers for the whole rewrite)."""
    if _ENABLED:
        held = [n for n in held_lock_names() if n not in allow]
        if held:
            with _STATE_LOCK:
                if len(_blocking_events) < _MAX_EVENTS:
                    _blocking_events.append(
                        {"op": op, "held": held, "site": _caller_site()})
    yield


def note_blocking(op: str, allow: Tuple[str, ...] = ()) -> None:
    """Point-event form of :func:`blocking_region`."""
    with blocking_region(op, allow):
        pass


# -- reporting ----------------------------------------------------------------
def _find_cycles(edges: Set[Tuple[str, str]]) -> List[List[str]]:
    """Cycles in the lock-order graph (each reported once, rotated so the
    lexically smallest name leads).  Self-loops (same lock name nested
    across instances) count."""
    graph: Dict[str, Set[str]] = {}
    for a, b in edges:
        graph.setdefault(a, set()).add(b)
    cycles: Set[Tuple[str, ...]] = set()

    def dfs(node: str, path: List[str], on_path: Set[str]) -> None:
        for nxt in sorted(graph.get(node, ())):
            if nxt in on_path:
                cyc = path[path.index(nxt):]
                k = cyc.index(min(cyc))
                cycles.add(tuple(cyc[k:] + cyc[:k]))
                continue
            path.append(nxt)
            on_path.add(nxt)
            dfs(nxt, path, on_path)
            on_path.discard(nxt)
            path.pop()

    for start in sorted(graph):
        dfs(start, [start], {start})
    return [list(c) for c in sorted(cycles)]


def sanitizer_report() -> Dict[str, object]:
    """Everything the run observed: order edges, deadlock cycles, blocking
    while holding, longest holds.  ``violations`` is the headline count the
    CI gate (and the negative-path tests) assert on."""
    with _STATE_LOCK:
        edges = dict(_edges)
        events = [dict(e) for e in _blocking_events]
        holds = dict(_hold_times)
        acquires = dict(_acquires)
    cycles = _find_cycles(set(edges))
    longest = sorted(((name, round(dt, 6), site)
                      for name, (dt, site) in holds.items()),
                     key=lambda t: -t[1])
    return {
        "enabled": _ENABLED,
        "acquires": acquires,
        "edges": sorted((a, b, site) for (a, b), site in edges.items()),
        "cycles": cycles,
        "blocking_while_holding": events,
        "longest_holds": longest[:10],
        "violations": len(cycles) + len(events),
    }


def assert_clean(context: str = "") -> None:
    """Raise if the run recorded any violation — the CI-side gate."""
    report = sanitizer_report()
    if report["violations"]:
        raise AssertionError(
            f"sanitizer found {report['violations']} violation(s)"
            f"{' in ' + context if context else ''}: "
            f"cycles={report['cycles']} "
            f"blocking={report['blocking_while_holding']}")
