"""Heterogeneous replication and recovery — paper §7.

Replicas of one logical dataset are kept under *different* partition schemes.
They do double duty:

* performance — a query picks the co-partitioned replica (no shuffle);
* fault tolerance — a lost node's pages of one replica are rebuilt by
  re-running the partitioner over the surviving pages of a *differently
  partitioned* replica.

The subtlety (paper §7): an object that lands on the same node in both the
source and target partitionings is a *conflicting object* — if that node dies,
neither copy survives. Conflicting objects are identified at partition time
and replicated separately to other nodes. For a random partitioning the
expected conflicting count is ``N/K`` (N objects, K nodes) — asserted by a
property test and reported by ``benchmarks/bench_recovery.py``.

This module operates on numpy record arrays per node. It is used three ways:
dataset shards (data pipeline), checkpoint tensor shards (checkpoint/), and
the paper-fidelity benchmarks.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .statistics import ReplicaInfo, StatisticsDB

KeyFn = Callable[[np.ndarray], np.ndarray]  # records -> int64 keys


def _node_of(partition_ids: np.ndarray, num_partitions: int,
             num_nodes: int) -> np.ndarray:
    return partition_ids % num_nodes


def shard_checksum(records: np.ndarray) -> int:
    """CRC32 over a shard's raw record bytes. Recovery re-materializes shards
    page by page in primary order, so a byte-exact checksum match certifies
    the rebuilt shard (cluster runtime uses this after node recovery)."""
    return zlib.crc32(np.ascontiguousarray(records).tobytes()) & 0xFFFFFFFF


_CONTENT_MULT = np.uint64(0x9E3779B97F4A7C15)
_CONTENT_MIX = np.uint64(0xC2B2AE3D27D4EB4F)


def record_content_checksum(records: np.ndarray) -> int:
    """Order-independent, duplicate-sensitive 64-bit content fingerprint:
    the wrapping sum of per-record hashes. Because addition commutes, the
    fingerprint of a shard equals the sum of the fingerprints of any chunking
    of it — which is what lets the streaming remesh and the co-partitioned
    rebuild verify shards they assembled in a *different record order* than
    the original (``shard_checksum`` is order-exact and cannot)."""
    records = np.ascontiguousarray(records)
    n = len(records)
    if n == 0:
        return 0
    raw8 = records.view(np.uint8).reshape(n, -1)
    width = raw8.shape[1]
    # position-dependent odd multipliers (cumprod wraps mod 2**64)
    mults = np.full(width, _CONTENT_MULT, dtype=np.uint64)
    total = 0
    # fold in bounded chunks: the uint64 widening is 8x the record bytes, so
    # hashing a whole shard at once would cost ~16x its size in temporaries
    step = max(1, (1 << 20) // width)
    with np.errstate(over="ignore"):
        mults = np.cumprod(mults, dtype=np.uint64)
        for i in range(0, n, step):
            raw = raw8[i:i + step].astype(np.uint64)
            row = (raw * mults).sum(axis=1, dtype=np.uint64)
            row = (row ^ (row >> np.uint64(29))) * _CONTENT_MIX
            row ^= row >> np.uint64(32)
            total = (total + int(row.sum(dtype=np.uint64))) % (1 << 64)
    return total


def combine_content_checksums(parts: Sequence[int]) -> int:
    """Fingerprint of a concatenation/union from its chunks' fingerprints."""
    return int(sum(int(p) for p in parts) % (1 << 64))


def replica_nodes(node: int, num_nodes: int, factor: int) -> List[int]:
    """Chain placement: the ``factor`` replica holders for ``node``'s shard are
    the next distinct nodes in ring order — never the primary itself, so any
    single-node loss leaves at least one copy (paper §7's separate-node rule
    for conflicting objects, generalized to page-level shard replicas)."""
    if factor >= num_nodes:
        raise ValueError(f"replication factor {factor} needs more than "
                         f"{num_nodes} nodes")
    return [(node + 1 + r) % num_nodes for r in range(factor)]


@dataclass
class PartitionScheme:
    """A partitioner: key function + partition count + node mapping."""

    name: str
    key_fn: KeyFn
    num_partitions: int
    num_nodes: int

    def partition_of_keys(self, keys: np.ndarray) -> np.ndarray:
        """Partition id from bare int64 keys — the join path routes a
        shuffled side by the *other* side's scheme, whose key field may have
        a different name, so the hash must be reachable without records."""
        h = np.asarray(keys).astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15)
        return (h % np.uint64(self.num_partitions)).astype(np.int64)

    def partition_of(self, records: np.ndarray) -> np.ndarray:
        return self.partition_of_keys(self.key_fn(records))

    def slot_of_keys(self, keys: np.ndarray) -> np.ndarray:
        """Scheme slot (index into a sharded set's ``node_ids``) per key."""
        return _node_of(self.partition_of_keys(keys), self.num_partitions,
                        self.num_nodes)

    def node_of_records(self, records: np.ndarray) -> np.ndarray:
        return _node_of(self.partition_of(records), self.num_partitions,
                        self.num_nodes)


@dataclass
class DistributedSet:
    """A locality set's distributed view: records per node (host metadata of
    the real per-node page sets)."""

    name: str
    scheme: Optional[PartitionScheme]  # None = randomly dispatched source set
    shards: Dict[int, np.ndarray] = field(default_factory=dict)

    def total_records(self) -> int:
        return sum(len(v) for v in self.shards.values())

    def all_records(self) -> np.ndarray:
        parts = [self.shards[n] for n in sorted(self.shards)]
        return np.concatenate(parts) if parts else np.empty(0)


def random_dispatch(name: str, records: np.ndarray, num_nodes: int,
                    seed: int = 0) -> DistributedSet:
    """Create a randomly dispatched source set (paper: "the lineitem source
    set is a randomly dispatched set")."""
    rng = np.random.default_rng(seed)
    nodes = rng.integers(0, num_nodes, size=len(records))
    shards = {n: records[nodes == n] for n in range(num_nodes)}
    return DistributedSet(name, None, shards)


@dataclass
class ReplicaRegistration:
    source: DistributedSet
    target: DistributedSet
    scheme: PartitionScheme
    # conflicting objects, replicated onto OTHER nodes: guard_node -> records
    conflict_guards: Dict[int, np.ndarray] = field(default_factory=dict)
    num_conflicting: int = 0


def partition_set(source: DistributedSet, target_name: str,
                  scheme: PartitionScheme) -> DistributedSet:
    """The ``partitionSet`` API (paper §7): run the partitioner over the
    source to produce a target set placed by the scheme."""
    target_shards: Dict[int, List[np.ndarray]] = {n: [] for n in range(scheme.num_nodes)}
    for node, recs in source.shards.items():
        if len(recs) == 0:
            continue
        tnodes = scheme.node_of_records(recs)
        for tn in np.unique(tnodes):
            target_shards[int(tn)].append(recs[tnodes == tn])
    shards = {n: (np.concatenate(v) if v else source.all_records()[:0])
              for n, v in target_shards.items()}
    return DistributedSet(target_name, scheme, shards)


def register_replica(source: DistributedSet, target: DistributedSet,
                     scheme: PartitionScheme,
                     stats: Optional[StatisticsDB] = None,
                     logical_name: Optional[str] = None) -> ReplicaRegistration:
    """The ``registerReplica`` API: record the replica relationship AND
    identify + separately replicate conflicting objects (paper §7)."""
    reg = ReplicaRegistration(source, target, scheme)
    guards: Dict[int, List[np.ndarray]] = {}
    total_conflicts = 0
    num_nodes = scheme.num_nodes
    for node, recs in source.shards.items():
        if len(recs) == 0:
            continue
        tnodes = scheme.node_of_records(recs)
        conflict_mask = tnodes == node  # same node in source AND target
        conflicts = recs[conflict_mask]
        total_conflicts += len(conflicts)
        if len(conflicts):
            guard_node = replica_nodes(node, num_nodes, 1)[0]  # a different node
            guards.setdefault(guard_node, []).append(conflicts)
    reg.conflict_guards = {n: np.concatenate(v) for n, v in guards.items()}
    reg.num_conflicting = total_conflicts
    if stats is not None and logical_name is not None:
        stats.register_replica(logical_name, ReplicaInfo(
            set_name=target.name, partition_key=scheme.name,
            num_partitions=scheme.num_partitions, num_nodes=scheme.num_nodes))
    return reg


def fail_node(dset: DistributedSet, node: int) -> None:
    """Simulate a node crash: its shard of this set is lost."""
    if node in dset.shards:
        dset.shards[node] = dset.shards[node][:0]


def recover_target_shard(reg: ReplicaRegistration, failed_node: int) -> np.ndarray:
    """Rebuild the target set's lost shard (paper §7 recovery):

    1. surviving nodes re-run the registered partitioner over their remaining
       source pages, re-dispatching objects whose target node is the failed
       node's replacement (here: the same logical node id, restored);
    2. conflicting objects — lost in both layouts — come from the guard
       replicas.
    """
    scheme = reg.scheme
    pieces: List[np.ndarray] = []
    for node, recs in reg.source.shards.items():
        if node == failed_node or len(recs) == 0:
            continue  # failed node's source pages are gone too
        tnodes = scheme.node_of_records(recs)
        sel = recs[tnodes == failed_node]
        if len(sel):
            pieces.append(sel)
    # conflicting objects: replicated separately on guard nodes
    for guard_node, recs in reg.conflict_guards.items():
        if guard_node == failed_node or len(recs) == 0:
            continue
        tnodes = scheme.node_of_records(recs)
        sel = recs[tnodes == failed_node]
        if len(sel):
            pieces.append(sel)
    recovered = (np.concatenate(pieces) if pieces
                 else reg.source.all_records()[:0])
    reg.target.shards[failed_node] = recovered
    return recovered


def recover_source_shard(reg: ReplicaRegistration, failed_node: int,
                         source_placement: Callable[[np.ndarray], np.ndarray]
                         ) -> np.ndarray:
    """Rebuild the *source* set's lost shard from the target replica: every
    object of the target whose source placement was the failed node.

    ``source_placement`` maps records -> original source node (for a randomly
    dispatched source this must be a recorded dispatch map; for a partitioned
    source it is its scheme's node mapping).
    """
    pieces: List[np.ndarray] = []
    for node, recs in reg.target.shards.items():
        if node == failed_node or len(recs) == 0:
            continue
        snodes = source_placement(recs)
        sel = recs[snodes == failed_node]
        if len(sel):
            pieces.append(sel)
    for guard_node, recs in reg.conflict_guards.items():
        if guard_node == failed_node or len(recs) == 0:
            continue
        snodes = source_placement(recs)
        sel = recs[snodes == failed_node]
        if len(sel):
            pieces.append(sel)
    recovered = (np.concatenate(pieces) if pieces
                 else reg.target.all_records()[:0])
    reg.source.shards[failed_node] = recovered
    return recovered


def expected_conflicts(n_objects: int, n_nodes: int) -> float:
    """Paper §7: E[#conflicting] = N/K for a random source→target mapping."""
    return n_objects / n_nodes
