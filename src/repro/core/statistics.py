"""Pangea "statistics database" — paper §3.2 / §7 / §9.2.2.

The manager node's catalog: which locality sets exist, which replicas of each
logical dataset exist under which partition scheme, plus access statistics.
Query planners (and the checkpoint restorer) ask it for the replica whose
partitioning best matches an operation — the paper's "select a Pangea replica
that is the best for the query execution".
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class ReplicaInfo:
    set_name: str
    partition_key: Optional[str]      # None = randomly dispatched (source set)
    num_partitions: int
    num_nodes: int
    page_size: int = 0
    extra: dict = field(default_factory=dict)


class StatisticsDB:
    def __init__(self):
        # logical dataset -> list of physical replicas
        self._replicas: Dict[str, List[ReplicaInfo]] = {}
        self._access_counts: Dict[str, int] = {}
        # shuffle -> partition -> node -> bytes of map output held there
        # (the locality signal behind scheduler reducer placement)
        self._shuffle_bytes: Dict[str, Dict[int, Dict[int, int]]] = {}
        # node -> (memory pressure score in [0, 1], event seq at recording)
        # (published by the shuffle finalizer from each node's MemoryManager;
        # the scheduler penalizes placement onto nodes that are already
        # spilling). ``_event_seq`` counts topology/job boundaries — a score
        # recorded before the latest boundary is stale and schedulers fall
        # back to the node's live pressure (PR-5 bugfix: back-to-back jobs
        # used to plan against the previous job's finalization snapshot).
        self._node_pressure: Dict[int, tuple] = {}
        self._event_seq = 0

    def register_replica(self, logical_name: str, info: ReplicaInfo) -> None:
        self._replicas.setdefault(logical_name, []).append(info)

    def update_replica(self, logical_name: str, info: ReplicaInfo) -> None:
        """Replace the registered entry with the same ``set_name`` (used when a
        set is re-sharded after an elastic remesh), or append if new."""
        replicas = self._replicas.setdefault(logical_name, [])
        for i, r in enumerate(replicas):
            if r.set_name == info.set_name:
                replicas[i] = info
                return
        replicas.append(info)

    # -- shuffle placement statistics (scheduler input) -----------------------
    def record_shuffle_bytes(self, shuffle: str, partition: int, node: int,
                             nbytes: int) -> None:
        """Record (idempotently) how many map-output bytes for ``partition``
        live on ``node``; re-recording after straggler re-execution simply
        overwrites the stale figure."""
        self._shuffle_bytes.setdefault(shuffle, {}) \
            .setdefault(partition, {})[node] = nbytes

    def shuffle_partition_bytes(self, shuffle: str,
                                partition: int) -> Dict[int, int]:
        return dict(self._shuffle_bytes.get(shuffle, {}).get(partition, {}))

    def total_shuffle_bytes(self, shuffle: str) -> int:
        return sum(b for part in self._shuffle_bytes.get(shuffle, {}).values()
                   for b in part.values())

    def clear_shuffle(self, shuffle: str) -> None:
        self._shuffle_bytes.pop(shuffle, None)
        # a finished job is an event boundary: its finalization-time pressure
        # snapshots no longer describe the cluster the next job plans against
        self.note_event()

    # -- topology/job event boundaries (pressure staleness) --------------------
    def note_event(self) -> None:
        """A topology or job event happened (node killed/recovered, set
        created/re-sharded, shuffle finished): previously recorded pressure
        snapshots are now stale."""
        self._event_seq += 1

    @property
    def event_seq(self) -> int:
        return self._event_seq

    def current_epoch(self) -> int:
        """Bound-method form of ``event_seq`` — handed to each node's page
        log as its ``epoch_fn``, so every durable log record is stamped with
        the topology/job event counter and replay can be fenced against the
        catalog (stale entries from before a drop/rebuild must not
        resurrect)."""
        return self._event_seq

    # -- per-node memory pressure (scheduler placement penalty) ----------------
    def record_node_pressure(self, node: int, score: float) -> None:
        self._node_pressure[node] = (max(0.0, min(1.0, float(score))),
                                     self._event_seq)

    def node_pressure(self, node: int) -> float:
        """Last recorded score regardless of age (freshness-agnostic view;
        placement uses ``node_pressure_fresh`` + a live fallback)."""
        return self._node_pressure.get(node, (0.0, 0))[0]

    def node_pressure_fresh(self, node: int) -> Optional[float]:
        """The recorded score, or None when it predates the last
        topology/job event (or was never recorded) — the caller should read
        the node's live ``MemoryManager.pressure_score()`` instead."""
        rec = self._node_pressure.get(node)
        if rec is None or rec[1] < self._event_seq:
            return None
        return rec[0]

    def node_pressure_map(self) -> Dict[int, float]:
        return {n: score for n, (score, _seq) in self._node_pressure.items()}

    def replicas_of(self, logical_name: str) -> List[ReplicaInfo]:
        return list(self._replicas.get(logical_name, []))

    def record_access(self, set_name: str) -> None:
        self._access_counts[set_name] = self._access_counts.get(set_name, 0) + 1

    def access_count(self, set_name: str) -> int:
        return self._access_counts.get(set_name, 0)

    def best_replica(self, logical_name: str,
                     desired_key: Optional[str]) -> Optional[ReplicaInfo]:
        """Pick the replica partitioned on ``desired_key`` if one exists
        (enables co-partitioned, shuffle-free joins — paper §9.2.2); fall back
        to any replica (source set first)."""
        replicas = self._replicas.get(logical_name, [])
        if not replicas:
            return None
        for r in replicas:
            if desired_key is not None and r.partition_key == desired_key:
                self.record_access(r.set_name)
                return r
        # prefer the unpartitioned source set as the generic fallback
        for r in replicas:
            if r.partition_key is None:
                self.record_access(r.set_name)
                return r
        self.record_access(replicas[0].set_name)
        return replicas[0]
