"""Shared-memory page-frame arena — the zero-copy data plane under the
multi-process cluster backend (``runtime/node_proc.py``).

Control messages between the driver and the per-node processes travel over a
length-prefixed socket (``runtime/rpc.py``); page payloads do NOT.  Each node
gets two arenas carved out of ``multiprocessing.shared_memory`` segments:

* an **inbox** the driver writes into (set creation / replica bytes), and
* an **outbox** the node process writes into (shuffle partition page images,
  set exports) that the driver *and sibling node processes* map read-only.

An arena is a single segment sliced into fixed-size frames.  Exactly one
process — the *allocator* — hands frames out and takes them back; every other
process only maps the segment and reads the frames named by a descriptor it
received over the control plane.  Descriptors are plain dicts (frame index
list + byte count), so they ride the JSON envelope with zero pickling.

Creation and unlinking are likewise owned by exactly one process (the
driver), regardless of who allocates: a SIGKILLed node process can never
leak a segment, because it never owned one.  ``segment_exists`` supports the
leak check the cluster runs on close.
"""
from __future__ import annotations

import secrets
from multiprocessing import resource_tracker, shared_memory
from typing import Dict, Iterable, List, Optional

import numpy as np

from .sanitizer import tracked_lock


class ArenaFullError(RuntimeError):
    """No run of free frames can hold the payload right now."""


def arena_name(tag: str) -> str:
    """A segment name unique across concurrent test runs on one host."""
    return f"pgea-{tag}-{secrets.token_hex(4)}"


def segment_exists(name: str) -> bool:
    """Whether a shared-memory segment of this name still exists (leak
    probe: attach read-only and immediately detach)."""
    try:
        seg = shared_memory.SharedMemory(name=name)
    except FileNotFoundError:
        return False
    # CPython < 3.13 registers even plain attaches with the resource
    # tracker, which would unlink the segment when *this* process exits.
    _untrack(seg)
    seg.close()
    return True


def _untrack(seg: shared_memory.SharedMemory) -> None:
    try:  # pragma: no cover - defensive; name mangling differs per version
        resource_tracker.unregister(seg._name, "shared_memory")
    except Exception:
        pass


class ShmArena:
    """Fixed-frame allocator over one shared-memory segment.

    ``create=True`` makes (and later unlinks) the segment; ``owner=True``
    runs the frame allocator.  The two are independent so the driver can
    create a node's outbox while the node process allocates from it.
    """

    def __init__(self, name: str, frame_size: int, num_frames: int,
                 *, create: bool = False, owner: bool = False):
        if frame_size <= 0 or num_frames <= 0:
            raise ValueError("arena needs positive frame_size and num_frames")
        self.name = name
        self.frame_size = int(frame_size)
        self.num_frames = int(num_frames)
        self.capacity = self.frame_size * self.num_frames
        self.owner = bool(owner)
        self.created = bool(create)
        self._seg = shared_memory.SharedMemory(
            name=name, create=create, size=self.capacity if create else 0)
        # CPython < 3.13 registers BOTH creates and attaches with the
        # resource tracker.  Forked node processes share the driver's
        # tracker, so any tracked registration would be double-counted
        # (noisy KeyErrors, premature unlinks).  Lifetime is managed
        # explicitly by the creator instead: untrack here, re-register
        # just before ``unlink`` so its internal unregister balances.
        _untrack(self._seg)
        self._buf = np.frombuffer(self._seg.buf, dtype=np.uint8,
                                  count=self.capacity)
        self._free: List[int] = list(range(self.num_frames)) if owner else []
        # allocator ops can come from concurrent driver threads (the
        # transfer engine ships shards in parallel)
        self._alloc_lock = tracked_lock("shm_arena")
        # Observability: the leak check wants in-use == 0 at close, the
        # benchmark wants peak occupancy.
        self.frames_in_use = 0
        self.peak_frames = 0
        self.puts = 0
        self.bytes_put = 0
        self._closed = False

    @classmethod
    def attach(cls, name: str, frame_size: int, num_frames: int,
               *, owner: bool = False) -> "ShmArena":
        """Map a segment some other process created.  ``owner=True`` means
        this process runs the allocator (a node process owning its outbox)."""
        return cls(name, frame_size, num_frames, create=False, owner=owner)

    # -- allocator side ----------------------------------------------------
    def put(self, payload) -> Dict[str, object]:
        """Copy ``payload`` (any buffer) into free frames; returns the
        JSON-able descriptor naming them.  Raises ArenaFullError when the
        payload cannot fit in the currently free frames."""
        if not self.owner:
            raise RuntimeError("only the arena owner can allocate frames")
        raw = np.frombuffer(payload, dtype=np.uint8)
        nbytes = raw.nbytes
        need = max(1, -(-nbytes // self.frame_size))
        with self._alloc_lock:
            if need > len(self._free):
                raise ArenaFullError(
                    f"arena {self.name}: need {need} frames, "
                    f"{len(self._free)} free")
            frames = [self._free.pop() for _ in range(need)]
            self.frames_in_use += need
            self.peak_frames = max(self.peak_frames, self.frames_in_use)
            self.puts += 1
            self.bytes_put += nbytes
        off = 0
        for f in frames:
            n = min(self.frame_size, nbytes - off)
            base = f * self.frame_size
            self._buf[base:base + n] = raw[off:off + n]
            off += n
        return {"frames": frames, "nbytes": nbytes}

    def free(self, desc: Dict[str, object]) -> None:
        if not self.owner:
            raise RuntimeError("only the arena owner can free frames")
        frames = list(desc["frames"])
        with self._alloc_lock:
            self._free.extend(frames)
            self.frames_in_use -= len(frames)

    def free_frames(self) -> int:
        with self._alloc_lock:
            return len(self._free)

    def reset_counters(self) -> None:
        """Zero the observability counters (``puts``/``bytes_put``/
        ``peak_frames``) so tests can assert per-test deltas on a shared
        arena; ``frames_in_use`` is live accounting and is left alone."""
        with self._alloc_lock:
            self.puts = 0
            self.bytes_put = 0
            self.peak_frames = self.frames_in_use

    # -- reader side (works for the owner too) -----------------------------
    def read(self, desc: Dict[str, object]) -> np.ndarray:
        """Gather a descriptor's bytes into one contiguous array (the single
        copy a cross-process page move pays on the read side)."""
        nbytes = int(desc["nbytes"])
        out = np.empty(nbytes, dtype=np.uint8)
        off = 0
        for f in desc["frames"]:
            n = min(self.frame_size, nbytes - off)
            base = int(f) * self.frame_size
            out[off:off + n] = self._buf[base:base + n]
            off += n
        return out

    def read_into(self, desc: Dict[str, object], out: np.ndarray) -> int:
        """Gather directly into ``out`` (e.g. a pinned pool page view)."""
        nbytes = int(desc["nbytes"])
        off = 0
        for f in desc["frames"]:
            n = min(self.frame_size, nbytes - off)
            base = int(f) * self.frame_size
            out[off:off + n] = self._buf[base:base + n]
            off += n
        return nbytes

    # -- lifecycle ---------------------------------------------------------
    def close(self) -> None:
        """Detach this process's mapping (never destroys the segment)."""
        if self._closed:
            return
        self._closed = True
        self._buf = None
        try:
            self._seg.close()
        except Exception:  # pragma: no cover
            pass

    def __del__(self) -> None:  # pragma: no cover - GC-order safety net
        # Drop the numpy view BEFORE the segment's own __del__ runs, else
        # an abandoned arena dies with "cannot close exported pointers".
        try:
            self.close()
        except Exception:
            pass

    def unlink(self) -> None:
        """Destroy the segment.  Only the creating process calls this."""
        if not self.created:
            raise RuntimeError("only the arena creator can unlink it")
        self.close()
        try:
            resource_tracker.register(self._seg._name, "shared_memory")
        except Exception:  # pragma: no cover
            pass
        try:
            self._seg.unlink()
        except FileNotFoundError:  # pragma: no cover
            pass


def gather(arena: Optional[ShmArena], desc: Optional[Dict[str, object]],
           raw: bytes) -> np.ndarray:
    """Uniform read side of the two payload channels: a shm descriptor when
    the arena had room, else the raw socket bytes that rode the envelope."""
    if desc is not None:
        if arena is None:
            raise RuntimeError("descriptor received but no arena attached")
        return arena.read(desc)
    return np.frombuffer(bytearray(raw), dtype=np.uint8)
