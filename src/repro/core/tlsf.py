"""Two-Level Segregated Fit (TLSF) allocator — paper §5.

Pangea "by default uses the two-level segregated fit (TLSF) memory allocator to
allocate variable-sized pages from the shared memory". This is a faithful
reimplementation over a contiguous byte arena: first-level bins are power-of-two
size classes, each subdivided into ``2**SL_BITS`` linear second-level bins.
Free blocks carry boundary tags so coalescing with both neighbours is O(1);
find-suitable-block is O(1) via the two bitmap levels.

The arena itself is just byte accounting — callers receive ``(offset, size)``
and take numpy views into the pool's shared arena (the mmap-shared-memory
analogue from the paper).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

SL_BITS = 4  # 16 second-level subdivisions per first-level class
SL_COUNT = 1 << SL_BITS
MIN_BLOCK = 64  # bytes; everything is rounded up to this granularity


def _fls(x: int) -> int:
    """Index of the highest set bit (find-last-set)."""
    return x.bit_length() - 1


def _ffs(x: int) -> int:
    """Index of the lowest set bit (find-first-set); -1 if zero."""
    return (x & -x).bit_length() - 1


def _mapping(size: int) -> Tuple[int, int]:
    """size -> (first-level index, second-level index)."""
    fl = _fls(size)
    if fl < SL_BITS:
        return 0, size >> 1  # tiny sizes collapse into class 0
    sl = (size >> (fl - SL_BITS)) - SL_COUNT
    return fl - SL_BITS + 1, sl


@dataclass
class _Block:
    offset: int
    size: int
    free: bool
    prev_phys: Optional[int] = None  # offset of physically-previous block
    next_phys: Optional[int] = None
    prev_free: Optional[int] = None  # free-list links (offsets)
    next_free: Optional[int] = None


class TLSF:
    """TLSF allocator over ``capacity`` bytes. alloc() -> offset, free(offset)."""

    def __init__(self, capacity: int):
        if capacity < MIN_BLOCK:
            raise ValueError(f"capacity {capacity} < MIN_BLOCK {MIN_BLOCK}")
        self.capacity = capacity
        self._blocks: Dict[int, _Block] = {}
        fl_max, _ = _mapping(capacity)
        self._nfl = fl_max + 2
        self._fl_bitmap = 0
        self._sl_bitmap = [0] * self._nfl
        self._free_heads: Dict[Tuple[int, int], Optional[int]] = {}
        root = _Block(0, capacity, free=True)
        self._blocks[0] = root
        self._insert_free(root)
        self.allocated_bytes = 0

    # -- free-list bookkeeping ------------------------------------------------
    def _insert_free(self, b: _Block) -> None:
        fl, sl = _mapping(b.size)
        head = self._free_heads.get((fl, sl))
        b.prev_free = None
        b.next_free = head
        if head is not None:
            self._blocks[head].prev_free = b.offset
        self._free_heads[(fl, sl)] = b.offset
        self._fl_bitmap |= 1 << fl
        self._sl_bitmap[fl] |= 1 << sl

    def _remove_free(self, b: _Block) -> None:
        fl, sl = _mapping(b.size)
        if b.prev_free is not None:
            self._blocks[b.prev_free].next_free = b.next_free
        else:
            self._free_heads[(fl, sl)] = b.next_free
        if b.next_free is not None:
            self._blocks[b.next_free].prev_free = b.prev_free
        if self._free_heads.get((fl, sl)) is None:
            self._sl_bitmap[fl] &= ~(1 << sl)
            if self._sl_bitmap[fl] == 0:
                self._fl_bitmap &= ~(1 << fl)
        b.prev_free = b.next_free = None

    def _find_suitable(self, size: int) -> Optional[_Block]:
        fl, sl = _mapping(size)
        # search current fl for sl' >= sl, else any block in a higher fl
        sl_map = self._sl_bitmap[fl] & (~0 << sl) if fl < self._nfl else 0
        if sl_map == 0:
            fl_map = self._fl_bitmap & (~0 << (fl + 1))
            if fl_map == 0:
                return None
            fl = _ffs(fl_map)
            sl_map = self._sl_bitmap[fl]
        sl = _ffs(sl_map)
        off = self._free_heads.get((fl, sl))
        return self._blocks[off] if off is not None else None

    # -- public API -----------------------------------------------------------
    def alloc(self, size: int) -> Optional[int]:
        """Allocate ``size`` bytes; returns arena offset or None if exhausted."""
        size = max(MIN_BLOCK, (size + MIN_BLOCK - 1) // MIN_BLOCK * MIN_BLOCK)
        b = self._find_suitable(size)
        if b is None or b.size < size:
            return None
        self._remove_free(b)
        if b.size - size >= MIN_BLOCK:  # split; remainder stays free
            rem = _Block(b.offset + size, b.size - size, free=True,
                         prev_phys=b.offset, next_phys=b.next_phys)
            if b.next_phys is not None:
                self._blocks[b.next_phys].prev_phys = rem.offset
            b.next_phys = rem.offset
            b.size = size
            self._blocks[rem.offset] = rem
            self._insert_free(rem)
        b.free = False
        self.allocated_bytes += b.size
        return b.offset

    def free(self, offset: int) -> None:
        b = self._blocks.get(offset)
        if b is None or b.free:
            raise ValueError(f"double/invalid free at offset {offset}")
        b.free = True
        self.allocated_bytes -= b.size
        # coalesce with physical next
        if b.next_phys is not None:
            nxt = self._blocks[b.next_phys]
            if nxt.free:
                self._remove_free(nxt)
                b.size += nxt.size
                b.next_phys = nxt.next_phys
                if nxt.next_phys is not None:
                    self._blocks[nxt.next_phys].prev_phys = b.offset
                del self._blocks[nxt.offset]
        # coalesce with physical prev
        if b.prev_phys is not None:
            prv = self._blocks[b.prev_phys]
            if prv.free:
                self._remove_free(prv)
                prv.size += b.size
                prv.next_phys = b.next_phys
                if b.next_phys is not None:
                    self._blocks[b.next_phys].prev_phys = prv.offset
                del self._blocks[b.offset]
                b = prv
        self._insert_free(b)

    def block_size(self, offset: int) -> int:
        return self._blocks[offset].size

    @property
    def free_bytes(self) -> int:
        return self.capacity - self.allocated_bytes

    def check_invariants(self) -> None:
        """Debug/property-test hook: arena fully tiled, no adjacent free blocks."""
        off, total, prev = 0, 0, None
        while True:
            b = self._blocks[off]
            assert b.offset == off and b.prev_phys == prev
            if prev is not None:
                pb = self._blocks[prev]
                assert not (pb.free and b.free), "uncoalesced neighbours"
            total += b.size
            prev = off
            if b.next_phys is None:
                break
            off = b.next_phys
        assert total == self.capacity, f"arena leak: {total} != {self.capacity}"
