"""Durable per-node page tier — append-only page log + consistent-hash index.

The ``SpillStore`` is scratch: it exists to absorb eviction bursts and dies
with its node. This module adds the tier *below* it, the one Pangea's
"monolithic storage for all data" thesis actually needs for long-lived sets:

* an **append-only page log** (``pages.log`` in the node's durable
  directory) — every write-through page image is appended as a checksummed
  record ``[magic | crc32 | epoch | seq | name_len | payload_len | flags |
  set name | payload]``. Appends never seek; a page rewritten later simply
  appends a superseding record for the same ``(set, seq)`` key;
* a **consistent-hash page index** — live entries are bucketed by hashing
  the owning set's name onto a virtual-node ring, so the index can grow its
  bucket count (or, later, split across index files) while relocating only
  the sets whose ring interval moved. Lookup is ``(set name, page seq) ->
  (file offset, length, epoch, payload crc)``;
* **epoch stamping** — every record carries the cluster's topology/job event
  counter (``StatisticsDB.event_seq`` via ``epoch_fn``) at append time.
  Replay after a restart compares a set's newest log epoch against the
  catalog's shard epoch and *fences* stale state: entries logged before a
  shard was dropped or rebuilt elsewhere must not resurrect;
* **torn-tail truncation** — replay walks the log verifying each record's
  CRC32; the first short or corrupt record marks a tail torn by a crash
  mid-append, and the file is truncated back to the last good record.

A restarted ``StorageNode`` warm-starts by replaying its local index
(``PageLog.__init__`` does the replay; ``BufferPool.adopt_durable_set``
turns live entries back into non-resident pages that fault in on demand),
and ``scheduler.recovery_plan`` costs "read the local page log" against
"pull replica bytes over the wire".
"""
from __future__ import annotations

import bisect
import hashlib
import os
import struct
import threading
import zlib
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

from . import sanitizer
from .sanitizer import tracked_lock, tracked_rlock

MAGIC = 0x50474C31  # "PGL1"
# magic u32 | crc u32 | epoch i64 | seq i64 | name_len u16 | payload_len u32
# | flags u8 — crc covers everything after itself (tail + name + payload)
_HEADER = struct.Struct("<IIqqHIB")
_TAIL = struct.Struct("<qqHIB")

FLAG_DATA = 0
FLAG_TOMBSTONE = 1   # drops every prior entry of the named set
FLAG_RENAME = 2      # payload = old set name; entries move to the new name
FLAG_GENERATION = 3  # payload = u64 generation number; first record of a
#                      compacted log file (never indexed)

LOG_FILENAME = "pages.log"
COMPACT_TMP_FILENAME = "pages.log.compact"

# Durability-vs-throughput knob (ROADMAP §4 follow-up). ``none`` preserves
# the original behavior: records are flushed to the OS but never fsync'd
# (a machine crash may lose the tail; replay's torn-tail truncation makes
# that safe, and replicas remain the durability truth). ``close`` syncs
# once when the log is closed, ``group`` batches one sync per
# ``group_bytes`` of appended records, ``always`` syncs every append.
FSYNC_POLICIES = ("none", "close", "group", "always")


def _hash64(key: str) -> int:
    return int.from_bytes(
        hashlib.blake2b(key.encode("utf-8"), digest_size=8).digest(), "little")


@dataclass
class PageLogEntry:
    """One live page image in the log: where it sits and how to verify it."""

    name: str
    seq: int
    epoch: int
    offset: int          # file offset of the payload bytes
    length: int
    payload_crc: int


class ConsistentHashIndex:
    """The page index: live entries bucketed by consistent-hashing the set
    name onto a virtual-node ring. All of one set's pages share a bucket, so
    set-granular operations (drop, rename, epoch query) touch one bucket,
    and growing the bucket count relocates only the sets whose ring interval
    moved — the property a future multi-file index needs."""

    def __init__(self, num_buckets: int = 16, vnodes: int = 8):
        self.num_buckets = num_buckets
        ring: List[Tuple[int, int]] = []
        for b in range(num_buckets):
            for v in range(vnodes):
                ring.append((_hash64(f"bucket{b}#vnode{v}"), b))
        ring.sort()
        self._points = [p for p, _ in ring]
        self._owners = [b for _, b in ring]
        self._buckets: List[Dict[Tuple[str, int], PageLogEntry]] = [
            {} for _ in range(num_buckets)]

    def bucket_of(self, name: str) -> int:
        i = bisect.bisect_right(self._points, _hash64(name))
        return self._owners[i % len(self._owners)]

    def put(self, entry: PageLogEntry) -> None:
        bucket = self._buckets[self.bucket_of(entry.name)]
        bucket[(entry.name, entry.seq)] = entry

    def get(self, name: str, seq: int) -> Optional[PageLogEntry]:
        return self._buckets[self.bucket_of(name)].get((name, seq))

    def entries_for(self, name: str) -> List[PageLogEntry]:
        bucket = self._buckets[self.bucket_of(name)]
        return sorted((e for (n, _), e in bucket.items() if n == name),
                      key=lambda e: e.seq)

    def drop_set(self, name: str) -> int:
        bucket = self._buckets[self.bucket_of(name)]
        victims = [k for k in bucket if k[0] == name]
        for k in victims:
            del bucket[k]
        return len(victims)

    def rename_set(self, old: str, new: str) -> int:
        entries = self.entries_for(old)
        self.drop_set(old)
        for e in entries:
            e.name = new
            self.put(e)
        return len(entries)

    def set_names(self) -> List[str]:
        names = {n for bucket in self._buckets for (n, _) in bucket}
        return sorted(names)

    def __len__(self) -> int:
        return sum(len(b) for b in self._buckets)


class PageLog:
    """One node's durable page tier. Thread-safe (engine workers append
    concurrently with pool faults). Construction replays the on-disk log
    into the index, truncating any torn tail, so a freshly opened PageLog
    *is* the warm-start state."""

    def __init__(self, directory: str,
                 epoch_fn: Optional[Callable[[], int]] = None,
                 index_buckets: int = 16,
                 fsync_policy: str = "none",
                 group_bytes: int = 1 << 20,
                 compact_threshold: Optional[float] = None,
                 compact_min_bytes: int = 256 << 10,
                 compact_interval_s: Optional[float] = None):
        if fsync_policy not in FSYNC_POLICIES:
            raise ValueError(f"fsync_policy must be one of {FSYNC_POLICIES}, "
                             f"got {fsync_policy!r}")
        if compact_threshold is not None and compact_threshold <= 1.0:
            raise ValueError("compact_threshold is a file/live amplification "
                             "ratio and must be > 1.0")
        self.directory = directory
        self.epoch_fn = epoch_fn
        self.fsync_policy = fsync_policy
        self.group_bytes = group_bytes
        self.compact_threshold = compact_threshold
        self.compact_min_bytes = compact_min_bytes
        os.makedirs(directory, exist_ok=True)
        self.path = os.path.join(directory, LOG_FILENAME)
        self.index = ConsistentHashIndex(index_buckets)
        self._lock = tracked_rlock("pagelog")
        # fsync order: _fsync_lock -> _lock, never the reverse.  The index
        # lock is released before the disk sync so readers of other sets do
        # not stall behind an appender's fsync; _fsync_lock serialises the
        # tail syncs themselves (two appenders must not double-count one
        # batched sync).
        self._fsync_lock = tracked_lock("pagelog.fsync")
        self._append_fh = None
        self._read_fh = None
        self._next_seq: Dict[str, int] = {}
        self.bytes_appended = 0
        self.fsync_count = 0     # observable: tests assert group batching
        self._unsynced = 0       # bytes appended since the last fsync
        self.report: Dict[str, int] = {}
        # Compaction state (ROADMAP §4 follow-up): superseded/tombstoned
        # records otherwise accumulate forever.  ``generation`` counts
        # rewrites; live/file byte counters feed the amplification trigger.
        self.generation = 0
        self.compactions = 0
        self.compaction_bytes = 0   # bytes rewritten by compaction passes
        self.last_compaction: Dict[str, int] = {}
        self._live_bytes = 0
        self._file_bytes = 0
        self._compactor: Optional[threading.Thread] = None
        self._compactor_stop = threading.Event()
        self._replay()
        if compact_interval_s is not None:
            self.start_compactor(compact_interval_s)

    # -- replay / torn-tail truncation ----------------------------------------
    def _replay(self) -> None:
        report = {"records": 0, "data": 0, "tombstones": 0, "renames": 0,
                  "truncated_bytes": 0, "crc_failures": 0}
        if os.path.exists(self.path):
            good_end, records = scan_log(self.path, self.index, report)
            file_len = os.path.getsize(self.path)
            if good_end < file_len:
                # torn tail: a crash mid-append left a short or corrupt
                # record; everything before it is intact, so cut there
                report["truncated_bytes"] = file_len - good_end
                with open(self.path, "r+b") as f:
                    f.truncate(good_end)
            for name in self.index.set_names():
                entries = self.index.entries_for(name)
                self._next_seq[name] = entries[-1].seq + 1 if entries else 0
            self.generation = report.get("generation", 0)
            self._file_bytes = os.path.getsize(self.path)
            self._live_bytes = sum(
                _record_size(e.name, e.length)
                for name in self.index.set_names()
                for e in self.index.entries_for(name))
        report["live_entries"] = len(self.index)
        report["live_sets"] = len(self.index.set_names())
        self.report = report

    # -- write path ------------------------------------------------------------
    def _epoch(self) -> int:
        return self.epoch_fn() if self.epoch_fn is not None else 0

    def _append_record(self, name: str, payload: bytes, seq: int,
                       flags: int, epoch: Optional[int] = None) -> int:
        """Append one record; returns the payload's file offset.  ``epoch``
        defaults to the live counter; compaction passes the original record's
        epoch so rewriting never un-fences stale state."""
        nb = name.encode("utf-8")
        if epoch is None:
            epoch = self._epoch()
        record = _pack_record(nb, payload, seq, flags, epoch)
        if self._append_fh is None:
            self._append_fh = open(self.path, "ab")
        fh = self._append_fh
        start = fh.tell()
        fh.write(record)
        fh.flush()
        nbytes = len(record)
        self.bytes_appended += nbytes
        self._file_bytes += nbytes
        self._unsynced += nbytes
        return start + _HEADER.size + len(nb), epoch

    def _sync_tail(self, force: bool = False) -> None:
        """Fsync the unsynced tail if the policy says it is due.  Called by
        the public mutators *after* releasing the index lock: a reader of an
        unrelated set must never stall behind an appender's disk sync."""
        with self._fsync_lock:
            with self._lock:
                fh = self._append_fh
                due = fh is not None and self._unsynced and (
                    force
                    or self.fsync_policy == "always"
                    or (self.fsync_policy == "group"
                        and self._unsynced >= self.group_bytes))
                if not due:
                    return
                pending = self._unsynced
            with sanitizer.blocking_region("pagelog.fsync",
                                           allow=("pagelog.fsync",)):
                try:
                    # pangea: allow(R3): tail sync holds only pagelog.fsync; the index lock was released above
                    os.fsync(fh.fileno())
                except (ValueError, OSError):
                    # A concurrent compact() swapped and closed the append
                    # handle; the compacted file was fsynced whole, so the
                    # tail this call meant to sync is already durable.
                    return
            with self._lock:
                self.fsync_count += 1
                self._unsynced = max(0, self._unsynced - pending)

    def next_seq(self, name: str) -> int:
        with self._lock:
            return self._next_seq.get(name, 0)

    def append(self, name: str, payload: bytes,
               seq: Optional[int] = None) -> PageLogEntry:
        """Append one page image for ``(name, seq)``. Re-appending an
        existing seq supersedes the prior image (the index keeps only the
        newest); seq=None allocates the set's next sequence number."""
        with self._lock:
            if seq is None:
                seq = self._next_seq.get(name, 0)
            prior = self.index.get(name, seq)
            offset, epoch = self._append_record(name, payload, seq, FLAG_DATA)
            self._next_seq[name] = max(self._next_seq.get(name, 0), seq + 1)
            entry = PageLogEntry(name=name, seq=seq, epoch=epoch,
                                 offset=offset, length=len(payload),
                                 payload_crc=zlib.crc32(payload) & 0xFFFFFFFF)
            self.index.put(entry)
            if prior is not None:
                self._live_bytes -= _record_size(name, prior.length)
            self._live_bytes += _record_size(name, len(payload))
            self.maybe_compact()
        self._sync_tail()
        return entry

    def drop_set(self, name: str) -> None:
        """Tombstone a set: replay will not resurrect its entries."""
        with self._lock:
            entries = self.index.entries_for(name)
            if not entries:
                return  # never logged (or already tombstoned): nothing to cut
            self._append_record(name, b"", 0, FLAG_TOMBSTONE)
            self.index.drop_set(name)
            self._next_seq.pop(name, None)
            self._live_bytes -= sum(_record_size(name, e.length)
                                    for e in entries)
            self.maybe_compact()
        self._sync_tail()

    def rename_set(self, old: str, new: str) -> None:
        """Re-key a set's entries in O(1) log bytes: a rename record whose
        payload is the old name; data records are not rewritten."""
        with self._lock:
            entries = self.index.entries_for(old)
            if not entries:
                return
            self._append_record(new, old.encode("utf-8"), 0, FLAG_RENAME)
            self.index.rename_set(old, new)
            self._next_seq[new] = self._next_seq.pop(old, 0)
            delta = len(new.encode("utf-8")) - len(old.encode("utf-8"))
            self._live_bytes += delta * len(entries)
            self.maybe_compact()
        self._sync_tail()

    # -- read path ---------------------------------------------------------------
    def read(self, name: str, seq: int) -> bytes:
        """Read and CRC-verify one live page image."""
        with self._lock:
            entry = self.index.get(name, seq)
            if entry is None:
                raise KeyError(f"page log has no entry for {name!r} seq {seq}")
            if self._read_fh is None:
                self._read_fh = open(self.path, "rb")
            self._read_fh.seek(entry.offset)
            payload = self._read_fh.read(entry.length)
        if (len(payload) != entry.length
                or zlib.crc32(payload) & 0xFFFFFFFF != entry.payload_crc):
            raise IOError(
                f"page log corruption: {name!r} seq {seq} failed CRC")
        return payload

    def entries_for(self, name: str) -> List[PageLogEntry]:
        with self._lock:
            return self.index.entries_for(name)

    def set_names(self) -> List[str]:
        with self._lock:
            return self.index.set_names()

    def set_epoch(self, name: str) -> int:
        """Newest epoch across a set's live entries (-1 when absent) — what
        replay fencing compares against the catalog's shard epoch."""
        with self._lock:
            entries = self.index.entries_for(name)
            return max((e.epoch for e in entries), default=-1)

    def set_bytes(self, name: str) -> int:
        with self._lock:
            return sum(e.length for e in self.index.entries_for(name))

    # -- compaction (ROADMAP §4 follow-up) ----------------------------------
    def live_bytes(self) -> int:
        with self._lock:
            return self._live_bytes

    def file_bytes(self) -> int:
        with self._lock:
            return self._file_bytes

    def amplification(self) -> float:
        """File bytes over live-record bytes — 1.0 is a perfectly compact
        log; superseded images, tombstoned sets, and rename markers all push
        it up."""
        with self._lock:
            return self._file_bytes / max(1, self._live_bytes)

    def compact(self) -> Dict[str, int]:
        """Rewrite the live records into a new generation file and atomically
        swap it in (``os.replace``).  The new file opens with a generation
        record, then every live page image in (set, seq) order with its
        original epoch and seq — so fencing, warm restore, and ``read()``
        behave identically before and after.  Readers never see a partial
        file: the swap is the commit point, and a crash before it leaves the
        old log untouched (plus a stale ``pages.log.compact`` that the next
        compaction overwrites and ``fsck`` reports)."""
        with self._lock:
            before = self._file_bytes
            tmp = os.path.join(self.directory, COMPACT_TMP_FILENAME)
            new_gen = self.generation + 1
            rewritten = 0
            with open(tmp, "wb") as out:
                out.write(_pack_record(b"", struct.pack("<Q", new_gen),
                                       0, FLAG_GENERATION, self._epoch()))
                for name in self.index.set_names():
                    nb = name.encode("utf-8")
                    for e in self.index.entries_for(name):
                        payload = self.read(name, e.seq)
                        out.write(_pack_record(nb, payload, e.seq,
                                               FLAG_DATA, e.epoch))
                        rewritten += 1
                out.flush()
                # pangea: allow(R3): compaction is a whole-file rewrite; it must commit under the index lock so readers never see a half-swapped index
                os.fsync(out.fileno())
            # swap + reopen: handles point at the old inode until replaced
            if self._append_fh is not None:
                self._append_fh.close()
                self._append_fh = None
            if self._read_fh is not None:
                self._read_fh.close()
                self._read_fh = None
            os.replace(tmp, self.path)
            try:
                dirfd = os.open(self.directory, os.O_RDONLY)
                try:
                    # pangea: allow(R3): directory fsync is part of the same atomic swap commit point as the file fsync above
                    os.fsync(dirfd)
                finally:
                    os.close(dirfd)
            except OSError:  # pragma: no cover - platform without dir fsync
                pass
            # offsets all moved: rebuild the index from the new file.  The
            # rewrite was fsynced whole, so any tail _sync_tail() still owed
            # is already durable.
            self._unsynced = 0
            self.index = ConsistentHashIndex(self.index.num_buckets)
            scan_log(self.path, self.index, {})
            self.generation = new_gen
            self._file_bytes = os.path.getsize(self.path)
            self._live_bytes = sum(
                _record_size(e.name, e.length)
                for name in self.index.set_names()
                for e in self.index.entries_for(name))
            self.compactions += 1
            self.compaction_bytes += self._file_bytes
            self.last_compaction = {
                "generation": new_gen, "records": rewritten,
                "before_bytes": before, "after_bytes": self._file_bytes}
            return dict(self.last_compaction)

    def maybe_compact(self) -> bool:
        """Amplification-triggered compaction: runs when the knob is set,
        the file is past the minimum size, and file/live exceeds the
        threshold.  Called after every mutating append (and periodically by
        the background compactor thread)."""
        if self.compact_threshold is None:
            return False
        with self._lock:
            if (self._file_bytes < self.compact_min_bytes
                    or self.amplification() <= self.compact_threshold):
                return False
            self.compact()
            return True

    def start_compactor(self, interval_s: float) -> None:
        """Background amplification sweeps — for nodes whose write paths
        should never pay the rewrite inline."""
        if self._compactor is not None:
            return
        self._compactor_stop.clear()

        def loop() -> None:
            while not self._compactor_stop.wait(interval_s):
                try:
                    self.maybe_compact()
                except Exception:  # pragma: no cover - keep sweeping
                    pass

        self._compactor = threading.Thread(
            target=loop, name="pagelog-compactor", daemon=True)
        self._compactor.start()

    def stop_compactor(self) -> None:
        if self._compactor is None:
            return
        self._compactor_stop.set()
        self._compactor.join(timeout=5.0)
        self._compactor = None

    def close(self) -> None:
        """Close file handles; the log FILES stay — that is the point of the
        durable tier (``SpillStore.clear`` has no analogue here). The
        ``close`` and ``group`` fsync policies drain any unsynced tail here
        so a clean shutdown is durable."""
        self.stop_compactor()
        if self.fsync_policy in ("close", "group"):
            self._sync_tail(force=True)
        with self._lock:
            if self._append_fh is not None:
                self._append_fh.close()
                self._append_fh = None
            if self._read_fh is not None:
                self._read_fh.close()
                self._read_fh = None


def _record_size(name: str, payload_len: int) -> int:
    return _HEADER.size + len(name.encode("utf-8")) + payload_len


def _pack_record(name_bytes: bytes, payload: bytes, seq: int, flags: int,
                 epoch: int) -> bytes:
    """The one wire format: header (magic + crc over tail/name/payload),
    name, payload — shared by the live append path and compaction."""
    tail = _TAIL.pack(epoch, seq, len(name_bytes), len(payload), flags)
    crc = zlib.crc32(tail)
    crc = zlib.crc32(name_bytes, crc)
    crc = zlib.crc32(payload, crc) & 0xFFFFFFFF
    return struct.pack("<II", MAGIC, crc) + tail + name_bytes + payload


def scan_log(path: str, index: Optional[ConsistentHashIndex],
             report: Dict[str, int]) -> Tuple[int, int]:
    """Walk one log file record by record, CRC-verifying each; optionally
    applying data/tombstone/rename records to ``index``. Returns
    ``(offset_after_last_good_record, records_seen)``. Shared by replay
    (which then truncates the torn tail) and ``fsck`` (read-only)."""
    good_end = 0
    records = 0
    with open(path, "rb") as f:
        data = f.read()
    pos = 0
    while pos + _HEADER.size <= len(data):
        magic, crc, epoch, seq, name_len, payload_len, flags = \
            _HEADER.unpack_from(data, pos)
        if magic != MAGIC:
            report["crc_failures"] = report.get("crc_failures", 0) + 1
            break
        end = pos + _HEADER.size + name_len + payload_len
        if end > len(data):
            break  # short record: torn tail
        body = data[pos + 8:end]  # everything the crc covers
        if zlib.crc32(body) & 0xFFFFFFFF != crc:
            report["crc_failures"] = report.get("crc_failures", 0) + 1
            break
        name = data[pos + _HEADER.size:
                    pos + _HEADER.size + name_len].decode("utf-8")
        payload_off = pos + _HEADER.size + name_len
        records += 1
        report["records"] = report.get("records", 0) + 1
        if flags == FLAG_GENERATION:
            report["generations"] = report.get("generations", 0) + 1
            if payload_len == 8:
                report["generation"] = struct.unpack_from(
                    "<Q", data, payload_off)[0]
        elif flags == FLAG_TOMBSTONE:
            report["tombstones"] = report.get("tombstones", 0) + 1
            if index is not None:
                index.drop_set(name)
        elif flags == FLAG_RENAME:
            report["renames"] = report.get("renames", 0) + 1
            if index is not None:
                old = data[payload_off:payload_off + payload_len].decode(
                    "utf-8")
                index.rename_set(old, name)
        else:
            report["data"] = report.get("data", 0) + 1
            if index is not None:
                payload = data[payload_off:payload_off + payload_len]
                index.put(PageLogEntry(
                    name=name, seq=seq, epoch=epoch, offset=payload_off,
                    length=payload_len,
                    payload_crc=zlib.crc32(payload) & 0xFFFFFFFF))
        pos = end
        good_end = pos
    return good_end, records


def fsck(directory: str) -> Dict[str, object]:
    """Read-only health check of one page-log directory (``tools/
    pagelog_fsck.py`` is the CLI). Reports record counts, live sets after
    applying tombstones/renames, and any torn tail — without truncating."""
    path = os.path.join(directory, LOG_FILENAME)
    out: Dict[str, object] = {"directory": directory, "exists": False}
    if not os.path.exists(path):
        return out
    report: Dict[str, int] = {}
    index = ConsistentHashIndex()
    good_end, _records = scan_log(path, index, report)
    file_len = os.path.getsize(path)
    out.update(report)
    out["exists"] = True
    out["file_bytes"] = file_len
    out["torn_tail_bytes"] = file_len - good_end
    out["live_entries"] = len(index)
    out["live_sets"] = index.set_names()
    out["generation"] = report.get("generation", 0)
    live = sum(_record_size(e.name, e.length)
               for name in index.set_names()
               for e in index.entries_for(name))
    out["live_bytes"] = live
    out["amplification"] = round(file_len / max(1, live), 4)
    # A generation record is written first by compaction; one appearing
    # later means files were concatenated or corrupted.
    gen_ok = True
    if report.get("generations", 0) > 1:
        gen_ok = False
    out["stale_compact_tmp"] = os.path.exists(
        os.path.join(directory, COMPACT_TMP_FILENAME))
    out["clean"] = (good_end == file_len
                    and report.get("crc_failures", 0) == 0
                    and gen_ok)
    return out
