"""Pangea core — the paper's contribution: locality sets, the unified buffer
pool, data-aware paging (Alg. 1 / Eq. 1), heterogeneous replication, and the
pushed-down services."""
from .attributes import (AttributeSet, CurrentOperation, DurabilityType,
                         EvictionStrategy, Lifetime, Location, ReadingPattern,
                         WritingPattern, eviction_ratio, select_strategy,
                         spilling_cost)
from .buffer_pool import BufferPool, PoolExhaustedError, SpillStore
from .kvcache import HBMExhaustedError, HostSlabStore, PagedKVCache
from .locality_set import LocalitySet, Page
from .memory_manager import (AdmissionController, MemoryManager,
                             MemoryReservation, derive_staging_cap)
from .paging import PagingSystem, eviction_overhead
from .replication import (DistributedSet, PartitionScheme, ReplicaRegistration,
                          combine_content_checksums, expected_conflicts,
                          fail_node, partition_set, random_dispatch,
                          record_content_checksum, recover_source_shard,
                          recover_target_shard, register_replica,
                          replica_nodes, shard_checksum)
from .services import (HashService, JoinService, PageIterator,
                       SequentialWriter, ShuffleService, VirtualShuffleBuffer,
                       as_record_bytes, canonical_join_sort, from_record_bytes,
                       get_page_iterators, job_data_attrs, join_output_dtype,
                       join_records, join_service, read_all)
from .statistics import ReplicaInfo, StatisticsDB
from .tlsf import TLSF

__all__ = [
    "AdmissionController", "derive_staging_cap",
    "AttributeSet", "BufferPool", "CurrentOperation", "DistributedSet",
    "DurabilityType", "EvictionStrategy", "HBMExhaustedError", "HashService",
    "HostSlabStore",
    "Lifetime", "LocalitySet", "Location", "MemoryManager",
    "MemoryReservation", "Page", "PagedKVCache",
    "PageIterator", "PagingSystem", "PartitionScheme", "PoolExhaustedError",
    "ReadingPattern", "ReplicaInfo", "ReplicaRegistration", "SequentialWriter",
    "ShuffleService", "SpillStore", "StatisticsDB", "TLSF",
    "VirtualShuffleBuffer", "WritingPattern", "eviction_overhead",
    "eviction_ratio", "expected_conflicts", "fail_node", "get_page_iterators",
    "as_record_bytes", "from_record_bytes", "job_data_attrs", "JoinService",
    "canonical_join_sort", "join_output_dtype", "join_records",
    "join_service", "partition_set", "random_dispatch", "read_all",
    "replica_nodes", "shard_checksum", "record_content_checksum",
    "combine_content_checksums",
    "recover_source_shard", "recover_target_shard", "register_replica",
    "select_strategy", "spilling_cost",
]
