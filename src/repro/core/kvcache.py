"""Paged KV cache — the buffer-pool abstraction applied to serving HBM.

Pangea's thesis is that one manager should own *all* memory. On the serving
path the contested memory is HBM holding KV pages. This module manages a
preallocated device page pool with the same locality-set machinery as the host
buffer pool:

* each sequence is a locality set of KV pages (write-back, random-read →
  LRU within the set, Table-3 spilling cost 5.0);
* Eq. 1 orders sequences for eviction: finished sequences (lifetime-ended)
  first, then cold sequences (stale ``t_r``), exactly the paper's dynamic
  priority;
* evicted pages are offloaded HBM→host (on this CPU container: a numpy store;
  on TPU: ``jax.device_put(..., memory_kind="pinned_host")``) and restored on
  demand.

The host side is pluggable: ``HostSlabStore`` is the flat dict default, and
``runtime/serving.py`` substitutes a tiered store that charges the node's
``MemoryManager`` and overflows to a remote node (level-3 spill) through the
``TransferEngine`` — the three-level hierarchy HBM → host pool → remote node.

The device half (attention over the page pool) is ``kernels/paged_attention``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from .attributes import (AttributeSet, CurrentOperation, DurabilityType,
                         Lifetime, ReadingPattern, WritingPattern)
from .locality_set import LocalitySet, Page
from .paging import PagingSystem


def kv_attrs() -> AttributeSet:
    return AttributeSet(
        durability=DurabilityType.WRITE_BACK,
        writing=WritingPattern.RANDOM_MUTABLE_WRITE,
        reading=ReadingPattern.RANDOM_READ,
    )


class HBMExhaustedError(MemoryError):
    pass


class HostSlabStore:
    """Level-2 host store for offloaded KV page slabs.

    The default is a flat in-memory dict.  The interface is deliberately
    small so a tiered implementation (host pool with a budget that overflows
    to a remote node) can slot in without the cache knowing:

    * ``put(pid, slab)``   — offload accepted this slab (may raise to refuse);
    * ``take(pid)``        — remove + return the slab for restore (None if the
      page was never offloaded);
    * ``peek(pid)``        — read without removing (replication / asserts);
    * ``discard(pid)``     — the sequence finished; drop any copy.
    """

    def __init__(self) -> None:
        self._slabs: Dict[int, np.ndarray] = {}

    def put(self, page_id: int, slab: np.ndarray) -> None:
        self._slabs[page_id] = slab

    def take(self, page_id: int) -> Optional[np.ndarray]:
        return self._slabs.pop(page_id, None)

    def peek(self, page_id: int) -> Optional[np.ndarray]:
        return self._slabs.get(page_id)

    def discard(self, page_id: int) -> None:
        self._slabs.pop(page_id, None)

    def __contains__(self, page_id: int) -> bool:
        return page_id in self._slabs

    def __len__(self) -> int:
        return len(self._slabs)


@dataclass
class SeqState:
    seq_id: int
    length: int = 0                    # tokens written
    page_ids: List[int] = field(default_factory=list)  # logical pages, in order


class PagedKVCache:
    """Page-granular KV storage for one model (all layers share page geometry).

    Physical layout (device): ``kv[L, P, page_size, 2, kv_heads, head_dim]``
    where P = hbm_pages. Logical pages beyond P live in the host store.
    ``block_table(seq)`` yields physical slots for the attention kernel.
    """

    def __init__(self, num_layers: int, hbm_pages: int, page_size: int,
                 kv_heads: int, head_dim: int, dtype=np.float32,
                 host_store: Optional[HostSlabStore] = None):
        import jax.numpy as jnp  # local import: keep module importable w/o jax
        self.num_layers = num_layers
        self.hbm_pages = hbm_pages
        self.page_size = page_size
        self.kv_heads = kv_heads
        self.head_dim = head_dim
        self.dtype = dtype
        self.kv = jnp.zeros(
            (num_layers, hbm_pages, page_size, 2, kv_heads, head_dim), dtype=dtype)
        self._free_slots: List[int] = list(range(hbm_pages))[::-1]
        self.paging = PagingSystem()
        self.clock = 1
        self._seqs: Dict[int, SeqState] = {}
        self._sets: Dict[int, LocalitySet] = {}
        # logical page id -> (physical slot | None, host copy | None)
        self._pages: Dict[int, Page] = {}
        self.host_store = host_store if host_store is not None else HostSlabStore()
        self._next_page_id = 0
        self.stats = {"offloads": 0, "fetches": 0, "offload_bytes": 0}

    @property
    def slab_nbytes(self) -> int:
        """Bytes of one logical page's slab across all layers."""
        return (self.num_layers * self.page_size * 2 * self.kv_heads
                * self.head_dim * np.dtype(self.dtype).itemsize)

    # -- sequence lifecycle -----------------------------------------------------
    def start_sequence(self, seq_id: int) -> SeqState:
        if seq_id in self._seqs:
            raise ValueError(f"sequence {seq_id} already active")
        st = SeqState(seq_id)
        ls = LocalitySet(f"seq{seq_id}", self.page_size, kv_attrs())
        self.clock += 1
        self.paging.register(ls, self.clock)
        ls.set_operation(CurrentOperation.READ_AND_WRITE, self.clock)
        self._seqs[seq_id] = st
        self._sets[seq_id] = ls
        return st

    def finish_sequence(self, seq_id: int) -> None:
        """Lifetime over: its pages become the preferred eviction victims and
        are reclaimed eagerly (paper §3.1 "evicted as soon as lifetime
        expires")."""
        st = self._seqs.pop(seq_id)
        ls = self._sets.pop(seq_id)
        self.clock += 1
        ls.end_lifetime(self.clock)
        for pid in st.page_ids:
            page = self._pages.pop(pid)
            if page.offset is not None:
                self._free_slots.append(page.offset)
            self.host_store.discard(pid)
        self.paging.unregister(ls.name)

    # -- page management ----------------------------------------------------------
    def _evict_one(self) -> None:
        picked = self.paging.pick_victims(self.clock)
        if picked is None:
            raise HBMExhaustedError("all KV pages pinned (every sequence active)")
        ls, victims = picked
        for vp in victims:
            self._offload(vp)

    def _offload(self, page: Page) -> None:
        assert page.offset is not None
        # device -> host (CPU container: numpy copy of that page's slab)
        slab = np.asarray(self.kv[:, page.offset])
        self.host_store.put(page.page_id, slab)
        self.stats["offloads"] += 1
        self.stats["offload_bytes"] += slab.nbytes
        self._free_slots.append(page.offset)
        page.offset = None

    def _restore(self, page: Page, ls: LocalitySet) -> int:
        import jax.numpy as jnp
        slot = self._alloc_slot(exclude_set=ls.name)
        try:
            slab = self.host_store.take(page.page_id)
        except BaseException:
            # a tiered store may fail mid-fetch (dead remote node); the slot
            # must go back so the cache stays consistent for the retry
            self._free_slots.append(slot)
            raise
        if slab is not None:
            self.kv = self.kv.at[:, slot].set(jnp.asarray(slab))
            self.stats["fetches"] += 1
        page.offset = slot
        return slot

    def _alloc_slot(self, exclude_set: Optional[str] = None) -> int:
        while not self._free_slots:
            self._evict_one()
        return self._free_slots.pop()

    def append_page(self, seq_id: int) -> Page:
        """Allocate the next logical page for a sequence."""
        st = self._seqs[seq_id]
        ls = self._sets[seq_id]
        self.clock += 1
        slot = self._alloc_slot()
        page = Page(page_id=self._next_page_id, set_name=ls.name,
                    size=self.page_size, offset=slot, pin_count=0, dirty=True,
                    last_access=self.clock)
        self._next_page_id += 1
        ls.pages[page.page_id] = page
        self._pages[page.page_id] = page
        st.page_ids.append(page.page_id)
        return page

    def ensure_capacity(self, seq_id: int, new_tokens: int = 1) -> None:
        st = self._seqs[seq_id]
        needed_pages = -(-(st.length + new_tokens) // self.page_size)
        while len(st.page_ids) < needed_pages:
            self.append_page(seq_id)

    def block_table(self, seq_id: int, max_pages: int) -> np.ndarray:
        """Physical slots for the attention kernel; restores any offloaded
        page of this sequence (decode reads the whole sequence)."""
        st = self._seqs[seq_id]
        ls = self._sets[seq_id]
        self.clock += 1
        ls.set_operation(CurrentOperation.READ_AND_WRITE, self.clock)
        table = np.full(max_pages, -1, dtype=np.int32)
        for i, pid in enumerate(st.page_ids[:max_pages]):
            page = self._pages[pid]
            if page.offset is None:
                self._restore(page, ls)
            page.last_access = self.clock
            table[i] = page.offset
        return table

    def advance(self, seq_id: int, tokens: int = 1) -> None:
        self._seqs[seq_id].length += tokens

    # -- byte-exact page access ---------------------------------------------------
    def write_page(self, seq_id: int, page_index: int, slab: np.ndarray) -> None:
        """Overwrite one logical page's slab ([L, page, 2, KH, D]); restores
        the page to HBM first if it was offloaded."""
        import jax.numpy as jnp
        st = self._seqs[seq_id]
        ls = self._sets[seq_id]
        page = self._pages[st.page_ids[page_index]]
        self.clock += 1
        if page.offset is None:
            self._restore(page, ls)
        page.last_access = self.clock
        page.dirty = True
        self.kv = self.kv.at[:, page.offset].set(jnp.asarray(slab))

    def read_page(self, seq_id: int, page_index: int) -> np.ndarray:
        """Byte-exact slab of one logical page, wherever it lives: resident
        pages read from HBM, offloaded ones from the host store (without
        pulling them back in)."""
        st = self._seqs[seq_id]
        page = self._pages[st.page_ids[page_index]]
        if page.offset is not None:
            return np.asarray(self.kv[:, page.offset])
        slab = self.host_store.peek(page.page_id)
        if slab is None:   # offloaded before any write: an all-zero page
            shape = (self.num_layers, self.page_size, 2,
                     self.kv_heads, self.head_dim)
            return np.zeros(shape, dtype=self.dtype)
        return np.asarray(slab)

    def sequence_slabs(self, seq_id: int) -> List[np.ndarray]:
        """All of a sequence's page slabs in logical order (byte-identity
        checks and replication)."""
        return [self.read_page(seq_id, i)
                for i in range(len(self._seqs[seq_id].page_ids))]

    def seq_length(self, seq_id: int) -> int:
        return self._seqs[seq_id].length

    def num_pages(self, seq_id: int) -> int:
        return len(self._seqs[seq_id].page_ids)

    # -- introspection --------------------------------------------------------------
    def resident_pages(self) -> int:
        return self.hbm_pages - len(self._free_slots)

    def active_sequences(self) -> List[int]:
        return list(self._seqs)
