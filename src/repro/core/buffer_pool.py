"""The unified buffer pool — paper §5.

One pool manages *all* data (user data, job data, shuffle data, hash data, KV
pages, dataset staging) in a single shared arena, the monolithic alternative to
per-layer caches. Pages are allocated from the arena by a TLSF allocator
(paper §5); callers receive zero-copy numpy views (the mmap shared-memory
analogue). Pin/unpin with reference counting.

Since PR 3 everything pressure-related — the data-aware ``PagingSystem``
(paper §6), the ``SpillStore``, resident/pinned/spilled accounting with
high-water marks, and the ``reserve``/``under_pressure`` backpressure API —
is owned by the per-node ``MemoryManager`` (``core/memory_manager.py``); the
pool is the arena + page mechanics and delegates policy to its manager
(``pool.memory``). ``pool.paging`` / ``pool.spill`` / ``pool.stats`` remain
as views into the manager for existing callers.
"""
from __future__ import annotations

import threading
from typing import Dict, Iterator, List, Optional

import numpy as np

from .attributes import AttributeSet, DurabilityType, Lifetime
from .locality_set import LocalitySet, Page
from .memory_manager import MemoryManager, SpillStore
from .paging import PagingSystem
from .tlsf import TLSF

__all__ = ["BufferPool", "PoolExhaustedError", "SpillStore", "MemoryManager"]


class PoolExhaustedError(MemoryError):
    """Raised when an allocation cannot be satisfied even after eviction
    (every resident page is pinned)."""


class BufferPool:
    """Monolithic pool over a single arena (paper §5).

    ``capacity`` bytes of "RAM"; everything beyond that spills through the
    data-aware paging system to the memory manager's spill store.
    """

    def __init__(self, capacity: int, spill_store: Optional[SpillStore] = None,
                 policy: str = "data-aware",
                 memory: Optional[MemoryManager] = None,
                 pressure_watermark: float = 0.85):
        self.capacity = capacity
        self.arena = np.zeros(capacity, dtype=np.uint8)
        self.tlsf = TLSF(capacity)
        self.memory = memory or MemoryManager(
            capacity, spill_store, policy,
            pressure_watermark=pressure_watermark)
        self.clock = 1  # logical time (paper: AccessRecency integers)
        self._pages: Dict[int, Page] = {}
        self._next_page_id = 0
        self._lock = threading.RLock()

    # -- delegation views (pre-PR-3 public surface) -----------------------------
    @property
    def spill(self) -> SpillStore:
        return self.memory.spill

    @property
    def paging(self) -> PagingSystem:
        return self.memory.paging

    @property
    def stats(self) -> Dict[str, int]:
        return self.memory.stats

    # -- locality-set lifecycle -------------------------------------------------
    def create_set(self, name: str, page_size: int,
                   attrs: Optional[AttributeSet] = None) -> LocalitySet:
        with self._lock:
            if name in self.paging.sets:
                raise ValueError(f"locality set {name!r} already exists")
            ls = LocalitySet(name, page_size, attrs)
            self.paging.register(ls, self.clock)
            return ls

    def get_set(self, name: str) -> LocalitySet:
        return self.paging.sets[name]

    def rename_set(self, ls: LocalitySet, new_name: str) -> LocalitySet:
        """Re-key a locality set (streaming remesh writes a shard under a
        staging name, then renames it into place once the old shard's pages
        are gone). Page ids are pool-global, so spill images carry over."""
        with self._lock:
            if new_name == ls.name:
                return ls
            if new_name in self.paging.sets:
                raise ValueError(f"locality set {new_name!r} already exists")
            self.paging.unregister(ls.name)
            ls.name = new_name
            for page in ls.pages.values():
                page.set_name = new_name
            self.paging.register(ls, self.clock)
            return ls

    def drop_set(self, ls: LocalitySet) -> None:
        """Free every page (lifetime over, data discarded) — including any
        spill images, which otherwise leak in the spill store."""
        with self._lock:
            for page in list(ls.pages.values()):
                if page.pinned:  # dropped out from under a holder
                    self.memory.note_unpinned(page.size)
                    page.pin_count = 0
                paged_out = page.spilled and not page.resident
                if page.resident:
                    self.tlsf.free(page.offset)
                    self.memory.note_free(page.size)
                    page.offset = None
                if page.spilled:
                    self.memory.discard_spilled(page.page_id, page.size,
                                                paged_out)
                    page.spilled = False
                self._pages.pop(page.page_id, None)
            ls.pages.clear()
            self.paging.unregister(ls.name)

    # -- page operations ----------------------------------------------------------
    def _tick(self) -> int:
        self.clock += 1
        return self.clock

    def new_page(self, ls: LocalitySet, size: Optional[int] = None) -> Page:
        """Allocate (and pin) a fresh page in ``ls``."""
        with self._lock:
            size = size or ls.page_size
            offset = self._alloc_with_eviction(size)
            page = Page(page_id=self._next_page_id, set_name=ls.name, size=size,
                        offset=offset, pin_count=1, dirty=True,
                        last_access=self._tick())
            self.memory.note_alloc(size)
            self.memory.note_pinned(size)
            self._next_page_id += 1
            ls.pages[page.page_id] = page
            self._pages[page.page_id] = page
            return page

    def view(self, page: Page) -> np.ndarray:
        """Zero-copy numpy view of a resident page (the shared-memory interface)."""
        if not page.resident:
            raise ValueError(f"page {page.page_id} is not resident")
        return self.arena[page.offset:page.offset + page.size]

    def pin(self, page: Page) -> np.ndarray:
        """Pin a page, fetching it from the spill store if necessary; returns
        the page view. Increments the reference count (paper §5)."""
        with self._lock:
            ls = self.get_set(page.set_name)
            if not page.resident:
                offset = self._alloc_with_eviction(page.size)
                page.offset = offset
                self.memory.note_alloc(page.size)
                if page.spilled:
                    data = np.frombuffer(self.spill.read(page.page_id), dtype=np.uint8)
                    self.arena[offset:offset + page.size] = data
                    ls.stats["fetch_bytes"] += page.size
                    self.memory.note_fetched(page.size)
                    self.memory.note_paged_in(page.size)
                page.dirty = False
            if page.pin_count == 0:
                self.memory.note_pinned(page.size)
            page.pin_count += 1
            page.last_access = self._tick()
            return self.view(page)

    def unpin(self, page: Page, dirty: bool = False) -> None:
        with self._lock:
            if page.pin_count <= 0:
                raise ValueError(f"unpin of unpinned page {page.page_id}")
            page.pin_count -= 1
            if page.pin_count == 0:
                self.memory.note_unpinned(page.size)
            page.dirty = page.dirty or dirty
            ls = self.get_set(page.set_name)
            # write-through: persist immediately once written (paper §4)
            if (page.dirty and ls.attrs.durability == DurabilityType.WRITE_THROUGH):
                self._spill_page(ls, page)
                page.dirty = False
                page.spilled = True

    # -- eviction (Algorithm 1 driver) ---------------------------------------------
    def _alloc_with_eviction(self, size: int) -> int:
        offset = self.tlsf.alloc(size)
        while offset is None:
            self.stats["alloc_retries"] += 1
            picked = self.memory.paging.pick_victims(self.clock)
            if picked is None:
                raise PoolExhaustedError(
                    f"cannot allocate {size}B: all resident pages pinned "
                    f"(free={self.tlsf.free_bytes}B of {self.capacity}B)")
            ls, victims = picked
            # evict incrementally — "one or more" (paper Alg. 1), stopping as
            # soon as the allocation fits; evicting the whole candidate list
            # would defeat MRU's working-prefix retention on sequential scans
            for page in victims:
                self._evict_page(ls, page)
                offset = self.tlsf.alloc(size)
                if offset is not None:
                    return offset
            offset = self.tlsf.alloc(size)
        return offset

    def _spill_page(self, ls: LocalitySet, page: Page) -> None:
        data = self.arena[page.offset:page.offset + page.size].tobytes()
        self.spill.write(page.page_id, data)
        page.spilled = True
        ls.stats["spill_bytes"] += page.size
        self.memory.note_spilled(page.size)

    def _evict_page(self, ls: LocalitySet, page: Page) -> None:
        assert page.resident and not page.pinned
        if ls.needs_spill_on_evict(page):
            self._spill_page(ls, page)
        page.dirty = False
        self.tlsf.free(page.offset)
        self.memory.note_free(page.size)
        page.offset = None
        ls.stats["evictions"] += 1
        self.stats["evictions"] += 1
        if ls.attrs.lifetime == Lifetime.ENDED:
            # data will never be read again; drop any spill image too (it
            # was a copy of a resident page, so it never counted as paged out)
            if page.spilled:
                self.memory.discard_spilled(page.page_id, page.size,
                                            paged_out=False)
                page.spilled = False
        elif page.spilled:
            # the page's only live copy is now on "disk": that is pressure
            self.memory.note_paged_out(page.size)

    # -- iteration helper (sequential-read service uses this) ----------------------
    def iter_pages(self, ls: LocalitySet) -> Iterator[Page]:
        for pid in sorted(ls.pages):
            yield ls.pages[pid]

    # -- accounting ------------------------------------------------------------------
    @property
    def resident_bytes(self) -> int:
        return self.tlsf.allocated_bytes

    def memory_report(self) -> Dict[str, Dict[str, int]]:
        rep: Dict[str, Dict[str, int]] = {}
        for name, ls in self.paging.sets.items():
            resident = sum(p.size for p in ls.pages.values() if p.resident)
            spilled = sum(p.size for p in ls.pages.values() if p.spilled and not p.resident)
            rep[name] = {"resident": resident, "spilled": spilled,
                         **ls.stats}
        return rep
