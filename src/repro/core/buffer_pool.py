"""The unified buffer pool — paper §5.

One pool manages *all* data (user data, job data, shuffle data, hash data, KV
pages, dataset staging) in a single shared arena, the monolithic alternative to
per-layer caches. Pages are allocated from the arena by a TLSF allocator
(paper §5); callers receive zero-copy numpy views (the mmap shared-memory
analogue). Pin/unpin with reference counting; eviction is delegated to the
data-aware PagingSystem (paper §6); spilled pages go to a SpillStore ("disk").
"""
from __future__ import annotations

import os
import tempfile
import threading
from typing import Dict, Iterator, List, Optional

import numpy as np

from .attributes import AttributeSet, CurrentOperation, DurabilityType, Lifetime
from .locality_set import LocalitySet, Page
from .paging import PagingSystem
from .tlsf import TLSF


class SpillStore:
    """Secondary storage for evicted pages. In-memory by default; set
    ``directory`` to spill to real files (used by the I/O benchmarks)."""

    def __init__(self, directory: Optional[str] = None):
        self.directory = directory
        self._mem: Dict[int, bytes] = {}
        self.bytes_written = 0
        self.bytes_read = 0
        self.write_ops = 0
        self.read_ops = 0
        if directory:
            os.makedirs(directory, exist_ok=True)

    def _path(self, page_id: int) -> str:
        return os.path.join(self.directory, f"page_{page_id}.bin")

    def write(self, page_id: int, data: bytes) -> None:
        self.bytes_written += len(data)
        self.write_ops += 1
        if self.directory:
            with open(self._path(page_id), "wb") as f:
                f.write(data)
        else:
            self._mem[page_id] = bytes(data)

    def read(self, page_id: int) -> bytes:
        self.read_ops += 1
        if self.directory:
            with open(self._path(page_id), "rb") as f:
                data = f.read()
        else:
            data = self._mem[page_id]
        self.bytes_read += len(data)
        return data

    def delete(self, page_id: int) -> None:
        if self.directory:
            try:
                os.remove(self._path(page_id))
            except FileNotFoundError:
                pass
        else:
            self._mem.pop(page_id, None)


class PoolExhaustedError(MemoryError):
    """Raised when an allocation cannot be satisfied even after eviction
    (every resident page is pinned)."""


class BufferPool:
    """Monolithic pool over a single arena (paper §5).

    ``capacity`` bytes of "RAM"; everything beyond that spills through the
    data-aware paging system to ``spill_store``.
    """

    def __init__(self, capacity: int, spill_store: Optional[SpillStore] = None,
                 policy: str = "data-aware"):
        self.capacity = capacity
        self.arena = np.zeros(capacity, dtype=np.uint8)
        self.tlsf = TLSF(capacity)
        self.spill = spill_store or SpillStore()
        self.paging = PagingSystem(policy)
        self.clock = 1  # logical time (paper: AccessRecency integers)
        self._pages: Dict[int, Page] = {}
        self._next_page_id = 0
        self._lock = threading.RLock()
        self.stats = {"evictions": 0, "spill_bytes": 0, "fetch_bytes": 0,
                      "alloc_retries": 0}

    # -- locality-set lifecycle -------------------------------------------------
    def create_set(self, name: str, page_size: int,
                   attrs: Optional[AttributeSet] = None) -> LocalitySet:
        with self._lock:
            if name in self.paging.sets:
                raise ValueError(f"locality set {name!r} already exists")
            ls = LocalitySet(name, page_size, attrs)
            self.paging.register(ls, self.clock)
            return ls

    def get_set(self, name: str) -> LocalitySet:
        return self.paging.sets[name]

    def drop_set(self, ls: LocalitySet) -> None:
        """Free every page (lifetime over, data discarded)."""
        with self._lock:
            for page in list(ls.pages.values()):
                if page.resident:
                    self.tlsf.free(page.offset)
                    page.offset = None
                if page.spilled:
                    self.spill.delete(page.page_id)
                self._pages.pop(page.page_id, None)
            ls.pages.clear()
            self.paging.unregister(ls.name)

    # -- page operations ----------------------------------------------------------
    def _tick(self) -> int:
        self.clock += 1
        return self.clock

    def new_page(self, ls: LocalitySet, size: Optional[int] = None) -> Page:
        """Allocate (and pin) a fresh page in ``ls``."""
        with self._lock:
            size = size or ls.page_size
            offset = self._alloc_with_eviction(size)
            page = Page(page_id=self._next_page_id, set_name=ls.name, size=size,
                        offset=offset, pin_count=1, dirty=True,
                        last_access=self._tick())
            self._next_page_id += 1
            ls.pages[page.page_id] = page
            self._pages[page.page_id] = page
            return page

    def view(self, page: Page) -> np.ndarray:
        """Zero-copy numpy view of a resident page (the shared-memory interface)."""
        if not page.resident:
            raise ValueError(f"page {page.page_id} is not resident")
        return self.arena[page.offset:page.offset + page.size]

    def pin(self, page: Page) -> np.ndarray:
        """Pin a page, fetching it from the spill store if necessary; returns
        the page view. Increments the reference count (paper §5)."""
        with self._lock:
            ls = self.get_set(page.set_name)
            if not page.resident:
                offset = self._alloc_with_eviction(page.size)
                page.offset = offset
                if page.spilled:
                    data = np.frombuffer(self.spill.read(page.page_id), dtype=np.uint8)
                    self.arena[offset:offset + page.size] = data
                    ls.stats["fetch_bytes"] += page.size
                    self.stats["fetch_bytes"] += page.size
                page.dirty = False
            page.pin_count += 1
            page.last_access = self._tick()
            return self.view(page)

    def unpin(self, page: Page, dirty: bool = False) -> None:
        with self._lock:
            if page.pin_count <= 0:
                raise ValueError(f"unpin of unpinned page {page.page_id}")
            page.pin_count -= 1
            page.dirty = page.dirty or dirty
            ls = self.get_set(page.set_name)
            # write-through: persist immediately once written (paper §4)
            if (page.dirty and ls.attrs.durability == DurabilityType.WRITE_THROUGH):
                self._spill_page(ls, page, count_eviction=False)
                page.dirty = False
                page.spilled = True

    # -- eviction (Algorithm 1 driver) ---------------------------------------------
    def _alloc_with_eviction(self, size: int) -> int:
        offset = self.tlsf.alloc(size)
        while offset is None:
            self.stats["alloc_retries"] += 1
            picked = self.paging.pick_victims(self.clock)
            if picked is None:
                raise PoolExhaustedError(
                    f"cannot allocate {size}B: all resident pages pinned "
                    f"(free={self.tlsf.free_bytes}B of {self.capacity}B)")
            ls, victims = picked
            # evict incrementally — "one or more" (paper Alg. 1), stopping as
            # soon as the allocation fits; evicting the whole candidate list
            # would defeat MRU's working-prefix retention on sequential scans
            for page in victims:
                self._evict_page(ls, page)
                offset = self.tlsf.alloc(size)
                if offset is not None:
                    return offset
            offset = self.tlsf.alloc(size)
        return offset

    def _spill_page(self, ls: LocalitySet, page: Page, count_eviction: bool = True) -> None:
        data = self.arena[page.offset:page.offset + page.size].tobytes()
        self.spill.write(page.page_id, data)
        page.spilled = True
        ls.stats["spill_bytes"] += page.size
        self.stats["spill_bytes"] += page.size

    def _evict_page(self, ls: LocalitySet, page: Page) -> None:
        assert page.resident and not page.pinned
        if ls.needs_spill_on_evict(page):
            self._spill_page(ls, page)
        page.dirty = False
        self.tlsf.free(page.offset)
        page.offset = None
        ls.stats["evictions"] += 1
        self.stats["evictions"] += 1
        if ls.attrs.lifetime == Lifetime.ENDED:
            # data will never be read again; drop any spill image too
            if page.spilled:
                self.spill.delete(page.page_id)
                page.spilled = False

    # -- iteration helper (sequential-read service uses this) ----------------------
    def iter_pages(self, ls: LocalitySet) -> Iterator[Page]:
        for pid in sorted(ls.pages):
            yield ls.pages[pid]

    # -- accounting ------------------------------------------------------------------
    @property
    def resident_bytes(self) -> int:
        return self.tlsf.allocated_bytes

    def memory_report(self) -> Dict[str, Dict[str, int]]:
        rep: Dict[str, Dict[str, int]] = {}
        for name, ls in self.paging.sets.items():
            resident = sum(p.size for p in ls.pages.values() if p.resident)
            spilled = sum(p.size for p in ls.pages.values() if p.spilled and not p.resident)
            rep[name] = {"resident": resident, "spilled": spilled,
                         **ls.stats}
        return rep
