"""The unified buffer pool — paper §5.

One pool manages *all* data (user data, job data, shuffle data, hash data, KV
pages, dataset staging) in a single shared arena, the monolithic alternative to
per-layer caches. Pages are allocated from the arena by a TLSF allocator
(paper §5); callers receive zero-copy numpy views (the mmap shared-memory
analogue). Pin/unpin with reference counting.

Since PR 3 everything pressure-related — the data-aware ``PagingSystem``
(paper §6), the ``SpillStore``, resident/pinned/spilled accounting with
high-water marks, and the ``reserve``/``under_pressure`` backpressure API —
is owned by the per-node ``MemoryManager`` (``core/memory_manager.py``); the
pool is the arena + page mechanics and delegates policy to its manager
(``pool.memory``). ``pool.paging`` / ``pool.spill`` / ``pool.stats`` remain
as views into the manager for existing callers.
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Optional

import numpy as np

from .attributes import AttributeSet, DurabilityType, Lifetime
from .locality_set import LocalitySet, Page
from .memory_manager import MemoryManager, SpillStore
from .paging import PagingSystem
from .sanitizer import tracked_rlock
from .tlsf import TLSF

__all__ = ["BufferPool", "PoolExhaustedError", "SpillStore", "MemoryManager"]


class PoolExhaustedError(MemoryError):
    """Raised when an allocation cannot be satisfied even after eviction
    (every resident page is pinned)."""


class BufferPool:
    """Monolithic pool over a single arena (paper §5).

    ``capacity`` bytes of "RAM"; everything beyond that spills through the
    data-aware paging system to the memory manager's spill store.
    """

    def __init__(self, capacity: int, spill_store: Optional[SpillStore] = None,
                 policy: str = "data-aware",
                 memory: Optional[MemoryManager] = None,
                 pressure_watermark: float = 0.85,
                 pagelog=None):
        self.capacity = capacity
        self.arena = np.zeros(capacity, dtype=np.uint8)
        self.tlsf = TLSF(capacity)
        self.memory = memory or MemoryManager(
            capacity, spill_store, policy,
            pressure_watermark=pressure_watermark, pagelog=pagelog)
        self.clock = 1  # logical time (paper: AccessRecency integers)
        self._pages: Dict[int, Page] = {}
        self._next_page_id = 0
        self._lock = tracked_rlock("buffer_pool")

    # -- delegation views (pre-PR-3 public surface) -----------------------------
    @property
    def spill(self) -> SpillStore:
        return self.memory.spill

    @property
    def paging(self) -> PagingSystem:
        return self.memory.paging

    @property
    def stats(self) -> Dict[str, int]:
        return self.memory.stats

    # -- locality-set lifecycle -------------------------------------------------
    def create_set(self, name: str, page_size: int,
                   attrs: Optional[AttributeSet] = None) -> LocalitySet:
        with self._lock:
            if name in self.paging.sets:
                raise ValueError(f"locality set {name!r} already exists")
            ls = LocalitySet(name, page_size, attrs)
            self.paging.register(ls, self.clock)
            return ls

    def get_set(self, name: str) -> LocalitySet:
        return self.paging.sets[name]

    def rename_set(self, ls: LocalitySet, new_name: str) -> LocalitySet:
        """Re-key a locality set (streaming remesh writes a shard under a
        staging name, then renames it into place once the old shard's pages
        are gone). Page ids are pool-global, so spill images carry over."""
        with self._lock:
            if new_name == ls.name:
                return ls
            if new_name in self.paging.sets:
                raise ValueError(f"locality set {new_name!r} already exists")
            self.paging.unregister(ls.name)
            old_name = ls.name
            ls.name = new_name
            for page in ls.pages.values():
                page.set_name = new_name
            if (self.memory.pagelog is not None
                    and any(p.durable for p in ls.pages.values())):
                # re-key the durable images too (O(1) rename record): replay
                # must find them under the name the catalog will ask for
                self.memory.pagelog.rename_set(old_name, new_name)
            self.paging.register(ls, self.clock)
            return ls

    def drop_set(self, ls: LocalitySet) -> None:
        """Free every page (lifetime over, data discarded) — including any
        spill images, which otherwise leak in the spill store."""
        with self._lock:
            any_durable = False
            for page in list(ls.pages.values()):
                if page.pinned:  # dropped out from under a holder
                    self.memory.note_unpinned(page.size)
                    page.pin_count = 0
                paged_out = page.spilled and not page.resident
                if page.resident:
                    self.tlsf.free(page.offset)
                    self.memory.note_free(page.size)
                    page.offset = None
                if page.spilled:
                    if page.durable:
                        any_durable = True
                        self.memory.discard_durable(page.size, paged_out)
                    else:
                        self.memory.discard_spilled(page.page_id, page.size,
                                                    paged_out)
                    page.spilled = False
                self._pages.pop(page.page_id, None)
            ls.pages.clear()
            self.paging.unregister(ls.name)
            if any_durable:
                # one set-level tombstone cuts every log entry (append-only
                # log: per-page deletes don't exist); replay will not
                # resurrect the dropped set
                self.memory.pagelog.drop_set(ls.name)

    # -- warm start from the durable tier -----------------------------------------
    def adopt_durable_set(self, name: str, page_size: int,
                          attrs: Optional[AttributeSet] = None) -> LocalitySet:
        """Re-register a set whose page images live in the durable log (the
        warm-start path): every live log entry becomes a non-resident page
        that faults back in on first pin. No bytes are read here — adoption
        is O(index), which is what makes a warm restart cheap."""
        with self._lock:
            log = self.memory.pagelog
            if log is None:
                raise ValueError("pool has no durable page log to adopt from")
            entries = log.entries_for(name)
            if not entries:
                raise KeyError(f"page log holds no entries for {name!r}")
            if attrs is None:
                attrs = AttributeSet(durability=DurabilityType.WRITE_THROUGH)
            ls = self.create_set(name, page_size, attrs)
            for e in entries:
                page = Page(page_id=self._next_page_id, set_name=name,
                            size=e.length, offset=None, pin_count=0,
                            dirty=False, spilled=True,
                            last_access=self._tick(),
                            durable=True, log_seq=e.seq)
                self._next_page_id += 1
                ls.pages[page.page_id] = page
                self._pages[page.page_id] = page
                self.memory.note_durable_out(e.length)
            return ls

    def warm_start(self, page_size: int,
                   attrs_factory=None) -> List[str]:
        """Adopt every set the durable log replayed (standalone-pool warm
        restart, e.g. a pool-backed checkpoint store; the cluster path
        adopts per shard after epoch fencing instead). Returns the adopted
        set names."""
        adopted: List[str] = []
        log = self.memory.pagelog
        if log is None:
            return adopted
        for name in log.set_names():
            if name in self.paging.sets:
                continue
            attrs = attrs_factory() if attrs_factory is not None else None
            self.adopt_durable_set(name, page_size, attrs)
            adopted.append(name)
        return adopted

    # -- page operations ----------------------------------------------------------
    def _tick(self) -> int:
        self.clock += 1
        return self.clock

    def new_page(self, ls: LocalitySet, size: Optional[int] = None) -> Page:
        """Allocate (and pin) a fresh page in ``ls``."""
        with self._lock:
            size = size or ls.page_size
            offset = self._alloc_with_eviction(size)
            page = Page(page_id=self._next_page_id, set_name=ls.name, size=size,
                        offset=offset, pin_count=1, dirty=True,
                        last_access=self._tick())
            self.memory.note_alloc(size)
            self.memory.note_pinned(size)
            self._next_page_id += 1
            ls.pages[page.page_id] = page
            self._pages[page.page_id] = page
            return page

    def view(self, page: Page) -> np.ndarray:
        """Zero-copy numpy view of a resident page (the shared-memory interface)."""
        if not page.resident:
            raise ValueError(f"page {page.page_id} is not resident")
        return self.arena[page.offset:page.offset + page.size]

    def pin(self, page: Page) -> np.ndarray:
        """Pin a page, fetching it from the spill store if necessary; returns
        the page view. Increments the reference count (paper §5)."""
        with self._lock:
            ls = self.get_set(page.set_name)
            if not page.resident:
                offset = self._alloc_with_eviction(page.size)
                page.offset = offset
                self.memory.note_alloc(page.size)
                if page.spilled:
                    raw = (self.memory.pagelog_read(ls.name, page.log_seq)
                           if page.durable
                           else self.spill.read(page.page_id))
                    data = np.frombuffer(raw, dtype=np.uint8)
                    self.arena[offset:offset + page.size] = data
                    ls.stats["fetch_bytes"] += page.size
                    self.memory.note_fetched(page.size)
                    if page.durable:
                        self.memory.note_durable_in(page.size)
                    else:
                        self.memory.note_paged_in(page.size)
                page.dirty = False
            if page.pin_count == 0:
                self.memory.note_pinned(page.size)
            page.pin_count += 1
            page.last_access = self._tick()
            return self.view(page)

    def unpin(self, page: Page, dirty: bool = False) -> None:
        with self._lock:
            if page.pin_count <= 0:
                raise ValueError(f"unpin of unpinned page {page.page_id}")
            page.pin_count -= 1
            if page.pin_count == 0:
                self.memory.note_unpinned(page.size)
            page.dirty = page.dirty or dirty
            ls = self.get_set(page.set_name)
            # write-through: persist immediately once written (paper §4)
            if (page.dirty and ls.attrs.durability == DurabilityType.WRITE_THROUGH):
                self._spill_page(ls, page)
                page.dirty = False
                page.spilled = True

    # -- eviction (Algorithm 1 driver) ---------------------------------------------
    def _alloc_with_eviction(self, size: int) -> int:
        offset = self.tlsf.alloc(size)
        while offset is None:
            self.stats["alloc_retries"] += 1
            picked = self.memory.paging.pick_victims(self.clock)
            if picked is None:
                raise PoolExhaustedError(
                    f"cannot allocate {size}B: all resident pages pinned "
                    f"(free={self.tlsf.free_bytes}B of {self.capacity}B)")
            ls, victims = picked
            # evict incrementally — "one or more" (paper Alg. 1), stopping as
            # soon as the allocation fits; evicting the whole candidate list
            # would defeat MRU's working-prefix retention on sequential scans
            for page in victims:
                self._evict_page(ls, page)
                offset = self.tlsf.alloc(size)
                if offset is not None:
                    return offset
            offset = self.tlsf.alloc(size)
        return offset

    def _spill_page(self, ls: LocalitySet, page: Page) -> None:
        data = self.arena[page.offset:page.offset + page.size].tobytes()
        if self.memory.durable_route(ls):
            # write-through sets persist into the durable page log, the tier
            # below scratch spill: the image survives node death and a
            # restarted node warm-starts from it
            self.memory.pagelog_write(ls.name, page, data)
        else:
            self.spill.write(page.page_id, data)
        page.spilled = True
        ls.stats["spill_bytes"] += page.size
        self.memory.note_spilled(page.size)

    def _evict_page(self, ls: LocalitySet, page: Page) -> None:
        assert page.resident and not page.pinned
        if ls.needs_spill_on_evict(page):
            self._spill_page(ls, page)
        page.dirty = False
        self.tlsf.free(page.offset)
        self.memory.note_free(page.size)
        page.offset = None
        ls.stats["evictions"] += 1
        self.stats["evictions"] += 1
        if ls.attrs.lifetime == Lifetime.ENDED:
            # data will never be read again; drop any spill image too (it
            # was a copy of a resident page, so it never counted as paged out)
            if page.spilled and not page.durable:
                self.memory.discard_spilled(page.page_id, page.size,
                                            paged_out=False)
                page.spilled = False
        elif page.spilled:
            if page.durable:
                # only copy is the durable log — its home tier, not pressure
                self.memory.note_durable_out(page.size)
            else:
                # the page's only live copy is now on "disk": that is pressure
                self.memory.note_paged_out(page.size)

    # -- iteration helper (sequential-read service uses this) ----------------------
    def iter_pages(self, ls: LocalitySet) -> Iterator[Page]:
        for pid in sorted(ls.pages):
            yield ls.pages[pid]

    # -- accounting ------------------------------------------------------------------
    @property
    def resident_bytes(self) -> int:
        return self.tlsf.allocated_bytes

    def memory_report(self) -> Dict[str, Dict[str, int]]:
        rep: Dict[str, Dict[str, int]] = {}
        for name, ls in self.paging.sets.items():
            resident = sum(p.size for p in ls.pages.values() if p.resident)
            spilled = sum(p.size for p in ls.pages.values() if p.spilled and not p.resident)
            rep[name] = {"resident": resident, "spilled": spilled,
                         **ls.stats}
        return rep
