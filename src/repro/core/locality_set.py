"""Locality sets — paper §3.2.

A Pangea locality set is a set of equal-sized pages associated with one dataset
that an application uses in a uniform way. Pages may live in the buffer pool,
in the spill store ("disk"), or both. Attribute updates (operation / lifetime)
drive the paging system's dynamic priority (paper §6).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from .attributes import (
    AttributeSet,
    CurrentOperation,
    DurabilityType,
    EvictionStrategy,
    Lifetime,
    ReadingPattern,
    WritingPattern,
)


@dataclass
class Page:
    """Buffer-pool page metadata. ``offset`` is None when not resident."""

    page_id: int
    set_name: str
    size: int
    offset: Optional[int] = None        # arena offset when resident
    pin_count: int = 0                  # reference counting (paper §5)
    dirty: bool = False
    spilled: bool = False               # has an image in the spill store
    last_access: int = 0                # logical clock of last pin
    durable: bool = False               # backing image lives in the page log
    log_seq: int = -1                   # page-log sequence within its set

    @property
    def resident(self) -> bool:
        return self.offset is not None

    @property
    def pinned(self) -> bool:
        return self.pin_count > 0


class LocalitySet:
    """Pages + attributes + per-set eviction strategy (paper §3.2, §6)."""

    def __init__(self, name: str, page_size: int, attrs: Optional[AttributeSet] = None):
        self.name = name
        self.page_size = page_size
        self.attrs = attrs or AttributeSet()
        self.pages: Dict[int, Page] = {}
        self._next_local_id = 0
        # paging-system hook; set by BufferPool.create_set
        self._on_attr_update = None
        # per-set counters for the benchmarks (paper reports page-out volume)
        self.stats = {"evictions": 0, "spill_bytes": 0, "fetch_bytes": 0}

    # -- attribute transitions (these drive the §6 priority model) ------------
    def _touch(self, clock: int) -> None:
        self.attrs.access_recency = clock
        self.stats["accesses"] = self.stats.get("accesses", 0) + 1
        if self._on_attr_update:
            self._on_attr_update(self)

    def set_operation(self, op: CurrentOperation, clock: int) -> None:
        self.attrs.operation = op
        self._touch(clock)

    def end_lifetime(self, clock: int) -> None:
        self.attrs.lifetime = Lifetime.ENDED
        self.attrs.operation = CurrentOperation.IDLE
        self._touch(clock)

    def revive(self, clock: int) -> None:
        self.attrs.lifetime = Lifetime.ALIVE
        self._touch(clock)

    # -- service-driven attribute inference (paper §3.2) ----------------------
    def infer_from_service(self, service: str, clock: int) -> None:
        """Each service exhibits a specific writing/reading pattern."""
        if service == "sequential-write":
            self.attrs.writing = WritingPattern.SEQUENTIAL_WRITE
            self.set_operation(CurrentOperation.WRITE, clock)
        elif service == "sequential-read":
            self.attrs.reading = ReadingPattern.SEQUENTIAL_READ
            self.set_operation(CurrentOperation.READ, clock)
        elif service == "shuffle":
            self.attrs.writing = WritingPattern.CONCURRENT_WRITE
            self.set_operation(CurrentOperation.WRITE, clock)
        elif service == "hash":
            self.attrs.writing = WritingPattern.RANDOM_MUTABLE_WRITE
            self.attrs.reading = ReadingPattern.RANDOM_READ
            self.set_operation(CurrentOperation.READ_AND_WRITE, clock)
        else:
            raise ValueError(f"unknown service {service!r}")

    # -- victim selection (paper §6) -------------------------------------------
    def unpinned_resident_pages(self) -> List[Page]:
        return [p for p in self.pages.values() if p.resident and not p.pinned]

    def select_victims(self) -> List[Page]:
        """Order unpinned resident pages per the set's strategy and cap the
        count by the CurrentOperation eviction ratio (paper §6)."""
        candidates = self.unpinned_resident_pages()
        if not candidates:
            return []
        strategy = self.attrs.strategy
        reverse = strategy == EvictionStrategy.MRU  # MRU: most recent first
        candidates.sort(key=lambda p: p.last_access, reverse=reverse)
        ratio = self.attrs.eviction_ratio
        n = max(1, int(len(candidates) * ratio))
        return candidates[:n]

    def needs_spill_on_evict(self, page: Page) -> bool:
        """A dirty page of a live write-back set must be spilled before its
        memory is recycled (paper §5). Write-through pages were persisted at
        unpin time; lifetime-ended pages are simply dropped."""
        if self.attrs.lifetime == Lifetime.ENDED:
            return False
        if self.attrs.durability == DurabilityType.WRITE_THROUGH:
            return page.dirty  # not yet flushed (shouldn't happen post-unpin)
        return page.dirty or not page.spilled
