"""Data-aware paging system — paper §6, Algorithm 1 + Eq. 1.

The paging system orders all locality sets by the overhead ``O`` of evicting
their pages:

    O = -1 * (t_now / t_r)   if lifetime == lifetime-ended
    O =  c * (t_r / t_now)   if lifetime == alive

where ``c`` is the Table-3 spilling-cost constant and ``t_r`` the set's access
recency. The set with the *lowest* O supplies victims; its per-set strategy
(MRU for sequential/concurrent patterns, LRU for random patterns) picks which
pages, and the CurrentOperation attribute caps how many (10% while writing).

A lazy min-heap keyed on O is maintained; entries are invalidated on attribute
updates (which are "significantly less frequent than page operations", §6).
"""
from __future__ import annotations

import heapq
import itertools
from typing import Dict, List, Optional, Tuple

from .attributes import Lifetime
from .locality_set import LocalitySet, Page


def eviction_overhead(ls: LocalitySet, clock: int) -> float:
    """Eq. 1. Lower = better eviction victim."""
    t_r = max(1, ls.attrs.access_recency)
    t_now = max(t_r, clock, 1)
    if ls.attrs.lifetime == Lifetime.ENDED:
        return -1.0 * (t_now / t_r)
    return ls.attrs.spilling_cost * (t_r / t_now)


class PagingSystem:
    """Algorithm 1: pick the lowest-priority locality set, evict victims from
    it using its selected strategy and tuned eviction count.

    ``policy`` selects the replacement approach (paper §9 comparisons):
      * "data-aware" — the paper's Eq.-1 dynamic priority (default);
      * "lru" / "mru" — global recency order across ALL sets, evicting 10%
        of unpinned pages per decision (the Fig.-3/8/9 baselines);
      * "freq-aware" — Eq. 1 with spilling cost replaced by access frequency
        (the paper's ablation in Fig. 3).
    """

    def __init__(self, policy: str = "data-aware"):
        self.policy = policy
        self._sets: Dict[str, LocalitySet] = {}
        self._heap: List[Tuple[float, int, str]] = []
        self._entry_count = itertools.count()
        self._stale: Dict[str, int] = {}  # name -> latest entry id

    # -- registration ----------------------------------------------------------
    def register(self, ls: LocalitySet, clock: int) -> None:
        self._sets[ls.name] = ls
        ls._on_attr_update = lambda s: self._push(s, clock)
        self._push(ls, clock)

    def unregister(self, name: str) -> None:
        self._sets.pop(name, None)
        self._stale.pop(name, None)

    def _push(self, ls: LocalitySet, clock: int) -> None:
        eid = next(self._entry_count)
        self._stale[ls.name] = eid
        if self.policy == "freq-aware":
            # Fig.-3 ablation: spilling cost replaced by access frequency
            if ls.attrs.lifetime == Lifetime.ENDED:
                o = -1.0
            else:
                o = float(ls.stats.get("accesses", 0))
        else:
            o = eviction_overhead(ls, clock)
        heapq.heappush(self._heap, (o, eid, ls.name))

    def refresh(self, clock: int) -> None:
        """Re-key every set at the current clock (O depends on t_now)."""
        for ls in self._sets.values():
            self._push(ls, clock)

    # -- Algorithm 1 -----------------------------------------------------------
    def pick_victims(self, clock: int) -> Optional[Tuple[LocalitySet, List[Page]]]:
        """Returns (victim set, victim pages) or None if nothing evictable.

        Lazy-heap walk: skip stale entries and sets with no unpinned resident
        pages; re-push skipped-but-live sets so they stay in the queue.
        """
        if self.policy in ("lru", "mru"):
            return self._pick_global_recency(self.policy)
        self.refresh(clock)
        repush: List[LocalitySet] = []
        found = None
        while self._heap:
            overhead, eid, name = heapq.heappop(self._heap)
            ls = self._sets.get(name)
            if ls is None or self._stale.get(name) != eid:
                continue  # stale entry
            victims = ls.select_victims()
            if victims:
                found = (ls, victims)
                repush.append(ls)
                break
            repush.append(ls)
        for ls in repush:
            self._push(ls, clock)
        return found

    def _pick_global_recency(self, policy: str):
        """Fig.-3/8/9 baselines: 10% of unpinned pages by global recency,
        ignoring data semantics. Victims are grouped under their owning set
        (one set per call — the caller loops)."""
        pages: List[Tuple[int, LocalitySet, Page]] = []
        for ls in self._sets.values():
            for p in ls.unpinned_resident_pages():
                pages.append((p.last_access, ls, p))
        if not pages:
            return None
        pages.sort(key=lambda t: t[0], reverse=(policy == "mru"))
        n = max(1, len(pages) // 10)
        chosen = pages[:n]
        ls0 = chosen[0][1]
        same = [p for _, ls, p in chosen if ls is ls0]
        return ls0, same

    # -- introspection ---------------------------------------------------------
    def priority_order(self, clock: int) -> List[Tuple[str, float]]:
        """All sets ordered by Eq.-1 overhead (victims first) — for tests."""
        items = [(eviction_overhead(ls, clock), name) for name, ls in self._sets.items()]
        items.sort()
        return [(name, o) for o, name in items]

    @property
    def sets(self) -> Dict[str, LocalitySet]:
        return self._sets
