"""Data-aware paging system — paper §6, Algorithm 1 + Eq. 1.

The paging system orders all locality sets by the overhead ``O`` of evicting
their pages:

    O = -1 * (t_now / t_r)   if lifetime == lifetime-ended
    O =  c * (t_r / t_now)   if lifetime == alive

where ``c`` is the Table-3 spilling-cost constant and ``t_r`` the set's access
recency. The set with the *lowest* O supplies victims; its per-set strategy
(MRU for sequential/concurrent patterns, LRU for random patterns) picks which
pages, and the CurrentOperation attribute caps how many (10% while writing).

A lazy min-heap keyed on O is maintained; entries are invalidated on attribute
updates (which are "significantly less frequent than page operations", §6).

Heap keys are memoized (PR-5 perf fix): at any fixed ``t_now`` Eq. 1 orders
ended sets by ``-1/t_r`` and alive sets by ``c * t_r`` (both classes scale
uniformly in ``t_now``, and ended overheads are negative while alive ones are
non-negative), so the heap is keyed on those ``t_now``-independent surrogates
and only *dirtied* sets — ones whose attributes actually changed — are ever
re-keyed. The old implementation re-pushed every registered set on every
eviction decision (O(sets·log sets) per allocation retry), which is exactly
the "full Eq.-1 heap refresh" wall-clock loss the ROADMAP flagged.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Dict, List, Optional, Tuple

from .attributes import Lifetime
from .locality_set import LocalitySet, Page


def eviction_overhead(ls: LocalitySet, clock: int) -> float:
    """Eq. 1. Lower = better eviction victim."""
    t_r = max(1, ls.attrs.access_recency)
    t_now = max(t_r, clock, 1)
    if ls.attrs.lifetime == Lifetime.ENDED:
        return -1.0 * (t_now / t_r)
    return ls.attrs.spilling_cost * (t_r / t_now)


class PagingSystem:
    """Algorithm 1: pick the lowest-priority locality set, evict victims from
    it using its selected strategy and tuned eviction count.

    ``policy`` selects the replacement approach (paper §9 comparisons):
      * "data-aware" — the paper's Eq.-1 dynamic priority (default);
      * "lru" / "mru" — global recency order across ALL sets, evicting 10%
        of unpinned pages per decision (the Fig.-3/8/9 baselines);
      * "freq-aware" — Eq. 1 with spilling cost replaced by access frequency
        (the paper's ablation in Fig. 3).
    """

    def __init__(self, policy: str = "data-aware"):
        self.policy = policy
        self._sets: Dict[str, LocalitySet] = {}
        self._heap: List[Tuple[float, int, str]] = []
        self._entry_count = itertools.count()
        self._stale: Dict[str, int] = {}  # name -> latest entry id
        self.rekeys = 0                   # heap pushes (memoization metric)

    # -- registration ----------------------------------------------------------
    def register(self, ls: LocalitySet, clock: int = 0) -> None:
        """Register a set with the paging system. ``clock`` is vestigial
        since the PR-5 memoization (heap keys are t_now-independent, so
        registration time never affects priority); accepted for caller
        compatibility."""
        self._sets[ls.name] = ls
        # attribute updates dirty the set: it alone is re-keyed
        ls._on_attr_update = self._push
        self._push(ls)

    def unregister(self, name: str) -> None:
        self._sets.pop(name, None)
        self._stale.pop(name, None)

    def _heap_key(self, ls: LocalitySet) -> float:
        """``t_now``-independent surrogate for Eq.-1 overhead: preserves the
        Eq.-1 ordering at every clock, so entries stay valid until the set's
        own attributes change (see module docstring)."""
        t_r = max(1, ls.attrs.access_recency)
        if self.policy == "freq-aware":
            # Fig.-3 ablation: spilling cost replaced by access frequency
            if ls.attrs.lifetime == Lifetime.ENDED:
                return -1.0
            return float(ls.stats.get("accesses", 0))
        if ls.attrs.lifetime == Lifetime.ENDED:
            return -1.0 / t_r
        return ls.attrs.spilling_cost * t_r

    def _push(self, ls: LocalitySet) -> None:
        eid = next(self._entry_count)
        self._stale[ls.name] = eid
        self.rekeys += 1
        heapq.heappush(self._heap, (self._heap_key(ls), eid, ls.name))

    def refresh(self, clock: int) -> None:
        """Re-key every set. With memoized keys this is never needed for
        correctness (attribute updates re-key incrementally); kept for
        explicit rebuilds after bulk attribute surgery."""
        for ls in self._sets.values():
            self._push(ls)

    # -- Algorithm 1 -----------------------------------------------------------
    def pick_victims(self, clock: int) -> Optional[Tuple[LocalitySet, List[Page]]]:
        """Returns (victim set, victim pages) or None if nothing evictable.

        Lazy-heap walk: skip stale entries and sets with no unpinned resident
        pages; re-push skipped-but-live sets so they stay in the queue.
        """
        if self.policy in ("lru", "mru"):
            return self._pick_global_recency(self.policy)
        repush: List[LocalitySet] = []
        found = None
        while self._heap:
            overhead, eid, name = heapq.heappop(self._heap)
            ls = self._sets.get(name)
            if ls is None or self._stale.get(name) != eid:
                continue  # stale entry
            victims = ls.select_victims()
            if victims:
                found = (ls, victims)
                repush.append(ls)
                break
            repush.append(ls)
        for ls in repush:
            self._push(ls)
        return found

    def _pick_global_recency(self, policy: str):
        """Fig.-3/8/9 baselines: 10% of unpinned pages by global recency,
        ignoring data semantics. Victims are grouped under their owning set
        (one set per call — the caller loops)."""
        pages: List[Tuple[int, LocalitySet, Page]] = []
        for ls in self._sets.values():
            for p in ls.unpinned_resident_pages():
                pages.append((p.last_access, ls, p))
        if not pages:
            return None
        pages.sort(key=lambda t: t[0], reverse=(policy == "mru"))
        n = max(1, len(pages) // 10)
        chosen = pages[:n]
        ls0 = chosen[0][1]
        same = [p for _, ls, p in chosen if ls is ls0]
        return ls0, same

    # -- introspection ---------------------------------------------------------
    def priority_order(self, clock: int) -> List[Tuple[str, float]]:
        """All sets ordered by Eq.-1 overhead (victims first) — for tests."""
        items = [(eviction_overhead(ls, clock), name) for name, ls in self._sets.items()]
        items.sort()
        return [(name, o) for o, name in items]

    @property
    def sets(self) -> Dict[str, LocalitySet]:
        return self._sets
