"""Per-node memory authority — eviction policy promoted out of the pool.

The paper's §6 data-aware eviction was a ``BufferPool`` internal, which meant
only the pool's own allocation path could see or react to memory pressure.
The ``MemoryManager`` owns everything pressure-related for one node:

* the ``PagingSystem`` (Eq. 1 / Algorithm 1 victim selection) and the
  ``SpillStore`` the victims land in;
* pressure accounting — resident / pinned / spilled / reserved bytes with
  high-water marks, so "how close to the cliff did this workload get" is a
  first-class, assertable number (the streaming-remesh driver budget and the
  reducer pull staging both run through ``reserve``);
* the backpressure API — ``reserve(nbytes)`` for staging buffers that live
  *outside* the arena (driver-side chunks in flight, pull staging), and
  ``under_pressure()`` / ``pressure_score()`` for callers that should slow
  down or place work elsewhere. The cluster scheduler reads the score through
  the statistics DB and penalizes nodes that are already spilling.

``BufferPool`` delegates to it (``pool.paging`` / ``pool.spill`` /
``pool.stats`` are views into the manager), and ``StorageNode`` exposes it to
the runtime as ``node.memory``.
"""
from __future__ import annotations

import os
import threading
from typing import Dict, Optional, Set

from .paging import PagingSystem


class SpillStore:
    """Secondary storage for evicted pages. In-memory by default; set
    ``directory`` to spill to real files (used by the I/O benchmarks).
    Tracks every page id it holds so ``clear()`` can delete them all when the
    owning node goes away (PR-3 leak fix: spill files used to outlive their
    pool)."""

    def __init__(self, directory: Optional[str] = None):
        self.directory = directory
        self._mem: Dict[int, bytes] = {}
        self._held: Set[int] = set()
        self.bytes_written = 0
        self.bytes_read = 0
        self.write_ops = 0
        self.read_ops = 0
        if directory:
            os.makedirs(directory, exist_ok=True)

    def _path(self, page_id: int) -> str:
        return os.path.join(self.directory, f"page_{page_id}.bin")

    def write(self, page_id: int, data: bytes) -> None:
        self.bytes_written += len(data)
        self.write_ops += 1
        self._held.add(page_id)
        if self.directory:
            with open(self._path(page_id), "wb") as f:
                f.write(data)
        else:
            self._mem[page_id] = bytes(data)

    def read(self, page_id: int) -> bytes:
        self.read_ops += 1
        if self.directory:
            with open(self._path(page_id), "rb") as f:
                data = f.read()
        else:
            data = self._mem[page_id]
        self.bytes_read += len(data)
        return data

    def delete(self, page_id: int) -> None:
        self._held.discard(page_id)
        if self.directory:
            try:
                os.remove(self._path(page_id))
            except FileNotFoundError:
                pass
        else:
            self._mem.pop(page_id, None)

    def held_page_ids(self) -> Set[int]:
        return set(self._held)

    def clear(self) -> None:
        """Delete every page image this store holds."""
        for pid in list(self._held):
            self.delete(pid)


class MemoryReservation:
    """A ``reserve()`` grant: bytes staged outside the arena but charged to
    this node. Context-managed so staging buffers can't leak accounting."""

    def __init__(self, manager: "MemoryManager", nbytes: int):
        self.manager = manager
        self.nbytes = nbytes
        self._released = False

    def release(self) -> None:
        if not self._released:
            self._released = True
            self.manager._release(self.nbytes)

    def __enter__(self) -> "MemoryReservation":
        return self

    def __exit__(self, *exc) -> None:
        self.release()


class MemoryManager:
    """Owns one node's eviction policy, spill store, and pressure accounting.

    All byte counters are *logical* page bytes (what callers asked for, not
    TLSF-rounded block sizes); ``BufferPool`` drives them through the
    ``note_*`` hooks under its own lock, and external stagers charge
    themselves via ``reserve``.
    """

    def __init__(self, capacity: int, spill_store: Optional[SpillStore] = None,
                 policy: str = "data-aware",
                 pressure_watermark: float = 0.85):
        self.capacity = capacity
        self.spill = spill_store or SpillStore()
        self.paging = PagingSystem(policy)
        self.pressure_watermark = pressure_watermark
        self._lock = threading.RLock()
        # live counters
        self.resident_bytes = 0
        self.pinned_bytes = 0
        # bytes paged OUT: spilled AND not resident (a write-through
        # durability copy of a resident page is not pressure)
        self.spilled_bytes = 0
        self.reserved_bytes = 0    # out-of-arena staging charged via reserve()
        # high-water marks
        self.resident_hwm = 0
        self.pinned_hwm = 0
        self.reserved_hwm = 0
        self.stats: Dict[str, int] = {"evictions": 0, "spill_bytes": 0,
                                      "fetch_bytes": 0, "alloc_retries": 0}

    @property
    def policy(self) -> str:
        return self.paging.policy

    # -- accounting hooks (called by BufferPool) ------------------------------
    def note_alloc(self, nbytes: int) -> None:
        with self._lock:
            self.resident_bytes += nbytes
            self.resident_hwm = max(self.resident_hwm, self.resident_bytes)

    def note_free(self, nbytes: int) -> None:
        with self._lock:
            self.resident_bytes -= nbytes

    def note_pinned(self, nbytes: int) -> None:
        """A page's pin count went 0 -> 1: its bytes are now unevictable."""
        with self._lock:
            self.pinned_bytes += nbytes
            self.pinned_hwm = max(self.pinned_hwm, self.pinned_bytes)

    def note_unpinned(self, nbytes: int) -> None:
        """A page's pin count went 1 -> 0."""
        with self._lock:
            self.pinned_bytes -= nbytes

    def note_spilled(self, nbytes: int) -> None:
        """Bytes written to the spill store (durability copies included)."""
        with self._lock:
            self.stats["spill_bytes"] += nbytes

    def note_paged_out(self, nbytes: int) -> None:
        """A page left residency with its backing copy on "disk"."""
        with self._lock:
            self.spilled_bytes += nbytes

    def note_paged_in(self, nbytes: int) -> None:
        """A paged-out page was faulted back into the arena."""
        with self._lock:
            self.spilled_bytes -= nbytes

    def note_fetched(self, nbytes: int) -> None:
        with self._lock:
            self.stats["fetch_bytes"] += nbytes

    def discard_spilled(self, page_id: int, nbytes: int,
                        paged_out: bool) -> None:
        """Delete a page's spill image (set dropped or lifetime ended);
        ``paged_out`` says whether those bytes were counted as pressure
        (non-resident) or were just a durability copy of a resident page."""
        with self._lock:
            self.spill.delete(page_id)
            if paged_out:
                self.spilled_bytes -= nbytes

    # -- backpressure ----------------------------------------------------------
    def reserve(self, nbytes: int) -> MemoryReservation:
        """Charge ``nbytes`` of out-of-arena staging to this node. Always
        grants (the monolithic pool spills rather than refuses) but moves the
        pressure signal, which is what schedulers and stagers key off."""
        with self._lock:
            self.reserved_bytes += nbytes
            self.reserved_hwm = max(self.reserved_hwm, self.reserved_bytes)
        return MemoryReservation(self, nbytes)

    def _release(self, nbytes: int) -> None:
        with self._lock:
            self.reserved_bytes -= nbytes

    def reset_reserved_hwm(self) -> int:
        """Start a fresh reservation high-water window (returns the old
        mark). Callers that assert a staging bound — e.g. the streaming
        remesh's O(page) driver guarantee — reset first so the measurement
        is theirs, not some earlier stager's."""
        with self._lock:
            old = self.reserved_hwm
            self.reserved_hwm = self.reserved_bytes
            return old

    def under_pressure(self) -> bool:
        """True when the node is past its watermark (arena residency plus
        out-of-arena reservations) or is carrying spilled-out bytes — i.e.
        new work placed here will likely page."""
        with self._lock:
            occupied = self.resident_bytes + self.reserved_bytes
            return (occupied >= self.pressure_watermark * self.capacity
                    or self.spilled_bytes > 0)

    def pressure_score(self) -> float:
        """Scalar pressure in [0, 1] for placement penalties: how far past
        the watermark the node sits, or how much of a capacity's worth of
        data it has already pushed to disk — whichever is worse."""
        with self._lock:
            occupied = self.resident_bytes + self.reserved_bytes
            wm = self.pressure_watermark * self.capacity
            over = max(0.0, occupied - wm) / max(1.0, self.capacity - wm)
            spill_frac = self.spilled_bytes / max(1, self.capacity)
            return min(1.0, max(over, spill_frac))

    def pressure_report(self) -> Dict[str, float]:
        with self._lock:
            return {
                "capacity": self.capacity,
                "resident": self.resident_bytes,
                "pinned": self.pinned_bytes,
                "spilled": self.spilled_bytes,
                "reserved": self.reserved_bytes,
                "resident_hwm": self.resident_hwm,
                "pinned_hwm": self.pinned_hwm,
                "reserved_hwm": self.reserved_hwm,
                "under_pressure": self.under_pressure(),
                "pressure_score": self.pressure_score(),
                **self.stats,
            }

    def close(self) -> None:
        """Tear the node's secondary storage down with it (a dead machine's
        local disk is gone): every spill image this manager wrote is deleted,
        so killed/replaced nodes don't leak spill files."""
        with self._lock:
            self.spill.clear()
            self.spilled_bytes = 0
