"""Per-node memory authority — eviction policy promoted out of the pool.

The paper's §6 data-aware eviction was a ``BufferPool`` internal, which meant
only the pool's own allocation path could see or react to memory pressure.
The ``MemoryManager`` owns everything pressure-related for one node:

* the ``PagingSystem`` (Eq. 1 / Algorithm 1 victim selection) and the
  ``SpillStore`` the victims land in;
* pressure accounting — resident / pinned / spilled / reserved bytes with
  high-water marks, so "how close to the cliff did this workload get" is a
  first-class, assertable number (the streaming-remesh driver budget and the
  reducer pull staging both run through ``reserve``);
* the backpressure API — ``reserve(nbytes)`` for staging buffers that live
  *outside* the arena (driver-side chunks in flight, pull staging), and
  ``under_pressure()`` / ``pressure_score()`` for callers that should slow
  down or place work elsewhere. The cluster scheduler reads the score through
  the statistics DB and penalizes nodes that are already spilling;
* admission control (PR 5) — ``try_reserve(nbytes, urgency=...)`` and the
  ``AdmissionController``: the pressure signal becomes a *grant*. In-flight
  staging is capped at a watermark-derived budget, writers block (with
  timeout) instead of stampeding a pressured node, and refusals are counted
  so schedulers can re-route refused work instead of pushing pages at a node
  that is already spilling.

``BufferPool`` delegates to it (``pool.paging`` / ``pool.spill`` /
``pool.stats`` are views into the manager), and ``StorageNode`` exposes it to
the runtime as ``node.memory``.
"""
from __future__ import annotations

import os
from typing import Dict, List, Optional, Set

from .attributes import DurabilityType
from .pagelog import PageLog
from .paging import PagingSystem
from .sanitizer import tracked_condition, tracked_rlock

# smallest staging budget a node will advertise: tiny pools (unit tests,
# smoke configs) must still admit a page-sized chunk or nothing ever moves
STAGING_CAP_FLOOR = 256 << 10


def derive_staging_cap(capacity: int, watermark: float) -> int:
    """The in-flight staging budget the pressure watermark implies: the
    headroom the watermark leaves free is what out-of-arena staging may
    occupy at once, floored so small pools still admit one chunk."""
    return max(min(capacity, STAGING_CAP_FLOOR),
               int((1.0 - watermark) * capacity))


class SpillStore:
    """Secondary storage for evicted pages. In-memory by default; set
    ``directory`` to spill to real files (used by the I/O benchmarks).
    Tracks every page id it holds so ``clear()`` can delete them all when the
    owning node goes away (PR-3 leak fix: spill files used to outlive their
    pool)."""

    def __init__(self, directory: Optional[str] = None):
        self.directory = directory
        self._mem: Dict[int, bytes] = {}
        self._held: Set[int] = set()
        self.bytes_written = 0
        self.bytes_read = 0
        self.write_ops = 0
        self.read_ops = 0
        if directory:
            os.makedirs(directory, exist_ok=True)

    def _path(self, page_id: int) -> str:
        return os.path.join(self.directory, f"page_{page_id}.bin")

    def write(self, page_id: int, data: bytes) -> None:
        self.bytes_written += len(data)
        self.write_ops += 1
        self._held.add(page_id)
        if self.directory:
            with open(self._path(page_id), "wb") as f:
                f.write(data)
        else:
            self._mem[page_id] = bytes(data)

    def read(self, page_id: int) -> bytes:
        self.read_ops += 1
        if self.directory:
            with open(self._path(page_id), "rb") as f:
                data = f.read()
        else:
            data = self._mem[page_id]
        self.bytes_read += len(data)
        return data

    def delete(self, page_id: int) -> None:
        self._held.discard(page_id)
        if self.directory:
            try:
                os.remove(self._path(page_id))
            except FileNotFoundError:
                pass
        else:
            self._mem.pop(page_id, None)

    def held_page_ids(self) -> Set[int]:
        return set(self._held)

    def clear(self) -> None:
        """Delete every page image this store holds."""
        for pid in list(self._held):
            self.delete(pid)


class MemoryReservation:
    """A ``reserve()``/``try_reserve()`` grant: bytes staged outside the arena
    but charged to this node. Context-managed so staging buffers can't leak
    accounting. Release is idempotent *under the manager's lock* — two racing
    releasers (a worker's ``finally`` and an engine-side cleanup) must not
    decrement twice and silently drive ``reserved_bytes`` negative."""

    def __init__(self, manager: "MemoryManager", nbytes: int):
        self.manager = manager
        self.nbytes = nbytes
        self._released = False

    def release(self) -> None:
        with self.manager._lock:
            if self._released:
                return
            self._released = True
            self.manager._release(self.nbytes)

    def __enter__(self) -> "MemoryReservation":
        return self

    def __exit__(self, *exc) -> None:
        self.release()


class AdmissionController:
    """Turns one node's pressure signal into an admission decision (PR 5).

    Two distinct questions, both derived from the watermark:

    * **staging admission** (``try_reserve`` via the manager) — may a writer
      put another ``nbytes`` of out-of-arena staging in flight right now?
      Granted while ``reserved_bytes`` stays under ``cap`` (a node with no
      staging in flight always admits one chunk, however large, so oversized
      single requests can't starve). Writers wait on the node's condition
      variable and are woken by releases.
    * **placement admission** (``admit_placement``) — would ``nbytes`` of new
      work *landing* on this node fit under the pressure watermark given
      what is already resident and staged? The cluster scheduler probes this
      with a deadline before pinning a reducer here and re-routes the
      partition when the node refuses past it.

    ``refused`` / ``throttled`` / ``forced`` count what the loop actually did
    and are published through ``pressure_report`` (and from there into the
    statistics DB alongside the pressure score).
    """

    #: bound on how long a waiting ask (``normal`` or ``required``) parks
    #: when the caller gave no timeout — an unbounded wait could deadlock a
    #: caller whose own earlier reservation is what holds the cap, and for
    #: "required" it would make the promised forced grant unreachable
    DEFAULT_WAIT_TIMEOUT_S = 1.0

    def __init__(self, manager: "MemoryManager", cap: Optional[int] = None):
        self.manager = manager
        self.cap = (derive_staging_cap(manager.capacity,
                                       manager.pressure_watermark)
                    if cap is None else cap)
        self._cv = tracked_condition("memman.cv", manager._lock)
        self.refused = 0      # asks denied past their deadline
        self.throttled = 0    # asks that waited before being granted
        self.forced = 0       # urgency="required" grants past the deadline
        self.waiting = 0      # asks currently parked on the condition var
        self._listeners: List = []   # notify hooks (event-name callbacks)

    # -- event hooks (deflaked tests, serving-tier schedulers) ---------------
    def add_notify_listener(self, fn) -> None:
        """Register ``fn(event)`` called on admission state changes:
        ``"waiting"`` when an ask parks on the condition variable and
        ``"release"`` whenever headroom appears (reservation released, pages
        freed, durable handoff). Callbacks run under the manager lock and
        must be non-blocking (set an ``Event``, bump a counter — no manager
        calls)."""
        with self._cv:
            self._listeners.append(fn)

    def remove_notify_listener(self, fn) -> None:
        with self._cv:
            self._listeners.remove(fn)

    def _fire(self, event: str) -> None:
        for fn in list(self._listeners):
            fn(event)

    def wait_until(self, predicate, timeout: float = 5.0) -> bool:
        """Park on the node's condition variable until ``predicate()`` holds
        (checked under the manager lock on every admission event) — the
        event-driven replacement for wall-clock polling loops in tests."""
        with self._cv:
            return self._cv.wait_for(predicate, timeout=timeout)

    # both predicates assume the manager's lock is held
    def _staging_headroom(self, nbytes: int) -> bool:
        m = self.manager
        return (m.reserved_bytes == 0
                or m.reserved_bytes + nbytes <= self.cap)

    def _placement_headroom(self, nbytes: int) -> bool:
        m = self.manager
        occupied = m.resident_bytes + m.reserved_bytes
        return occupied + nbytes <= m.pressure_watermark * m.capacity

    def _notify(self) -> None:
        self._cv.notify_all()
        self._fire("release")

    def try_reserve(self, nbytes: int, *, urgency: str = "normal",
                    timeout: Optional[float] = None
                    ) -> Optional[MemoryReservation]:
        """Staging admission with blocking-with-timeout waits.

        * ``urgency="low"`` — never waits; refused immediately without
          headroom (opportunistic stagers, e.g. prefetchers).
        * ``urgency="normal"`` — waits up to ``timeout`` for headroom, then
          is refused (callers re-route or retry elsewhere).
        * ``urgency="required"`` — waits up to ``timeout``, then is granted
          anyway (correctness paths that must not drop data; the monolithic
          pool spills rather than loses records). Counted as ``forced``.
        """
        if urgency not in ("low", "normal", "required"):
            raise ValueError(f"unknown urgency {urgency!r}")
        if timeout is None and urgency != "low":
            # bounded by default: waiting forever could deadlock a caller
            # whose own earlier reservation holds the cap, and for
            # "required" it would make the promised forced grant unreachable
            timeout = self.DEFAULT_WAIT_TIMEOUT_S
        m = self.manager
        with self._cv:
            if not self._staging_headroom(nbytes):
                granted = False
                if urgency != "low" and timeout > 0:
                    self.waiting += 1
                    # wake wait_until() watchers of `waiting` (they re-check
                    # their predicate and re-park; peers see no headroom change)
                    self._cv.notify_all()
                    self._fire("waiting")
                    try:
                        granted = self._cv.wait_for(
                            lambda: self._staging_headroom(nbytes),
                            timeout=timeout)
                    finally:
                        self.waiting -= 1
                        self._cv.notify_all()
                if granted:
                    self.throttled += 1
                else:
                    if urgency != "required":
                        self.refused += 1
                        return None
                    self.forced += 1
            m.reserved_bytes += nbytes
            m.reserved_hwm = max(m.reserved_hwm, m.reserved_bytes)
        return MemoryReservation(m, nbytes)

    def admit_placement(self, nbytes: int,
                        deadline_s: Optional[float] = 0.0,
                        count: bool = True) -> bool:
        """Placement admission: True when ``nbytes`` of landing work fits
        under the watermark, waiting up to ``deadline_s`` for headroom to
        appear. A refusal past the deadline is counted — the scheduler's cue
        to re-place the work on the next-best candidate. ``count=False``
        marks a cheap re-probe of a node that already refused this planning
        pass, so probe declines don't inflate the ``refused`` counter."""
        with self._cv:
            if self._placement_headroom(nbytes):
                return True
            if deadline_s and self._cv.wait_for(
                    lambda: self._placement_headroom(nbytes),
                    timeout=deadline_s):
                self.throttled += 1
                return True
            if count:
                self.refused += 1
            return False


class MemoryManager:
    """Owns one node's eviction policy, spill store, and pressure accounting.

    All byte counters are *logical* page bytes (what callers asked for, not
    TLSF-rounded block sizes); ``BufferPool`` drives them through the
    ``note_*`` hooks under its own lock, and external stagers charge
    themselves via ``reserve``.
    """

    def __init__(self, capacity: int, spill_store: Optional[SpillStore] = None,
                 policy: str = "data-aware",
                 pressure_watermark: float = 0.85,
                 admission_cap: Optional[int] = None,
                 pagelog: Optional[PageLog] = None):
        self.capacity = capacity
        self.spill = spill_store or SpillStore()
        # the durable tier beneath the scratch spill store: write-through
        # sets page against it instead, and it survives node death
        self.pagelog = pagelog
        self.paging = PagingSystem(policy)
        self.pressure_watermark = pressure_watermark
        self._lock = tracked_rlock("memman")
        self.admission = AdmissionController(self, admission_cap)
        # live counters
        self.resident_bytes = 0
        self.pinned_bytes = 0
        # bytes paged OUT: spilled AND not resident (a write-through
        # durability copy of a resident page is not pressure)
        self.spilled_bytes = 0
        # bytes whose only live copy is the durable page log. NOT pressure:
        # the log is long-lived data's home tier, not an eviction overflow —
        # a node serving a larger-than-RAM set from its log must keep
        # attracting placement, which ``spilled_bytes`` would repel
        self.durable_bytes = 0
        self.reserved_bytes = 0    # out-of-arena staging charged via reserve()
        # high-water marks
        self.resident_hwm = 0
        self.pinned_hwm = 0
        self.reserved_hwm = 0
        self.stats: Dict[str, int] = {"evictions": 0, "spill_bytes": 0,
                                      "fetch_bytes": 0, "alloc_retries": 0,
                                      "log_bytes": 0, "log_fetch_bytes": 0}

    @property
    def policy(self) -> str:
        return self.paging.policy

    # -- accounting hooks (called by BufferPool) ------------------------------
    def note_alloc(self, nbytes: int) -> None:
        with self._lock:
            self.resident_bytes += nbytes
            self.resident_hwm = max(self.resident_hwm, self.resident_bytes)

    def note_free(self, nbytes: int) -> None:
        with self._lock:
            self.resident_bytes -= nbytes
            # freed residency is admission headroom: wake placement probes
            # and throttled writers now instead of letting them sleep out
            # their full deadline against a predicate that already holds
            self.admission._notify()

    def note_pinned(self, nbytes: int) -> None:
        """A page's pin count went 0 -> 1: its bytes are now unevictable."""
        with self._lock:
            self.pinned_bytes += nbytes
            self.pinned_hwm = max(self.pinned_hwm, self.pinned_bytes)

    def note_unpinned(self, nbytes: int) -> None:
        """A page's pin count went 1 -> 0."""
        with self._lock:
            self.pinned_bytes -= nbytes

    def note_spilled(self, nbytes: int) -> None:
        """Bytes written to the spill store (durability copies included)."""
        with self._lock:
            self.stats["spill_bytes"] += nbytes

    def note_paged_out(self, nbytes: int) -> None:
        """A page left residency with its backing copy on "disk"."""
        with self._lock:
            self.spilled_bytes += nbytes

    def note_paged_in(self, nbytes: int) -> None:
        """A paged-out page was faulted back into the arena."""
        with self._lock:
            self.spilled_bytes -= nbytes

    def note_fetched(self, nbytes: int) -> None:
        with self._lock:
            self.stats["fetch_bytes"] += nbytes

    def discard_spilled(self, page_id: int, nbytes: int,
                        paged_out: bool) -> None:
        """Delete a page's spill image (set dropped or lifetime ended);
        ``paged_out`` says whether those bytes were counted as pressure
        (non-resident) or were just a durability copy of a resident page."""
        with self._lock:
            self.spill.delete(page_id)
            if paged_out:
                self.spilled_bytes -= nbytes

    # -- durable tier (page log) ----------------------------------------------
    def durable_route(self, ls) -> bool:
        """Whether a set's persisted images belong in the page log instead of
        the scratch spill store: write-through durability (long-lived user
        data, paper §4) on a node that has a durable tier configured."""
        return (self.pagelog is not None
                and ls.attrs.durability == DurabilityType.WRITE_THROUGH)

    def pagelog_write(self, set_name: str, page, data: bytes) -> None:
        """Persist one page image into the durable log, keyed
        ``(set, page.log_seq)``; first write allocates the set's next
        sequence number, rewrites supersede in place (append-only)."""
        # The log runs under its own lock (and fsyncs outside it); holding
        # the manager lock across disk I/O would stall every accounting hook
        # behind an appender.  Same-page write races are excluded upstream
        # by the buffer pool's lock, so seq consistency survives the move.
        entry = self.pagelog.append(
            set_name, data, seq=page.log_seq if page.log_seq >= 0 else None)
        with self._lock:
            page.log_seq = entry.seq
            page.durable = True
            self.stats["log_bytes"] += len(data)

    def pagelog_read(self, set_name: str, seq: int) -> bytes:
        data = self.pagelog.read(set_name, seq)
        with self._lock:
            self.stats["log_fetch_bytes"] += len(data)
        return data

    def note_durable_out(self, nbytes: int) -> None:
        """A page's only live copy is now the durable log (evicted clean, or
        adopted non-resident at warm start)."""
        with self._lock:
            self.durable_bytes += nbytes

    def note_durable_in(self, nbytes: int) -> None:
        """A log-backed page was faulted back into the arena."""
        with self._lock:
            self.durable_bytes -= nbytes
            self.admission._notify()

    def discard_durable(self, nbytes: int, paged_out: bool) -> None:
        """Account a dropped durable page; the log itself is append-only, so
        the set-level tombstone (``PageLog.drop_set``) is the actual cut."""
        with self._lock:
            if paged_out:
                self.durable_bytes -= nbytes

    # -- backpressure / admission ---------------------------------------------
    def reserve(self, nbytes: int) -> MemoryReservation:
        """Charge ``nbytes`` of out-of-arena staging to this node. Always
        grants (the monolithic pool spills rather than refuses) but moves the
        pressure signal, which is what schedulers and stagers key off.
        Paced writers use ``try_reserve`` instead and respect the grant."""
        with self._lock:
            self.reserved_bytes += nbytes
            self.reserved_hwm = max(self.reserved_hwm, self.reserved_bytes)
        return MemoryReservation(self, nbytes)

    def try_reserve(self, nbytes: int, *, urgency: str = "normal",
                    timeout: Optional[float] = None
                    ) -> Optional[MemoryReservation]:
        """Admission-controlled staging grant — see ``AdmissionController``.
        Returns None when the node refuses past the timeout (the caller
        should back off or route elsewhere); ``urgency="required"`` never
        returns None."""
        return self.admission.try_reserve(nbytes, urgency=urgency,
                                          timeout=timeout)

    def _release(self, nbytes: int) -> None:
        with self._lock:
            self.reserved_bytes -= nbytes
            if self.reserved_bytes < 0:
                # explicit raise, not `assert`: accounting corruption must
                # stay loud under `python -O` too
                raise AssertionError(
                    f"reserved_bytes went negative ({self.reserved_bytes}) "
                    f"— a reservation was released more bytes than it "
                    f"charged")
            self.admission._notify()

    def reset_reserved_hwm(self) -> int:
        """Start a fresh reservation high-water window (returns the old
        mark). Callers that assert a staging bound — e.g. the streaming
        remesh's O(page) driver guarantee — reset first so the measurement
        is theirs, not some earlier stager's."""
        with self._lock:
            old = self.reserved_hwm
            self.reserved_hwm = self.reserved_bytes
            return old

    def under_pressure(self) -> bool:
        """True when the node is past its watermark (arena residency plus
        out-of-arena reservations), or is carrying more paged-out bytes than
        its remaining watermark headroom could fault back — i.e. new work
        placed here will likely page.

        Paged-out bytes alone are NOT pressure (PR-5 bugfix): after a burst
        is consumed and dropped, a node may hold cold data on disk while its
        arena sits nearly empty. Those bytes fault back on demand into free
        space, so the node should attract placement again — the old
        ``spilled_bytes > 0`` check repelled it indefinitely. Durability
        copies of resident pages were never counted here (they are images,
        not page-outs) and still are not."""
        with self._lock:
            occupied = self.resident_bytes + self.reserved_bytes
            wm = self.pressure_watermark * self.capacity
            return occupied >= wm or occupied + self.spilled_bytes > wm

    def pressure_score(self) -> float:
        """Scalar pressure in [0, 1] for placement penalties: how far past
        the watermark the node sits, counting only the paged-out bytes that
        could NOT fault back under the watermark (cold on-disk residue with
        free headroom above it scores zero — see ``under_pressure``)."""
        with self._lock:
            occupied = self.resident_bytes + self.reserved_bytes
            wm = self.pressure_watermark * self.capacity
            over = max(0.0, occupied - wm) / max(1.0, self.capacity - wm)
            spill_over = max(0.0, occupied + self.spilled_bytes - wm) \
                / max(1, self.capacity)
            return min(1.0, max(over, spill_over))

    def pressure_report(self) -> Dict[str, float]:
        with self._lock:
            return {
                "capacity": self.capacity,
                "resident": self.resident_bytes,
                "pinned": self.pinned_bytes,
                "spilled": self.spilled_bytes,
                "durable": self.durable_bytes,
                "reserved": self.reserved_bytes,
                "resident_hwm": self.resident_hwm,
                "pinned_hwm": self.pinned_hwm,
                "reserved_hwm": self.reserved_hwm,
                "under_pressure": self.under_pressure(),
                "pressure_score": self.pressure_score(),
                "admission_cap": self.admission.cap,
                "refused": self.admission.refused,
                "throttled": self.admission.throttled,
                "forced": self.admission.forced,
                "waiting": self.admission.waiting,
                **self.stats,
                **(
                    {
                        "pagelog_bytes": self.pagelog.file_bytes(),
                        "pagelog_amplification": self.pagelog.amplification(),
                        "pagelog_generation": self.pagelog.generation,
                        "pagelog_compactions": self.pagelog.compactions,
                    }
                    if self.pagelog is not None else {}
                ),
            }

    def close(self) -> None:
        """Tear the node's SCRATCH storage down with it: every spill image
        this manager wrote is deleted, so killed/replaced nodes don't leak
        spill files. The durable page log is deliberately NOT wiped — its
        files surviving the process is the entire point of the tier; only
        its handles are closed. (A cold restart that really lost the disk is
        modeled by ``Cluster.revive_node(warm=False)``, which removes the
        log directory before reopening.)"""
        with self._lock:
            self.spill.clear()
            self.spilled_bytes = 0
            self.durable_bytes = 0
            if self.pagelog is not None:
                self.pagelog.close()
