"""Locality-set attributes (paper Table 2) and spilling costs (paper Table 3).

Every locality set carries a tag vector describing *how* an application uses it.
Attributes are either declared at creation time or inferred automatically from
the service that touches the set (paper §3.2 "Determining attributes").
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field


class DurabilityType(enum.Enum):
    """write-through: persist immediately on write (user data).

    write-back: keep in the pool; spill only on eviction (job/execution data).
    """

    WRITE_THROUGH = "write-through"
    WRITE_BACK = "write-back"


class WritingPattern(enum.Enum):
    SEQUENTIAL_WRITE = "sequential-write"      # immutable, write-once, in order
    CONCURRENT_WRITE = "concurrent-write"      # many streams into one page (shuffle)
    RANDOM_MUTABLE_WRITE = "random-mutable-write"  # alloc/modify/free (hash, KV state)
    NONE = "none"


class ReadingPattern(enum.Enum):
    SEQUENTIAL_READ = "sequential-read"
    RANDOM_READ = "random-read"
    NONE = "none"


class Location(enum.Enum):
    PINNED = "pinned"
    UNPINNED = "unpinned"


class Lifetime(enum.Enum):
    ALIVE = "alive"
    ENDED = "lifetime-ended"


class CurrentOperation(enum.Enum):
    READ = "read"
    WRITE = "write"
    READ_AND_WRITE = "read-and-write"
    IDLE = "idle"


class EvictionStrategy(enum.Enum):
    MRU = "mru"
    LRU = "lru"


class StorageScheme(enum.Enum):
    """Physical page layout of a locality set.

    row: ``[count:int64][record bytes...]`` — each page holds contiguous
    fixed-width records (the seed layout; every legacy set uses it).

    columnar: ``[count:int64][validity bitmap][col0 block][col1 block]...``
    — each page holds one column block: per-field contiguous arrays plus a
    validity bitmap (arrow-ish). Selected per set so the vectorized shuffle /
    aggregate / join kernels can stream whole columns without per-record
    decode; spill and pagelog paths are layout-oblivious (pages are opaque
    byte payloads either way).
    """

    ROW = "row"
    COLUMNAR = "columnar"


# ---------------------------------------------------------------------------
# Paper Table 3: normalized spilling-cost constants `c`.
# The cost is keyed on (reading/writing pattern, durability) because those are
# "the main factors determining the spilling cost" (paper §6 factor 2).
# ---------------------------------------------------------------------------
SPILL_COST_SEQ_WRITE_THROUGH = 1.0
SPILL_COST_SEQ_WRITE_BACK = 2.5
SPILL_COST_CONCURRENT_WRITE_BACK = 2.5
SPILL_COST_RANDOM_WRITE_BACK = 5.0


def spilling_cost(
    writing: WritingPattern,
    reading: ReadingPattern,
    durability: DurabilityType,
) -> float:
    """Table-3 lookup: normalized cost `c` of spilling one page of this set."""
    random_access = (
        writing == WritingPattern.RANDOM_MUTABLE_WRITE
        or reading == ReadingPattern.RANDOM_READ
    )
    if random_access:
        return SPILL_COST_RANDOM_WRITE_BACK
    if writing == WritingPattern.CONCURRENT_WRITE:
        return SPILL_COST_CONCURRENT_WRITE_BACK
    if durability == DurabilityType.WRITE_BACK:
        return SPILL_COST_SEQ_WRITE_BACK
    return SPILL_COST_SEQ_WRITE_THROUGH


def select_strategy(writing: WritingPattern, reading: ReadingPattern) -> EvictionStrategy:
    """Paper §6: MRU for sequential-write / concurrent-write / sequential-read
    locality sets, LRU for random-mutable-write / random-read sets."""
    if (
        writing == WritingPattern.RANDOM_MUTABLE_WRITE
        or reading == ReadingPattern.RANDOM_READ
    ):
        return EvictionStrategy.LRU
    return EvictionStrategy.MRU


# Eviction-ratio tuning (paper §6): evict only this fraction of unpinned pages
# from a victim set whose CurrentOperation involves `write`; a set that is only
# being read has no such limit (ratio 1.0).
WRITE_EVICTION_RATIO = 0.10


def eviction_ratio(op: CurrentOperation) -> float:
    if op in (CurrentOperation.WRITE, CurrentOperation.READ_AND_WRITE):
        return WRITE_EVICTION_RATIO
    return 1.0


@dataclass
class AttributeSet:
    """The full Table-2 tag vector for one locality set."""

    durability: DurabilityType = DurabilityType.WRITE_BACK
    writing: WritingPattern = WritingPattern.NONE
    reading: ReadingPattern = ReadingPattern.NONE
    lifetime: Lifetime = Lifetime.ALIVE
    operation: CurrentOperation = CurrentOperation.IDLE
    storage: StorageScheme = StorageScheme.ROW
    access_recency: int = 0  # integer timestamp of last access (paper Table 2)
    # free-form labels an application may attach (e.g. "kv-cache", "layer=3")
    labels: dict = field(default_factory=dict)

    @property
    def spilling_cost(self) -> float:
        return spilling_cost(self.writing, self.reading, self.durability)

    @property
    def strategy(self) -> EvictionStrategy:
        return select_strategy(self.writing, self.reading)

    @property
    def eviction_ratio(self) -> float:
        return eviction_ratio(self.operation)
