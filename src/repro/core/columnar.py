"""Columnar page layout + fused partition/aggregate kernels (arrow-ish).

The row scheme stores ``[count:int64][record bytes...]`` — every hot path
then loops over record *rows*, so shuffle/aggregate/join throughput is bound
by the Python interpreter, not memory bandwidth. This module adds the second
``StorageScheme`` the paper's locality sets can select (Shark's in-memory
columnar store is the precedent — PAPERS.md): each page holds one **column
block**::

    [count:int64][pad][validity bitmap][field0 cap*w0][field1 cap*w1]...

* ``count`` — records in this block (<= the layout's fixed capacity).
* validity bitmap — one bit per slot (LSB-first within each byte); all
  current producers write fully valid blocks, but the format carries the
  bitmap so nullable columns slot in without a layout change.
* field regions — one contiguous fixed-width array per record field, each
  sized for the block's full capacity and 8-byte aligned, so a column can be
  viewed as its numpy dtype with zero copies.

Because capacity (and so every region offset) is a pure function of
``(dtype, page_size)``, blocks are self-describing given the set's dtype —
the spill store and the durable page log persist them as the same opaque page
images as row pages (layout-oblivious durability).

The fused hot-path kernel lives here too: :func:`fused_partition_crc` does
reducer-hash -> dispatch plan -> per-column gather -> per-partition
incremental CRC32 in one vectorized pass per block (the host analogue of
``kernels/shuffle_dispatch``; its ``ops`` module re-exports this so the
kernel package stays the single import point for dispatch math).

Checksum compatibility: :func:`columnar_content_checksum` computes the exact
``replication.record_content_checksum`` value from column arrays without
materializing rows — per-record multipliers are sliced per field at the
field's byte offset, and the mod-2**64 wraparound arithmetic commutes over
the per-field partial sums — so row-oriented and columnar shards verify
against each other byte-for-byte.
"""
from __future__ import annotations

import zlib
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from .attributes import AttributeSet, CurrentOperation, StorageScheme
from .buffer_pool import BufferPool
from .locality_set import LocalitySet, Page
from .replication import _CONTENT_MIX, _CONTENT_MULT

_HEADER = 8  # int64 record count at block start (same as row pages)

# reducer-routing hash constants — MUST match ClusterShuffle.partition_of_keys
_ROUTE_MULT = np.uint64(0xC2B2AE3D27D4EB4F)


def _pad8(n: int) -> int:
    return (n + 7) & ~7


class ColumnLayout:
    """Region offsets of one column block for ``(dtype, page_size)``.

    Solved once and cached: capacity is the largest ``n`` such that header +
    padded validity bitmap + padded per-field regions fit the page.
    """

    _cache: Dict[Tuple[np.dtype, int], "ColumnLayout"] = {}

    def __init__(self, dtype: np.dtype, page_size: int):
        dtype = np.dtype(dtype)
        self.dtype = dtype
        self.page_size = page_size
        self.fields = _field_layout(dtype)
        width = sum(w for _, _, _, w in self.fields)
        if width != dtype.itemsize:
            raise ValueError(
                f"columnar layout needs a packed dtype: fields cover {width} "
                f"bytes but itemsize is {dtype.itemsize}")
        # estimate then shrink past padding: per record cost w + 1/8 bit
        cap = ((page_size - _HEADER) * 8) // (8 * width + 1)
        while cap > 0 and self._block_bytes(cap) > page_size:
            cap -= 1
        if cap < 1:
            raise ValueError("page too small for one columnar record")
        self.capacity = cap
        self.validity_off = _HEADER
        self.validity_bytes = (cap + 7) // 8
        off = _pad8(self.validity_off + self.validity_bytes)
        self.field_offs: Dict[str, int] = {}
        for name, _, _, w in self.fields:
            self.field_offs[name] = off
            off = _pad8(off + cap * w)
        self.block_bytes = off

    def _block_bytes(self, cap: int) -> int:
        off = _pad8(_HEADER + (cap + 7) // 8)
        for _, _, _, w in self.fields:
            off = _pad8(off + cap * w)
        return off

    @classmethod
    def for_page(cls, dtype: np.dtype, page_size: int) -> "ColumnLayout":
        key = (np.dtype(dtype), page_size)
        layout = cls._cache.get(key)
        if layout is None:
            layout = cls._cache[key] = cls(key[0], page_size)
        return layout


_FIELD_LAYOUT_CACHE: Dict[np.dtype, List[Tuple[str, np.dtype, int, int]]] = {}


def _field_layout(dtype: np.dtype) -> List[Tuple[str, np.dtype, int, int]]:
    """``(name, field_dtype, byte_offset_in_record, itemsize)`` per field, in
    record byte order (the order the checksum multipliers walk). Cached per
    dtype — this sits under every per-block hot-path call."""
    dtype = np.dtype(dtype)
    out = _FIELD_LAYOUT_CACHE.get(dtype)
    if out is not None:
        return out
    if dtype.names is None:
        # plain/subarray dtype: treat as a single anonymous column
        out = [("", dtype, 0, dtype.itemsize)]
    else:
        out = []
        for name in dtype.names:
            fdt, off = dtype.fields[name][:2]
            out.append((name, fdt, off, fdt.itemsize))
        out.sort(key=lambda t: t[2])
    _FIELD_LAYOUT_CACHE[dtype] = out
    return out


def _col_view(col: np.ndarray) -> np.ndarray:
    """Flat uint8 view of a column chunk (scalar or subarray field)."""
    return np.ascontiguousarray(col).view(np.uint8).reshape(-1)


def records_to_columns(records: np.ndarray) -> Dict[str, np.ndarray]:
    """Structured record array -> per-field contiguous column arrays."""
    if records.dtype.names is None:
        return {"": np.ascontiguousarray(records)}
    return {name: np.ascontiguousarray(records[name])
            for name in records.dtype.names}


def columns_to_records(columns: Dict[str, np.ndarray], dtype: np.dtype,
                       n: Optional[int] = None) -> np.ndarray:
    """Per-field columns -> structured record array (row materialization)."""
    dtype = np.dtype(dtype)
    if dtype.names is None:
        col = columns[""]
        return np.ascontiguousarray(col[:n] if n is not None else col)
    if n is None:
        n = len(next(iter(columns.values())))
    out = np.empty(n, dtype)
    for name in dtype.names:
        out[name] = columns[name][:n]
    return out


def concat_columns(chunks: Sequence[Dict[str, np.ndarray]],
                   dtype: np.dtype) -> Tuple[Dict[str, np.ndarray], int]:
    """Concatenate column-chunk dicts field-wise -> ``(columns, n)``."""
    names = [name for name, _, _, _ in _field_layout(dtype)]
    if not chunks:
        empty = columns_of_empty(dtype)
        return empty, 0
    cols = {name: np.concatenate([c[name] for c in chunks])
            for name in names}
    return cols, len(cols[names[0]])


def columns_of_empty(dtype: np.dtype) -> Dict[str, np.ndarray]:
    empty = np.empty(0, np.dtype(dtype))
    return records_to_columns(empty)


# ---------------------------------------------------------------------------
# Block codec: encode/decode one page's column block
# ---------------------------------------------------------------------------
def write_block(view: np.ndarray, layout: ColumnLayout,
                columns: Dict[str, np.ndarray], n: int) -> None:
    """Encode ``n`` records of ``columns`` into a page view (full rewrite)."""
    view[:_HEADER].view(np.int64)[0] = n
    validity = view[layout.validity_off:layout.validity_off
                    + layout.validity_bytes]
    full, rem = divmod(n, 8)
    validity[:full] = 0xFF
    if rem:
        validity[full] = (1 << rem) - 1
    if full + (1 if rem else 0) < layout.validity_bytes:
        validity[full + (1 if rem else 0):] = 0
    for name, _, _, w in layout.fields:
        off = layout.field_offs[name]
        view[off:off + n * w] = _col_view(columns[name][:n])


def append_block(view: np.ndarray, layout: ColumnLayout, count: int,
                 columns: Dict[str, np.ndarray], i: int, take: int) -> int:
    """Append ``columns[i:i+take]`` after ``count`` existing records; returns
    the new count. Used by writers filling a block across batches."""
    new = count + take
    view[:_HEADER].view(np.int64)[0] = new
    validity = view[layout.validity_off:layout.validity_off
                    + layout.validity_bytes]
    full, rem = divmod(new, 8)
    pfull = count // 8
    validity[pfull:full] = 0xFF
    if rem:
        validity[full] = (1 << rem) - 1
    for name, _, _, w in layout.fields:
        off = layout.field_offs[name]
        view[off + count * w:off + new * w] = _col_view(columns[name][i:i + take])
    return new


def read_block(view: np.ndarray, layout: ColumnLayout
               ) -> Tuple[Dict[str, np.ndarray], int]:
    """Decode a page view into zero-copy column views + record count."""
    n = int(view[:_HEADER].view(np.int64)[0])
    cols: Dict[str, np.ndarray] = {}
    for name, fdt, _, w in layout.fields:
        off = layout.field_offs[name]
        raw = view[off:off + n * w]
        if fdt.subdtype is not None:
            base, shape = fdt.subdtype
            cols[name] = raw.view(base).reshape((n, *shape))
        else:
            cols[name] = raw.view(fdt)
    return cols, n


def block_validity(view: np.ndarray, layout: ColumnLayout) -> np.ndarray:
    """The raw validity bitmap bytes of a block (LSB-first bit per slot)."""
    return view[layout.validity_off:layout.validity_off
                + layout.validity_bytes]


# ---------------------------------------------------------------------------
# Columnar sequential write/read service
# ---------------------------------------------------------------------------
class ColumnarWriter:
    """Columnar twin of ``services.SequentialWriter``: append fixed-dtype
    records (or pre-split columns) block by block. Accepting columns directly
    lets the fused shuffle path route gathered column slices into pages
    without ever materializing rows."""

    def __init__(self, pool: BufferPool, ls: LocalitySet, dtype: np.dtype):
        self.pool = pool
        self.ls = ls
        self.dtype = np.dtype(dtype)
        self.layout = ColumnLayout.for_page(self.dtype, ls.page_size)
        self.per_page = self.layout.capacity
        self._page: Optional[Page] = None
        self._view: Optional[np.ndarray] = None
        self._count = 0
        # flattened (name, region offset, itemsize, base dtype, row shape)
        # per field — the gather path runs per map block, so the dict/attr
        # lookups are hoisted out of it once here
        self._gfields = []
        for name, fdt, _, w in self.layout.fields:
            if fdt.subdtype is not None:
                base, shape = fdt.subdtype
            else:
                base, shape = fdt, None
            self._gfields.append(
                (name, self.layout.field_offs[name], w, base, shape))
        ls.infer_from_service("sequential-write", pool.clock)

    def _open_page(self) -> None:
        self._page = self.pool.new_page(self.ls)
        self._count = 0
        # the page stays pinned until _close_page, so its view is stable:
        # cache it instead of re-resolving per append
        self._view = self.pool.view(self._page)
        self._view[:_HEADER].view(np.int64)[0] = 0
        block_validity(self._view, self.layout)[:] = 0

    def _close_page(self) -> None:
        if self._page is None:
            return
        # header count + validity are written once here, not per append —
        # the page is pinned (unspillable, not written through) until this
        # unpin, so no reader or durability path sees the stale header
        view = self._view
        view[:_HEADER].view(np.int64)[0] = self._count
        validity = block_validity(view, self.layout)
        full, rem = divmod(self._count, 8)
        validity[:full] = 0xFF
        if rem:
            validity[full] = (1 << rem) - 1
        self.pool.unpin(self._page, dirty=True)
        self._page = None
        self._view = None

    def append_flat(self, flats: Dict[str, np.ndarray], n: int,
                    start: int = 0) -> None:
        """Append ``n`` records starting at record ``start`` from flat uint8
        per-field views (``_col_view`` of each full column). The bulk landing
        path computes the flat views once per routed page and calls this per
        partition — each append is then one slice assignment per field."""
        i = start
        stop = start + n
        layout = self.layout
        offs = layout.field_offs
        while i < stop:
            if self._page is None:
                self._open_page()
            count = self._count
            take = min(self.per_page - count, stop - i)
            new = count + take
            view = self._view
            for name, _, _, w in layout.fields:
                off = offs[name]
                view[off + count * w:off + new * w] = \
                    flats[name][i * w:(i + take) * w]
            self._count = new
            i += take
            if new == self.per_page:
                self._close_page()

    def append_columns(self, columns: Dict[str, np.ndarray], n: int,
                       start: int = 0) -> None:
        self.append_flat(
            {name: _col_view(columns[name]) for name, _, _, _
             in self.layout.fields}, n, start=start)

    def gather_append(self, columns: Dict[str, np.ndarray],
                      order: np.ndarray, lo: int, hi: int,
                      crcs: Optional[List[int]] = None) -> List[int]:
        """Land ``columns[order[lo:hi]]`` straight into this writer's pages:
        ``np.take`` gathers each field directly into the open page's column
        region (no routed intermediate array anywhere), and the per-field
        CRC32 chains (:func:`columns_crc32` contract) run over the landed
        bytes. This is the shuffle map's zero-copy landing — one gather +
        one CRC pass per field per page, nothing else touches the data."""
        gfields = self._gfields
        if crcs is None:
            crcs = [0] * len(gfields)
        i = lo
        while i < hi:
            if self._page is None:
                self._open_page()
            count = self._count
            take = min(self.per_page - count, hi - i)
            new = count + take
            view = self._view
            idx = order[i:i + take]
            fi = 0
            for name, off, w, base, shape in gfields:
                region = view[off + count * w:off + new * w]
                if shape is not None:
                    dst = region.view(base).reshape((take, *shape))
                else:
                    dst = region.view(base)
                # mode="clip" skips numpy's exception-safe temp+copy path
                # for out= (indices come from argsort — never out of range)
                np.take(columns[name], idx, axis=0, out=dst, mode="clip")
                crcs[fi] = zlib.crc32(region.data, crcs[fi])
                fi += 1
            self._count = new
            i += take
            if new == self.per_page:
                self._close_page()
        return crcs

    def append_batch(self, records: np.ndarray) -> None:
        if len(records) == 0:
            return
        self.append_columns(records_to_columns(records), len(records))

    def close(self) -> None:
        self._close_page()
        self.ls.set_operation(CurrentOperation.IDLE, self.pool.clock)


def iter_column_blocks(pool: BufferPool, ls: LocalitySet, dtype: np.dtype
                       ) -> Iterator[Tuple[Dict[str, np.ndarray], int]]:
    """Stream a columnar set's blocks as zero-copy ``(columns, n)`` views —
    valid only until the next iteration (the page is unpinned); copy to
    retain. Pinning each page faults spilled/logged blocks back in."""
    layout = ColumnLayout.for_page(np.dtype(dtype), ls.page_size)
    ls.infer_from_service("sequential-read", pool.clock)
    for pid in sorted(ls.pages):
        page = ls.pages[pid]
        view = pool.pin(page)
        try:
            cols, n = read_block(view, layout)
            if n:
                yield cols, n
        finally:
            pool.unpin(page)


def set_column_crcs(pool: BufferPool, ls: LocalitySet,
                    dtype: np.dtype) -> List[int]:
    """Per-field CRC chains over a columnar set's stored blocks in page
    order — the read-side twin of the map pass's ``partition_crcs`` chain.
    Because the chains are split-invariant, a set rebuilt from raw page
    images (replica copy, shm import across the process data plane) yields
    the writer's exact fingerprint iff every block landed intact and in
    order."""
    dtype = np.dtype(dtype)
    crcs: Optional[List[int]] = None
    for cols, n in iter_column_blocks(pool, ls, dtype):
        crcs = columns_crc32(cols, dtype, 0, n, crcs)
    if crcs is None:
        crcs = [0] * len(_field_layout(dtype))
    return crcs


def read_all_columnar(pool: BufferPool, ls: LocalitySet,
                      dtype: np.dtype) -> np.ndarray:
    """Materialize a columnar set back into a record array (the read-path
    twin of ``services.read_all``; byte-identical logical content)."""
    dtype = np.dtype(dtype)
    chunks = [columns_to_records(cols, dtype, n)
              for cols, n in iter_column_blocks(pool, ls, dtype)]
    if not chunks:
        return np.empty(0, dtype=dtype)
    return np.concatenate(chunks)


# ---------------------------------------------------------------------------
# Checksums — byte-compatible with replication.record_content_checksum
# ---------------------------------------------------------------------------
def columnar_content_checksum(columns: Dict[str, np.ndarray],
                              dtype: np.dtype,
                              n: Optional[int] = None) -> int:
    """``record_content_checksum`` computed straight from column arrays.

    The row function multiplies record byte ``j`` by ``MULT**(j+1)`` and sums
    per record before mixing; addition mod 2**64 commutes, so the per-field
    partial sums (each field using the multiplier slice at its record byte
    offset) reproduce the identical value without materializing rows. This is
    what lets a columnar shard verify against a row-oriented replica of the
    same logical records."""
    dtype = np.dtype(dtype)
    fields = _field_layout(dtype)
    width = dtype.itemsize
    if n is None:
        n = len(columns[fields[0][0]])
    if n == 0:
        return 0
    mults = np.full(width, _CONTENT_MULT, dtype=np.uint64)
    total = 0
    step = max(1, (1 << 20) // width)
    with np.errstate(over="ignore"):
        mults = np.cumprod(mults, dtype=np.uint64)
        for i in range(0, n, step):
            m = min(step, n - i)
            row = np.zeros(m, dtype=np.uint64)
            for name, _, off, w in fields:
                raw = _col_view(columns[name][i:i + m]).reshape(m, w)
                row += (raw.astype(np.uint64)
                        * mults[off:off + w]).sum(axis=1, dtype=np.uint64)
            row = (row ^ (row >> np.uint64(29))) * _CONTENT_MIX
            row ^= row >> np.uint64(32)
            total = (total + int(row.sum(dtype=np.uint64))) % (1 << 64)
    return total


def columns_crc32(columns: Dict[str, np.ndarray], dtype: np.dtype,
                  lo: int = 0, hi: Optional[int] = None,
                  crcs: Optional[List[int]] = None) -> List[int]:
    """Per-field order-exact CRC32 chains over a column slice — the columnar
    shuffle's per-partition output fingerprint. One chain per field (record
    byte order) rather than one interleaved chain, so the fingerprint is
    invariant to how a record sequence is split into slices: writers chain
    per routed slice, readers chain per stored block, and the two streams
    agree as long as record order does. Chain by passing the previous value
    as ``crcs`` (updated in place when provided)."""
    fields = _field_layout(np.dtype(dtype))
    if crcs is None:
        crcs = [0] * len(fields)
    for i, (name, _, _, _) in enumerate(fields):
        col = columns[name]
        sl = col[lo:hi] if hi is not None else col[lo:]
        crcs[i] = zlib.crc32(np.ascontiguousarray(sl).data, crcs[i])
    return crcs


# ---------------------------------------------------------------------------
# Fused hash-partition + incremental-CRC kernel (the shuffle map hot path)
# ---------------------------------------------------------------------------
def route_partition_ids(keys: np.ndarray, num_partitions: int) -> np.ndarray:
    """Reducer id per key — bit-for-bit ``ClusterShuffle.partition_of_keys``,
    computed in-place over one uint64 temp (and with the modulo strength-
    reduced to a mask when the reducer count is a power of two)."""
    h = np.asarray(keys).astype(np.uint64)
    np.multiply(h, _ROUTE_MULT, out=h)
    h ^= h >> np.uint64(29)
    p = np.uint64(num_partitions)
    if num_partitions & (num_partitions - 1) == 0:
        np.bitwise_and(h, p - np.uint64(1), out=h)
    else:
        np.remainder(h, p, out=h)
    return h


def fused_partition_crc(keys: np.ndarray, columns: Dict[str, np.ndarray],
                        dtype: np.dtype, num_partitions: int,
                        crcs: Optional[List[int]] = None):
    """One fused pass over a column block: reducer hash -> dispatch plan
    (stable argsort over narrow partition ids + bincount, the
    ``host_dispatch_plan`` contract) -> per-column contiguous gather ->
    per-partition CRC32 chained into ``crcs``.

    Returns ``(routed, counts, offsets, crcs)`` where ``routed`` holds each
    column re-ordered so partition ``r`` occupies rows
    ``offsets[r]:offsets[r+1]`` — ready to memcpy into per-reducer pages with
    no per-record work. ``crcs[r]`` is partition ``r``'s per-field CRC chain
    (see :func:`columns_crc32`), updated incrementally so shuffle output is
    CRC-verified without a second pass."""
    h = route_partition_ids(keys, num_partitions)
    # narrow ids radix-sort ~5x faster than int64 comparison sort
    if num_partitions <= 256:
        parts = h.astype(np.uint8)
    elif num_partitions <= 65536:
        parts = h.astype(np.uint16)
    else:
        parts = h.astype(np.int64)
    order = np.argsort(parts, kind="stable")
    counts = np.bincount(parts, minlength=num_partitions)
    offsets = np.empty(num_partitions + 1, np.int64)
    offsets[0] = 0
    np.cumsum(counts, out=offsets[1:])
    fields = _field_layout(np.dtype(dtype))
    routed = {name: np.take(columns[name], order, axis=0)
              for name, _, _, _ in fields}
    if crcs is None:
        crcs = [[0] * len(fields) for _ in range(num_partitions)]
    # CRC straight off each routed column's flat byte view: the routed
    # arrays are C-contiguous, so every partition slice is one buffer
    bounds = offsets.tolist()
    for fi, (name, _, _, w) in enumerate(fields):
        flat = _col_view(routed[name])
        for r in range(num_partitions):
            lo, hi = bounds[r], bounds[r + 1]
            if hi > lo:
                crcs[r][fi] = zlib.crc32(flat[lo * w:hi * w].data,
                                         crcs[r][fi])
    return routed, counts, offsets, crcs


def segment_sum(keys: np.ndarray, vals: np.ndarray
                ) -> Tuple[np.ndarray, np.ndarray]:
    """Vectorized group-by-sum over one column pair: sort-free ``np.add.at``
    segment reduce keyed by ``np.unique`` — the columnar aggregation path
    (replaces per-record open-addressing inserts on co-partitioned shards)."""
    keys = np.asarray(keys, np.int64)
    vals = np.asarray(vals, np.float64)
    if len(keys) == 0:
        return keys, vals
    uk, inv = np.unique(keys, return_inverse=True)
    out = np.zeros(len(uk), dtype=np.float64)
    np.add.at(out, inv, vals)
    return uk, out
