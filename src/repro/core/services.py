"""Pushed-down computational services — paper §8.

Services are how applications touch locality sets; each service exhibits a
specific access pattern, which is how attributes get inferred automatically
(paper §3.2). Implemented here over numpy record views into buffer-pool pages:

* Sequential read/write service — multi-worker page writers + concurrent page
  iterators (the data-pipeline substrate).
* Shuffle service — virtual shuffle buffers: many writers append records for
  the same partition into small pages split from one large page
  (concurrent-write pattern). The device-side half of shuffle for MoE dispatch
  lives in ``kernels/shuffle_dispatch``.
* Hash service — virtual hash buffer: each page is an independent open-
  addressing hash partition (extendible hashing); full pages split; when the
  pool is exhausted pages spill as partial aggregates and are re-aggregated.
* Join service — build partitioned hash maps from one set, probe with another.

Page layout for record pages: ``[count:int64][record bytes...]``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from .attributes import (AttributeSet, CurrentOperation, DurabilityType,
                         StorageScheme, WritingPattern)
from .buffer_pool import BufferPool, PoolExhaustedError
from .columnar import (ColumnarWriter, _col_view, _field_layout,
                       columns_to_records, iter_column_blocks,
                       records_to_columns)
from .locality_set import LocalitySet, Page
from .sanitizer import tracked_lock

_HEADER = 8  # int64 record count at page start


def job_data_attrs() -> AttributeSet:
    """Attribute preset for shuffle/execution job data (paper §3.1): write-back
    (spill only under pressure), concurrent-write pattern; lifetime is ended
    explicitly once the consuming stage has pulled the data."""
    return AttributeSet(durability=DurabilityType.WRITE_BACK,
                        writing=WritingPattern.CONCURRENT_WRITE)


def user_data_attrs() -> AttributeSet:
    """Attribute preset for long-lived user data (paper §3.1/§4):
    write-through durability — every written page is persisted at unpin, and
    on a node with a durable page log the images land there, so the set
    pages against disk as its working set exceeds the pool and survives a
    node restart (warm recovery)."""
    return AttributeSet(durability=DurabilityType.WRITE_THROUGH,
                        writing=WritingPattern.SEQUENTIAL_WRITE)


def columnar_job_data_attrs() -> AttributeSet:
    """Job-data preset with the columnar storage scheme: the set's pages hold
    column blocks, so the vectorized shuffle/aggregate paths stream whole
    columns (``core/columnar.py``)."""
    attrs = job_data_attrs()
    attrs.storage = StorageScheme.COLUMNAR
    return attrs


def columnar_user_data_attrs() -> AttributeSet:
    """Long-lived user data stored columnar (write-through durability rides
    the same page-image log path — blocks are opaque payloads to it)."""
    attrs = user_data_attrs()
    attrs.storage = StorageScheme.COLUMNAR
    return attrs


def is_columnar(ls: LocalitySet) -> bool:
    """Whether a locality set's pages hold column blocks (the per-set
    ``AttributeSet.storage`` dimension selects the scheme)."""
    return ls.attrs.storage is StorageScheme.COLUMNAR


def as_record_bytes(records: np.ndarray, dtype: np.dtype) -> np.ndarray:
    """[N, ...] records -> [N, itemsize] uint8 rows (handles structured AND
    subarray dtypes, e.g. one token sequence per record)."""
    records = np.ascontiguousarray(records)
    n = len(records)
    raw = records.view(np.uint8).reshape(n, -1)
    if raw.shape[1] != dtype.itemsize:
        raise ValueError(f"record bytes {raw.shape[1]} != dtype itemsize "
                         f"{dtype.itemsize}")
    return raw


def from_record_bytes(buf: np.ndarray, dtype: np.dtype, n: int) -> np.ndarray:
    """Inverse of as_record_bytes: uint8 buffer -> n records of ``dtype``."""
    raw = buf[:n * dtype.itemsize]
    if dtype.subdtype is not None:
        base, shape = dtype.subdtype
        return raw.view(base).reshape((n, *shape))
    return raw.view(dtype)


# ---------------------------------------------------------------------------
# Sequential read/write service
# ---------------------------------------------------------------------------
class SequentialWriter:
    """Append fixed-dtype records to a locality set, page by page."""

    def __init__(self, pool: BufferPool, ls: LocalitySet, dtype: np.dtype):
        self.pool = pool
        self.ls = ls
        self.dtype = np.dtype(dtype)
        self.per_page = (ls.page_size - _HEADER) // self.dtype.itemsize
        if self.per_page < 1:
            raise ValueError("page too small for one record")
        self._page: Optional[Page] = None
        self._count = 0
        ls.infer_from_service("sequential-write", pool.clock)

    def _open_page(self) -> None:
        self._page = self.pool.new_page(self.ls)
        self._count = 0

    def _close_page(self) -> None:
        if self._page is None:
            return
        view = self.pool.view(self._page)
        view[:_HEADER].view(np.int64)[0] = self._count
        self.pool.unpin(self._page, dirty=True)
        self._page = None

    def append_batch(self, records: np.ndarray) -> None:
        raw = as_record_bytes(records, self.dtype)
        i = 0
        while i < len(raw):
            if self._page is None:
                self._open_page()
            room = self.per_page - self._count
            take = min(room, len(raw) - i)
            view = self.pool.view(self._page)
            start = _HEADER + self._count * self.dtype.itemsize
            stop = start + take * self.dtype.itemsize
            view[start:stop] = raw[i:i + take].reshape(-1)
            self._count += take
            i += take
            if self._count == self.per_page:
                self._close_page()

    def append(self, record) -> None:
        self.append_batch(np.array([record], dtype=self.dtype))

    def close(self) -> None:
        self._close_page()
        self.ls.set_operation(CurrentOperation.IDLE, self.pool.clock)


class PageIterator:
    """Concurrent page iterator over a subset of a locality set's pages."""

    def __init__(self, pool: BufferPool, ls: LocalitySet, dtype: np.dtype,
                 page_ids: Sequence[int]):
        self.pool = pool
        self.ls = ls
        self.dtype = np.dtype(dtype)
        self.page_ids = list(page_ids)

    def __iter__(self) -> Iterator[np.ndarray]:
        for pid in self.page_ids:
            page = self.ls.pages[pid]
            view = self.pool.pin(page)
            try:
                n = int(view[:_HEADER].view(np.int64)[0])
                yield from_record_bytes(view[_HEADER:], self.dtype, n)
            finally:
                self.pool.unpin(page)


def get_page_iterators(pool: BufferPool, ls: LocalitySet, dtype: np.dtype,
                       num_workers: int) -> List[PageIterator]:
    """Split the set's pages round-robin across ``num_workers`` iterators
    (paper §8 sequential read service)."""
    ls.infer_from_service("sequential-read", pool.clock)
    pids = sorted(ls.pages)
    return [PageIterator(pool, ls, dtype, pids[w::num_workers])
            for w in range(num_workers)]


def read_all(pool: BufferPool, ls: LocalitySet, dtype: np.dtype) -> np.ndarray:
    its = get_page_iterators(pool, ls, dtype, 1)
    chunks = [recs.copy() for recs in its[0]]
    if not chunks:
        return np.empty(0, dtype=dtype)
    return np.concatenate(chunks)


# ---------------------------------------------------------------------------
# Shuffle service — virtual shuffle buffers (paper §8)
# ---------------------------------------------------------------------------
SMALL_PAGE = 1 << 16  # 64 KiB small pages split from each large page


class _SmallPageAllocator:
    """Secondary allocator that pins one large page in a partition's locality
    set and splits it into small pages handed to concurrent writers.

    Thread-safe (PR 5): concurrent writers share one allocator per partition,
    so ``alloc_small`` hands out disjoint small pages under a lock, and every
    small page carries an *extra pin* on behalf of its writer (released via
    ``release_small``) — otherwise a rotation triggered by one writer would
    unpin the large page a peer is still filling, and an eviction under
    pressure would pull the arena out from under its view."""

    def __init__(self, pool: BufferPool, ls: LocalitySet, small_page: int = SMALL_PAGE):
        self.pool = pool
        self.ls = ls
        self.small_page = min(small_page, ls.page_size)
        self._page: Optional[Page] = None
        self._next_off = 0
        self._outstanding = 0
        self._lock = tracked_lock("services.smallpage")

    def alloc_small(self) -> Tuple[Page, int]:
        """Returns ``(large_page, offset)`` with the large page pinned once
        for the caller; pair with ``release_small`` when the small page is
        full or the writer closes."""
        with self._lock:
            if self._page is None or self._next_off + self.small_page > self._page.size:
                self._rotate()
            off = self._next_off
            self._next_off += self.small_page
            self._outstanding += 1
            self.pool.pin(self._page)
            return self._page, off

    def release_small(self, page: Page) -> None:
        """Drop a writer's pin on its small page's large page."""
        self.pool.unpin(page, dirty=True)

    def _rotate(self) -> None:
        if self._page is not None:
            self.pool.unpin(self._page, dirty=True)
        self._page = self.pool.new_page(self.ls)
        self._next_off = 0
        # zero every small-page count header (arena memory may be recycled)
        view = self.pool.view(self._page)
        for base in range(0, self._page.size - self.small_page + 1, self.small_page):
            view[base:base + _HEADER].view(np.int64)[0] = 0

    def close(self) -> None:
        with self._lock:
            if self._page is not None:
                self.pool.unpin(self._page, dirty=True)
                self._page = None


class VirtualShuffleBuffer:
    """Per-(worker, partition) append handle writing into small pages
    (paper §3.2 code example + §8). Each open small page keeps its large
    page pinned (via ``alloc_small``), so concurrent writers on the same
    partition can't have their pages evicted mid-fill; ``close`` releases
    the pin on a partially filled page."""

    def __init__(self, allocator: _SmallPageAllocator, dtype: np.dtype,
                 on_write: Optional[Callable[[int, int], None]] = None):
        self.allocator = allocator
        self.on_write = on_write  # (num_records, num_bytes) per add_batch
        self.dtype = np.dtype(dtype)
        self._page: Optional[Page] = None
        self._base = 0
        self._count = 0
        self._cap = (allocator.small_page - _HEADER) // self.dtype.itemsize

    def _open(self) -> None:
        self._page, self._base = self.allocator.alloc_small()
        self._count = 0
        view = self.allocator.pool.view(self._page)
        view[self._base:self._base + _HEADER].view(np.int64)[0] = 0

    def _close_small(self) -> None:
        if self._page is not None:
            self.allocator.release_small(self._page)
            self._page = None

    def add_batch(self, records: np.ndarray) -> None:
        raw = as_record_bytes(records, self.dtype)
        if self.on_write is not None and len(raw):
            self.on_write(len(raw), len(raw) * self.dtype.itemsize)
        i = 0
        pool = self.allocator.pool
        while i < len(raw):
            if self._page is None:
                self._open()
            take = min(self._cap - self._count, len(raw) - i)
            view = pool.view(self._page)
            start = self._base + _HEADER + self._count * self.dtype.itemsize
            stop = start + take * self.dtype.itemsize
            view[start:stop] = raw[i:i + take].reshape(-1)
            self._count += take
            view[self._base:self._base + _HEADER].view(np.int64)[0] = self._count
            i += take
            if self._count == self._cap:
                self._close_small()  # small page full; next add opens another

    def close(self) -> None:
        """Release the pin on a partially filled small page (the records
        stay; only the writer's hold on the arena is dropped)."""
        self._close_small()

    def add(self, record) -> None:
        self.add_batch(np.array([record], dtype=self.dtype))


def iter_small_page_records(pool: BufferPool, ls: LocalitySet,
                            dtype: np.dtype,
                            small_page: int = SMALL_PAGE) -> Iterator[np.ndarray]:
    """Stream the records of a set whose pages are small-page shuffle output
    (each ``small_page`` window self-describes with an int64 count header).
    This is the decode side of a raw page-image move: a map partition
    exported as whole page images — same host or across the process data
    plane — reads back here without the producing service.  Yielded arrays
    are views valid only until the next iteration."""
    dtype = np.dtype(dtype)
    small = min(small_page, ls.page_size)
    for pid in sorted(ls.pages):
        page = ls.pages[pid]
        view = pool.pin(page)
        try:
            for base in range(0, page.size - small + 1, small):
                n = int(view[base:base + _HEADER].view(np.int64)[0])
                if n == 0:
                    continue
                yield from_record_bytes(view[base + _HEADER:], dtype, n)
        finally:
            pool.unpin(page)


class ShuffleService:
    """One locality set per partition; concurrent writers share large pages
    through small-page sub-allocation. Readers use the sequential service."""

    def __init__(self, pool: BufferPool, name: str, num_partitions: int,
                 dtype: np.dtype, page_size: int = 1 << 20,
                 attrs_factory: Optional[Callable[[], AttributeSet]] = None):
        self.pool = pool
        self.dtype = np.dtype(dtype)
        self.num_partitions = num_partitions
        self.partition_sets: List[LocalitySet] = []
        self._allocators: List[_SmallPageAllocator] = []
        for p in range(num_partitions):
            attrs = attrs_factory() if attrs_factory else AttributeSet()
            ls = pool.create_set(f"{name}/part{p}", page_size, attrs)
            ls.infer_from_service("shuffle", pool.clock)
            self.partition_sets.append(ls)
            self._allocators.append(_SmallPageAllocator(pool, ls))
        self._buffers: Dict[Tuple[int, int], VirtualShuffleBuffer] = {}
        self._lock = tracked_lock("services.shuffle")  # buffer map + write counters
        # per-partition write accounting: what the locality-aware scheduler
        # reads to place reducers where their input already lives
        self.partition_records: List[int] = [0] * num_partitions
        self.partition_bytes: List[int] = [0] * num_partitions

    def _count_write(self, partition_id: int, nrec: int, nbytes: int) -> None:
        with self._lock:
            self.partition_records[partition_id] += nrec
            self.partition_bytes[partition_id] += nbytes

    def get_buffer(self, worker_id, partition_id: int) -> VirtualShuffleBuffer:
        """Append handle for one (worker, partition). ``worker_id`` is any
        hashable writer identity — concurrent writer threads must use
        distinct ids so each gets its own buffer (the partition's allocator
        hands their small pages out disjointly)."""
        key = (worker_id, partition_id)
        with self._lock:
            if key not in self._buffers:
                self._buffers[key] = VirtualShuffleBuffer(
                    self._allocators[partition_id], self.dtype,
                    on_write=lambda nr, nb, p=partition_id: self._count_write(p, nr, nb))
            return self._buffers[key]

    def shuffle_batch(self, worker_id: int, records: np.ndarray,
                      key_fn: Callable[[np.ndarray], np.ndarray]) -> None:
        """Vectorized shuffle: route ``records`` to partitions by key hash."""
        keys = key_fn(records)
        parts = keys % self.num_partitions
        for p in np.unique(parts):
            self.get_buffer(worker_id, int(p)).add_batch(records[parts == p])

    def finish_writes(self) -> None:
        for buf in self._buffers.values():
            buf.close()  # drop writer pins on partially filled small pages
        for alloc in self._allocators:
            alloc.close()
        for ls in self.partition_sets:
            ls.set_operation(CurrentOperation.IDLE, self.pool.clock)

    def iter_partition(self, partition_id: int) -> Iterator[np.ndarray]:
        """Stream one partition's records small-page by small-page — the
        pressure-safe read path: a consumer (e.g. a reducer pull) stages
        O(small page), never the whole partition. Pinning each large page in
        turn faults any spilled map output back through the pool. Yielded
        arrays are views valid only until the next iteration; copy to
        retain."""
        ls = self.partition_sets[partition_id]
        ls.infer_from_service("sequential-read", self.pool.clock)
        yield from iter_small_page_records(
            self.pool, ls, self.dtype, self.small_page_of(partition_id))

    def small_page_of(self, partition_id: int) -> int:
        """The small-page stride of one partition's pages — what a raw
        page-image consumer needs to decode them (``iter_small_page_records``
        on the far side of an export)."""
        return self._allocators[partition_id].small_page

    def read_partition(self, partition_id: int) -> np.ndarray:
        """Read back one whole partition (gathers ``iter_partition``)."""
        out = [chunk.copy() for chunk in self.iter_partition(partition_id)]
        if not out:
            return np.empty(0, dtype=self.dtype)
        return np.concatenate(out)

    def release_partition(self, partition_id: int) -> None:
        """Consumer is done with this partition: end the lifetime of its
        job-data pages (making them the cheapest eviction victims, paper §6)
        and drop the set, returning arena space to the pool."""
        ls = self.partition_sets[partition_id]
        ls.end_lifetime(self.pool.clock)
        self.pool.drop_set(ls)


class ColumnarShuffleService:
    """Columnar twin of ``ShuffleService``: one columnar locality set per
    partition, written block-at-a-time by per-(worker, partition)
    ``ColumnarWriter`` handles. The fused map pass hands each writer an
    already-routed *column slice* — ``add_columns`` memcpys it straight into
    the partition's column block, no per-record work and no row
    materialization anywhere on the map side. ``iter_partition`` streams the
    blocks back out as zero-copy ``(columns, n)`` views (the reducer pull /
    join probe feed). The same accounting surface as the row service
    (``partition_records`` / ``partition_bytes``) keeps the locality-aware
    scheduler working unchanged."""

    def __init__(self, pool: BufferPool, name: str, num_partitions: int,
                 dtype: np.dtype, page_size: int = 1 << 20,
                 attrs_factory: Optional[Callable[[], AttributeSet]] = None):
        self.pool = pool
        self.dtype = np.dtype(dtype)
        self.num_partitions = num_partitions
        self.partition_sets: List[LocalitySet] = []
        # one shared landing writer per partition, provisioned up front with
        # its first page (the paper's pre-provisioned per-partition shuffle
        # buffers) — map passes memcpy routed slices without any cold-start
        # page allocation in the landing loop. Appends serialize under
        # ``_lock``, consistent with the per-node CRC-chain contract.
        self._writers: List[ColumnarWriter] = []
        self._lock = tracked_lock("services.columnar")
        for p in range(num_partitions):
            attrs = attrs_factory() if attrs_factory else columnar_job_data_attrs()
            ls = pool.create_set(f"{name}/part{p}", page_size, attrs)
            ls.infer_from_service("shuffle", pool.clock)
            self.partition_sets.append(ls)
            w = ColumnarWriter(pool, ls, self.dtype)
            w._open_page()
            self._writers.append(w)
        self.partition_records: List[int] = [0] * num_partitions
        self.partition_bytes: List[int] = [0] * num_partitions
        # per-partition, per-field incremental CRC32 of the routed column
        # bytes, chained slice by slice in append order by the fused map pass.
        # One chain per field keeps the fingerprint invariant to block
        # boundaries, so consumers re-verify it block by block after the pull.
        nfields = len(_field_layout(self.dtype))
        self.partition_crcs: List[List[int]] = [
            [0] * nfields for _ in range(num_partitions)]
        self._released: set = set()

    def get_writer(self, worker_id, partition_id: int) -> ColumnarWriter:
        """The pre-provisioned landing writer for one partition (``worker_id``
        is accepted for call-site compatibility; writers are shared, so
        callers must serialize appends — ``add_columns``/``add_routed`` do)."""
        return self._writers[partition_id]

    def add_columns(self, worker_id, partition_id: int,
                    columns: Dict[str, np.ndarray], n: int,
                    start: int = 0) -> None:
        """Append ``columns[start:start+n]`` to one partition (the routed
        slice a fused dispatch pass produced)."""
        if n == 0:
            return
        with self._lock:
            self._writers[partition_id].append_columns(columns, n, start=start)
            self.partition_records[partition_id] += n
            self.partition_bytes[partition_id] += n * self.dtype.itemsize

    def add_routed(self, worker_id, columns: Dict[str, np.ndarray],
                   offsets: np.ndarray) -> None:
        """Bulk landing: append every partition's routed slice in one call.
        ``columns`` is partition-major (the fused dispatch output) and
        ``offsets`` the ``num_partitions + 1`` slice boundaries. One lock
        round-trip and one set of flat column views for the whole page,
        instead of one of each per partition."""
        itemsize = self.dtype.itemsize
        flats = {name: _col_view(columns[name])
                 for name, _, _, _ in _field_layout(self.dtype)}
        bounds = offsets.tolist() if hasattr(offsets, "tolist") else offsets
        with self._lock:
            for p in range(self.num_partitions):
                lo = bounds[p]
                n = bounds[p + 1] - lo
                if n == 0:
                    continue
                self._writers[p].append_flat(flats, n, start=lo)
                self.partition_records[p] += n
                self.partition_bytes[p] += n * itemsize

    def add_gathered(self, worker_id, columns: Dict[str, np.ndarray],
                     order: np.ndarray, offsets: np.ndarray) -> None:
        """Fused landing (the map hot path): gather each partition's rows
        from the source block straight into its pre-provisioned pages —
        ``np.take(..., out=page_region)``, no routed intermediate — while
        chaining the per-field partition CRCs over the landed bytes.
        ``order``/``offsets`` are a ``host_dispatch_plan`` result over this
        block's reducer ids."""
        itemsize = self.dtype.itemsize
        bounds = (offsets.tolist() if hasattr(offsets, "tolist")
                  else list(offsets))
        with self._lock:
            for p in range(self.num_partitions):
                lo, hi = bounds[p], bounds[p + 1]
                if hi == lo:
                    continue
                self._writers[p].gather_append(columns, order, lo, hi,
                                               self.partition_crcs[p])
                self.partition_records[p] += hi - lo
                self.partition_bytes[p] += (hi - lo) * itemsize

    def finish_writes(self) -> None:
        # each writer's close already marks its (1:1) partition set IDLE
        for w in self._writers:
            w.close()

    def iter_partition(self, partition_id: int
                       ) -> Iterator[Tuple[Dict[str, np.ndarray], int]]:
        """Stream one partition's column blocks — zero-copy views valid only
        until the next iteration; pinning each page faults spilled blocks
        back through the pool (same pressure-safe contract as the row
        service's small-page iterator)."""
        yield from iter_column_blocks(
            self.pool, self.partition_sets[partition_id], self.dtype)

    def read_partition(self, partition_id: int) -> np.ndarray:
        out = [columns_to_records(cols, self.dtype, n)
               for cols, n in self.iter_partition(partition_id)]
        if not out:
            return np.empty(0, dtype=self.dtype)
        return np.concatenate(out)

    def release_partition(self, partition_id: int) -> None:
        """End one partition's lifetime and drop its pages. Idempotent:
        deferred-release pulls (``ClusterShuffle.pull_columns``) and failure
        cleanup (``discard_map_output``) may both reach the same partition."""
        with self._lock:
            if partition_id in self._released:
                return
            self._released.add(partition_id)
        ls = self.partition_sets[partition_id]
        ls.end_lifetime(self.pool.clock)
        self.pool.drop_set(ls)


# ---------------------------------------------------------------------------
# Hash service — virtual hash buffer (paper §8)
# ---------------------------------------------------------------------------
def _hash_slot(keys: np.ndarray, cap: int) -> np.ndarray:
    """Fibonacci hash → initial probe slot."""
    h = (keys.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15))
    return (h % np.uint64(cap)).astype(np.int64)


class _HashPage:
    """Open-addressing (linear probing) int64->float64 aggregate table living
    inside one buffer-pool page. Layout: [count:int64][used:u1 xC][pad]
    [keys:int64 xC][vals:float64 xC]."""

    def __init__(self, pool: BufferPool, ls: LocalitySet, page: Page):
        self.pool = pool
        self.ls = ls
        self.page = page
        cap = (page.size - _HEADER - 7) // (1 + 8 + 8)
        cap -= cap % 8 or 0
        self.cap = max(8, cap - 8)
        self._layout()

    def _layout(self) -> None:
        view = self.pool.view(self.page)
        off = _HEADER
        self.used = view[off:off + self.cap].view(np.uint8)
        off += self.cap
        off += (-off) % 8
        self.keys = view[off:off + 8 * self.cap].view(np.int64)
        off += 8 * self.cap
        self.vals = view[off:off + 8 * self.cap].view(np.float64)

    @property
    def count(self) -> int:
        return int(self.pool.view(self.page)[:_HEADER].view(np.int64)[0])

    def _set_count(self, n: int) -> None:
        self.pool.view(self.page)[:_HEADER].view(np.int64)[0] = n

    def insert_add(self, keys: np.ndarray,
                   vals: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Vectorized aggregate-insert. Returns (rem_keys, rem_vals): pairs
        not inserted because the table hit its load limit — the caller seals
        this page and retries on a fresh one. Rejections are safe even when a
        rejected key exists deeper in the probe chain, because ``finalize()``
        re-aggregates partials across the whole partition chain."""
        if len(keys) == 0:
            return keys, vals
        # pre-aggregate duplicate keys within the batch
        ukeys, inv = np.unique(keys, return_inverse=True)
        uvals = np.zeros(len(ukeys), dtype=np.float64)
        np.add.at(uvals, inv, vals)
        keys, vals = ukeys, uvals

        limit = int(self.cap * 0.7)
        n = self.count
        base = _hash_slot(keys, self.cap)
        pending = np.arange(len(keys))
        for probe in range(self.cap):
            if len(pending) == 0:
                break
            s = (base[pending] + probe) % self.cap
            occupied = self.used[s].astype(bool)
            match = occupied & (self.keys[s] == keys[pending])
            if match.any():
                self.vals[s[match]] += vals[pending[match]]  # unique keys → unique slots
            empty = ~occupied
            survivors = pending[occupied & ~match]  # collided; probe further
            if empty.any():
                cand = pending[empty]
                cslot = s[empty]
                order = np.argsort(cslot, kind="stable")
                cand, cslot = cand[order], cslot[order]
                first = np.ones(len(cslot), dtype=bool)
                first[1:] = cslot[1:] != cslot[:-1]
                winners, wslots = cand[first], cslot[first]
                losers = cand[~first]
                room = max(0, limit - n)
                if room < len(winners):
                    rejected = winners[room:]
                    winners, wslots = winners[:room], wslots[:room]
                    if len(winners):
                        self.used[wslots] = 1
                        self.keys[wslots] = keys[winners]
                        self.vals[wslots] = vals[winners]
                        n += len(winners)
                    self._set_count(n)
                    rem = np.concatenate([rejected, losers, survivors])
                    return keys[rem], vals[rem]
                self.used[wslots] = 1
                self.keys[wslots] = keys[winners]
                self.vals[wslots] = vals[winners]
                n += len(winners)
                survivors = np.concatenate([survivors, losers])
            pending = survivors
        self._set_count(n)
        return keys[pending], vals[pending]

    def items(self) -> Tuple[np.ndarray, np.ndarray]:
        mask = self.used.astype(bool)
        return self.keys[mask].copy(), self.vals[mask].copy()


class HashService:
    """Hash aggregation over buffer-pool pages (paper §8).

    K root partitions, each a *chain* of hash pages. The chain head is pinned
    and receives inserts; when it fills, it is sealed (unpinned → becomes an
    evictable/spillable partial-aggregate page, exactly the paper's "select a
    page, unpin it, and spill it to disk as partial-aggregation results") and
    a fresh head is allocated. ``finalize()`` re-aggregates each partition's
    chain — pinning sealed pages pulls any spilled partials back through the
    buffer pool transparently (the monolithic-design payoff: no separate
    spill-file machinery).
    """

    PAIR_DTYPE = np.dtype([("key", np.int64), ("val", np.float64)])

    def __init__(self, pool: BufferPool, name: str, num_root_partitions: int = 8,
                 page_size: int = 1 << 20):
        self.pool = pool
        self.name = name
        self.ls = pool.create_set(name, page_size)
        self.ls.infer_from_service("hash", pool.clock)
        self.depth = max(1, int(np.ceil(np.log2(max(2, num_root_partitions)))))
        self._heads: Dict[int, _HashPage] = {}
        self._sealed: Dict[int, List[int]] = {p: [] for p in range(1 << self.depth)}
        for p in range(1 << self.depth):
            self._heads[p] = self._new_hash_page()

    def _new_hash_page(self) -> _HashPage:
        page = self.pool.new_page(self.ls)  # returned pinned
        view = self.pool.view(page)
        view[:] = 0
        return _HashPage(self.pool, self.ls, page)

    def _partition_of(self, keys: np.ndarray) -> np.ndarray:
        h = keys.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15)
        return (h >> np.uint64(64 - self.depth)).astype(np.int64)

    def _seal_and_replace(self, part: int) -> None:
        hp = self._heads[part]
        self._sealed[part].append(hp.page.page_id)
        self.pool.unpin(hp.page, dirty=True)  # now evictable (paper §8)
        self._heads[part] = self._new_hash_page()

    def insert(self, keys: np.ndarray, vals: np.ndarray) -> None:
        keys = np.asarray(keys, dtype=np.int64)
        vals = np.asarray(vals, dtype=np.float64)
        parts = self._partition_of(keys)
        for p in np.unique(parts):
            m = parts == p
            k, v = keys[m], vals[m]
            while len(k):
                k, v = self._heads[int(p)].insert_add(k, v)
                if len(k):
                    self._seal_and_replace(int(p))

    def finalize(self) -> Tuple[np.ndarray, np.ndarray]:
        """Re-aggregate each partition chain (head + sealed partials)."""
        all_keys: List[np.ndarray] = []
        all_vals: List[np.ndarray] = []
        for p, hp in self._heads.items():
            k, v = hp.items()
            all_keys.append(k)
            all_vals.append(v)
            for pid in self._sealed[p]:
                page = self.ls.pages[pid]
                self.pool.pin(page)  # transparently restores spilled partials
                try:
                    sk, sv = _HashPage(self.pool, self.ls, page).items()
                    all_keys.append(sk)
                    all_vals.append(sv)
                finally:
                    self.pool.unpin(page)
        keys = np.concatenate(all_keys) if all_keys else np.empty(0, np.int64)
        vals = np.concatenate(all_vals) if all_vals else np.empty(0, np.float64)
        if len(keys) == 0:
            return keys, vals
        uk, inv = np.unique(keys, return_inverse=True)
        out = np.zeros(len(uk), dtype=np.float64)
        np.add.at(out, inv, vals)
        return uk, out

    def close(self) -> None:
        for hp in self._heads.values():
            self.pool.unpin(hp.page, dirty=True)
        self.ls.set_operation(CurrentOperation.IDLE, self.pool.clock)


# ---------------------------------------------------------------------------
# Join service (paper §8): partitioned hash join through the buffer pool
# ---------------------------------------------------------------------------
def join_output_dtype(build_dtype: np.dtype, probe_dtype: np.dtype,
                      build_key: str, probe_key: str) -> np.dtype:
    """Output record layout for a materialized equi-join: the join key first,
    then the build side's non-key fields (prefixed ``b_``), then the probe
    side's (prefixed ``p_``). Scalar fields only — the canonical output order
    is a lexicographic sort over every field."""
    build_dtype = np.dtype(build_dtype)
    probe_dtype = np.dtype(probe_dtype)
    fields = [("key", build_dtype.fields[build_key][0])]
    fields += [(f"b_{n}", build_dtype.fields[n][0])
               for n in build_dtype.names if n != build_key]
    fields += [(f"p_{n}", probe_dtype.fields[n][0])
               for n in probe_dtype.names if n != probe_key]
    return np.dtype(fields)


def canonical_join_sort(out: np.ndarray) -> np.ndarray:
    """Sort joined records into their canonical total order (every field,
    first field most significant). A hash join emits matches in probe order,
    which differs between a single-pool run and a distributed one — after this
    sort the two are byte-identical, which is how equivalence is asserted."""
    if len(out) <= 1:
        return out
    order = np.lexsort(tuple(out[f] for f in reversed(out.dtype.names)))
    return out[order]


class JoinService:
    """Partitioned hash join over buffer-pool pages (paper §8).

    Build-side records are appended page by page into a locality set, so an
    over-capacity build *spills through the pool's eviction policy* instead of
    growing an unbounded heap table; only the join keys stay resident, as a
    sorted row index. Probing is vectorized (binary search over the sorted
    keys) and matched build rows are fetched back one page at a time —
    faulting any spilled build pages in transparently, the same
    monolithic-pool story as the hash service's partial-aggregate pages.
    """

    def __init__(self, pool: BufferPool, name: str,
                 build_dtype: np.dtype, probe_dtype: np.dtype,
                 build_key: str, probe_key: str,
                 page_size: int = 1 << 16,
                 attrs_factory: Optional[Callable[[], AttributeSet]] = job_data_attrs):
        self.pool = pool
        self.build_dtype = np.dtype(build_dtype)
        self.probe_dtype = np.dtype(probe_dtype)
        self.build_key = build_key
        self.probe_key = probe_key
        self.out_dtype = join_output_dtype(self.build_dtype, self.probe_dtype,
                                           build_key, probe_key)
        attrs = attrs_factory() if attrs_factory else None
        self.ls = pool.create_set(name, page_size, attrs)
        self._writer = SequentialWriter(pool, self.ls, self.build_dtype)
        self.per_page = self._writer.per_page
        self._key_chunks: List[np.ndarray] = []
        self.build_rows = 0
        self._skeys: Optional[np.ndarray] = None   # build keys, sorted
        self._srows: Optional[np.ndarray] = None   # row id of each sorted key
        self._pids: List[int] = []

    # -- build side ------------------------------------------------------------
    def build_batch(self, records: np.ndarray) -> None:
        if len(records) == 0:
            return
        self._key_chunks.append(
            np.asarray(records[self.build_key], np.int64).copy())
        self._writer.append_batch(records)
        self.build_rows += len(records)

    def finish_build(self) -> None:
        """Seal the build side: close the writer (its pages become evictable)
        and sort the resident key index for binary-search probing."""
        self._writer.close()
        self._pids = sorted(self.ls.pages)
        keys = (np.concatenate(self._key_chunks) if self._key_chunks
                else np.empty(0, np.int64))
        self._key_chunks = []
        order = np.argsort(keys, kind="stable")
        self._skeys = keys[order]
        self._srows = order

    def _fetch_build_rows(self, row_ids: np.ndarray) -> np.ndarray:
        """Gather build records by row id, pinning each touched page once
        (row ids are page-grouped first, so a spilled page faults in at most
        once per probe batch)."""
        out = np.empty(len(row_ids), self.build_dtype)
        if len(row_ids) == 0:
            return out
        order = np.argsort(row_ids, kind="stable")
        rs = row_ids[order]
        pg = rs // self.per_page
        bounds = np.flatnonzero(np.diff(pg)) + 1
        for a, b in zip(np.concatenate([[0], bounds]),
                        np.concatenate([bounds, [len(rs)]])):
            page = self.ls.pages[self._pids[int(pg[a])]]
            view = self.pool.pin(page)
            try:
                n = int(view[:_HEADER].view(np.int64)[0])
                recs = from_record_bytes(view[_HEADER:], self.build_dtype, n)
                out[order[a:b]] = recs[rs[a:b] % self.per_page]
            finally:
                self.pool.unpin(page)
        return out

    # -- probe side ------------------------------------------------------------
    def _match_positions(self, probe_keys: np.ndarray):
        """(probe_row_idx, build_row_id) for every match of a probe batch."""
        pk = np.asarray(probe_keys, np.int64)
        left = np.searchsorted(self._skeys, pk, "left")
        counts = np.searchsorted(self._skeys, pk, "right") - left
        m = counts > 0
        cm, lm = counts[m], left[m]
        offs = np.concatenate([[0], np.cumsum(cm)])
        total = int(offs[-1])
        pos = np.repeat(lm, cm) + (np.arange(total) - np.repeat(offs[:-1], cm))
        return np.repeat(np.flatnonzero(m), cm), self._srows[pos]

    def probe_count(self, records: np.ndarray) -> int:
        """Match count for a probe batch without materializing the output."""
        if len(records) == 0 or self.build_rows == 0:
            return 0
        pk = np.asarray(records[self.probe_key], np.int64)
        return int((np.searchsorted(self._skeys, pk, "right")
                    - np.searchsorted(self._skeys, pk, "left")).sum())

    def probe_batch(self, records: np.ndarray) -> np.ndarray:
        """Probe the build table with one batch; returns the matched joined
        records (un-ordered — callers canonical-sort the final concat)."""
        if len(records) == 0 or self.build_rows == 0:
            return np.empty(0, self.out_dtype)
        probe_idx, build_rows = self._match_positions(records[self.probe_key])
        if len(probe_idx) == 0:
            return np.empty(0, self.out_dtype)
        brecs = self._fetch_build_rows(build_rows)
        precs = records[probe_idx]
        out = np.empty(len(probe_idx), self.out_dtype)
        out["key"] = precs[self.probe_key]
        for f in self.build_dtype.names:
            if f != self.build_key:
                out[f"b_{f}"] = brecs[f]
        for f in self.probe_dtype.names:
            if f != self.probe_key:
                out[f"p_{f}"] = precs[f]
        return out

    # -- columnar batches (PR 7) -----------------------------------------------
    def build_columns(self, columns: Dict[str, np.ndarray], n: int) -> None:
        """Build from a column block: the key column feeds the resident index
        directly (no row decode); rows are materialized once for the spillable
        build pages, which stay row-oriented so ``_fetch_build_rows``'s
        page-grouped gather is unchanged."""
        if n == 0:
            return
        self._key_chunks.append(
            np.asarray(columns[self.build_key][:n], np.int64).copy())
        self._writer.append_batch(columns_to_records(columns,
                                                     self.build_dtype, n))
        self.build_rows += n

    def probe_columns(self, columns: Dict[str, np.ndarray],
                      n: int) -> np.ndarray:
        """Probe with a column block: the searchsorted match runs on the key
        column as-is and output fields gather per column — no probe-side row
        materialization at all (the columnar join hot path)."""
        if n == 0 or self.build_rows == 0:
            return np.empty(0, self.out_dtype)
        pk = np.asarray(columns[self.probe_key][:n], np.int64)
        probe_idx, build_rows = self._match_positions(pk)
        if len(probe_idx) == 0:
            return np.empty(0, self.out_dtype)
        brecs = self._fetch_build_rows(build_rows)
        out = np.empty(len(probe_idx), self.out_dtype)
        out["key"] = pk[probe_idx]
        for f in self.build_dtype.names:
            if f != self.build_key:
                out[f"b_{f}"] = brecs[f]
        for f in self.probe_dtype.names:
            if f != self.probe_key:
                out[f"p_{f}"] = columns[f][:n][probe_idx]
        return out

    def close(self) -> None:
        """End the build table's job-data lifetime and return its pages."""
        self.ls.end_lifetime(self.pool.clock)
        self.pool.drop_set(self.ls)


def join_records(pool: BufferPool, build_ls: LocalitySet,
                 probe_ls: LocalitySet, build_dtype: np.dtype,
                 probe_dtype: np.dtype, build_key: str, probe_key: str,
                 out_name: str = "join_out",
                 page_size: int = 1 << 16) -> np.ndarray:
    """Single-pool materialized equi-join — the reference the distributed
    ``runtime/join.ClusterJoin`` must match byte-for-byte (after the shared
    canonical sort). Streams both sides through the sequential read service;
    the build table lives in pool pages via ``JoinService``."""
    js = JoinService(pool, f"{out_name}.build", build_dtype, probe_dtype,
                     build_key, probe_key, page_size=page_size)
    for recs in PageIterator(pool, build_ls, build_dtype,
                             sorted(build_ls.pages)):
        js.build_batch(recs)
    js.finish_build()
    outs = [js.probe_batch(recs)
            for recs in PageIterator(pool, probe_ls, probe_dtype,
                                     sorted(probe_ls.pages))]
    js.close()
    out = (np.concatenate(outs) if outs
           else np.empty(0, js.out_dtype))
    return canonical_join_sort(out)


def join_service(pool: BufferPool, build_ls: LocalitySet, probe_ls: LocalitySet,
                 build_dtype: np.dtype, probe_dtype: np.dtype,
                 build_key: str, probe_key: str,
                 out_name: str = "join_out") -> np.ndarray:
    """Hash join match count: build a table from ``build_ls``, probe with
    ``probe_ls``. Kept as the count-only entry point (``join_records``
    materializes the joined rows) — both run on ``JoinService``."""
    js = JoinService(pool, f"{out_name}.tbl", build_dtype, probe_dtype,
                     build_key, probe_key)
    for recs in PageIterator(pool, build_ls, build_dtype,
                             sorted(build_ls.pages)):
        js.build_batch(recs)
    js.finish_build()
    matches = sum(js.probe_count(recs)
                  for recs in PageIterator(pool, probe_ls, probe_dtype,
                                           sorted(probe_ls.pages)))
    js.close()
    return np.array([matches], dtype=np.int64)
