"""qwen3-0.6b [hf:Qwen/Qwen3-0.6B]: 28L d1024 16H (GQA kv=8) d_ff=3072
vocab=151936, qk_norm, head_dim=128."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-0.6b", family="dense",
    n_layers=28, d_model=1024, n_heads=16, kv_heads=8, d_ff=3072,
    vocab=151936, head_dim=128,
    qk_norm=True,
    remat="layer",
)
