"""minitron-8b [arXiv:2407.14679; hf]: 32L d4096 32H (GQA kv=8) d_ff=16384
vocab=256000 (pruned nemotron)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="minitron-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, kv_heads=8, d_ff=16384,
    vocab=256000, head_dim=128,
    remat="layer",
)
