"""olmo-1b [arXiv:2402.00838; hf]: 16L d2048 16H (MHA) d_ff=8192 vocab=50304,
non-parametric LayerNorm."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="olmo-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=16, kv_heads=16, d_ff=8192,
    vocab=50304, head_dim=128,
    norm="nonparam_ln",
    remat="layer",
)
