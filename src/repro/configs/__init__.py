"""Architecture config registry: ``get_config("<arch-id>")`` plus reduced
smoke configs for CPU tests."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from .base import (ALL_SHAPES, DECODE_32K, LONG_500K, PREFILL_32K, TRAIN_4K,
                   ArchConfig, ShapeConfig, shapes_for)

ARCH_IDS = [
    "grok-1-314b",
    "deepseek-v2-lite-16b",
    "glm4-9b",
    "olmo-1b",
    "qwen3-0.6b",
    "minitron-8b",
    "rwkv6-3b",
    "recurrentgemma-9b",
    "seamless-m4t-large-v2",
    "qwen2-vl-72b",
]


def _module_name(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "_")


def get_config(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_module_name(arch_id)}")
    return mod.CONFIG


def all_configs() -> Dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def smoke_config(arch_id: str) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests (assignment: small
    layers/width, few experts, tiny vocab)."""
    cfg = get_config(arch_id)
    kw = dict(
        n_layers=len(cfg.block_pattern) + 1 if cfg.block_pattern else 2,
        d_model=64,
        d_ff=128,
        vocab=256,
        remat="none",
        opt_state_dtype="float32",
    )
    if cfg.n_heads:
        kw.update(n_heads=4, kv_heads=min(cfg.kv_heads, 2), head_dim=16)
    if cfg.n_experts:
        kw.update(n_experts=4, n_shared_experts=min(cfg.n_shared_experts, 1),
                  top_k=2, d_expert=64)
    if cfg.kv_lora:
        kw.update(kv_lora=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16)
    if cfg.family == "ssm":
        kw.update(rwkv_head_dim=16)
    if cfg.n_encoder_layers:
        kw.update(n_encoder_layers=2)
    if cfg.window:
        kw.update(window=16)
    kw["page_size"] = 8
    return cfg.with_(**kw)


__all__ = ["ALL_SHAPES", "ARCH_IDS", "ArchConfig", "DECODE_32K", "LONG_500K",
           "PREFILL_32K", "ShapeConfig", "TRAIN_4K", "all_configs",
           "get_config", "shapes_for", "smoke_config"]
