"""Architecture / run configuration schema.

One ``ArchConfig`` instance per assigned architecture lives in
``configs/<id>.py``; ``shapes.py`` defines the assigned input-shape set.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int                 # 0 for attn-free
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_expert: int = 0            # routed-expert ffn width (deepseek: 1408)
    moe_strategy: str = "expert_parallel"  # or "expert_tp"
    capacity_factor: float = 1.25

    # --- MLA (deepseek) ---
    kv_lora: int = 0             # 0 -> standard GQA attention
    q_lora: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # --- misc attention ---
    qk_norm: bool = False        # qwen3
    rope: str = "rope"           # rope | mrope | none
    rope_theta: float = 10000.0
    window: Optional[int] = None  # sliding-window (local attention)
    # hybrid pattern (recurrentgemma): block types cycled over layers
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")

    # --- norms ---
    norm: str = "rmsnorm"        # rmsnorm | layernorm | nonparam_ln (olmo)

    # --- ssm (rwkv6) ---
    rwkv_head_dim: int = 64

    # --- enc-dec (seamless) ---
    n_encoder_layers: int = 0

    # --- frontend stubs (vlm / audio): inputs are precomputed embeddings ---
    embed_inputs: bool = False

    # --- parallelism preset (launch/mesh.sharding_rules) ---
    #   fsdp_tp  — FSDP over "data" + tensor-parallel over "model" (default)
    #   dp       — pure data parallel: batch over every mesh axis, params
    #              ZeRO-sharded over "data" only (small models)
    #   serve_2d — weight-stationary decode: weights 2D-sharded, FFN/MoE
    #              activations gathered over "data" around the block
    parallelism: str = "fsdp_tp"

    # --- attention lowering (xla chunked path) ---
    attn_block_k: int = 128     # kv-chunk size; larger = fewer carry r/w
    attn_p_bf16: bool = False   # cast softmax weights to bf16 for the PV dot

    # --- training / numerics ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"   # bf16 for the very large archs
    remat: str = "none"                # none | layer  (activation ckpting)
    microbatches: int = 1              # grad-accumulation slices per step
    tie_embeddings: bool = False

    # --- serving ---
    kv_cache_dtype: str = "bfloat16"
    page_size: int = 64

    # --- applicability flags (DESIGN.md §Arch-applicability) ---
    subquadratic: bool = False   # may run long_500k

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    def with_(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shapes_for(cfg: ArchConfig):
    """The assigned shape set, with long_500k only for sub-quadratic archs
    (the 8 full-attention skips are recorded in EXPERIMENTS.md)."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.subquadratic:
        out.append(LONG_500K)
    return out
