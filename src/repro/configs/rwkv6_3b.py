"""rwkv6-3b (Finch) [arXiv:2404.05892; hf]: 32L d2560 (attention-free,
data-dependent decay) d_ff=8960 vocab=65536.

TPU adaptation (DESIGN.md): public head_size is 64 (40 heads); we use
head_dim=80 (32 heads) so the head dim tiles the 16-way model axis cleanly.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=0, kv_heads=0, d_ff=8960,
    vocab=65536,
    rwkv_head_dim=80,
    rope="none",
    subquadratic=True,
    remat="layer",
)
