"""grok-1-314b [hf:xai-org/grok-1; unverified]: 64L d6144 48H (GQA kv=8)
d_ff=32768 vocab=131072, MoE 8 experts top-2."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, kv_heads=8, d_ff=32768,
    vocab=131072, head_dim=128,
    n_experts=8, top_k=2, d_expert=32768,
    # 8 experts don't tile a 16-way model axis -> expert-TP (ffn sharded)
    moe_strategy="expert_tp",
    opt_state_dtype="bfloat16",   # 314B params: m/v in bf16 to fit HBM
    remat="layer",
)
