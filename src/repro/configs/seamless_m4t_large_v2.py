"""seamless-m4t-large-v2 [arXiv:2308.11596; hf]: enc-dec 24L+24L d1024 16H
d_ff=8192 vocab=256206. Modality frontend is a STUB — input_specs() provides
precomputed audio-frame embeddings (per the assignment)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=24, n_encoder_layers=24, d_model=1024, n_heads=16, kv_heads=16,
    d_ff=8192, vocab=256206, head_dim=64,
    norm="layernorm", rope="none",
    embed_inputs=True,
    remat="layer",
)
