"""qwen2-vl-72b [arXiv:2409.12191; hf]: 80L d8192 64H (GQA kv=8) d_ff=29568
vocab=152064, M-RoPE. Vision frontend is a STUB — input_specs() provides
precomputed patch embeddings + 3D M-RoPE position ids."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, kv_heads=8, d_ff=29568,
    vocab=152064, head_dim=128,
    rope="mrope",
    embed_inputs=True,
    opt_state_dtype="bfloat16",   # 72B
    remat="layer",
)
