"""deepseek-v2-lite-16b [arXiv:2405.04434; hf]: 27L d2048 16H MLA kv_lora=512,
MoE 2 shared + 64 routed top-6, d_expert=1408, vocab=102400.

Deviation noted in DESIGN.md: the public config uses a dense FFN in layer 1;
we use MoE in every layer for a uniform scan body.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, kv_heads=16, d_ff=1408,
    vocab=102400, head_dim=128,
    n_experts=64, n_shared_experts=2, top_k=6, d_expert=1408,
    moe_strategy="expert_parallel",   # 64 % 16 == 0 -> all-to-all EP
    kv_lora=512, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    remat="layer",
)
