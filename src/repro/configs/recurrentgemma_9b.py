"""recurrentgemma-9b [arXiv:2402.19427]: 38L d4096 16H (MQA kv=1) d_ff=12288
vocab=256000; RG-LRU + local attention, pattern 2 recurrent : 1 attention,
window 2048."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, kv_heads=1, d_ff=12288,
    vocab=256000, head_dim=256,
    window=2048,
    block_pattern=("rec", "rec", "attn"),
    subquadratic=True,
    remat="layer",
)
