"""Production mesh + per-arch sharding rules.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state). Single pod: (16, 16) over ("data", "model") = 256 chips.
Multi-pod: (2, 16, 16) over ("pod", "data", "model") = 512 chips; the "pod"
axis extends data parallelism across the DCN.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig
from ..sharding import DEFAULT_RULES, spec_for


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes) -> Mesh:
    """Arbitrary mesh (tests use small ones, elastic remesh uses survivors)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def sharding_rules(cfg: ArchConfig, mesh: Mesh,
                   parallelism: Optional[str] = None) -> Dict:
    """Per-arch logical->mesh rules; divisibility-aware (DESIGN.md §5).

    ``parallelism`` overrides ``cfg.parallelism`` (used by the §Perf
    hillclimb to compare presets on the same arch)."""
    preset = parallelism or cfg.parallelism
    model_sz = axis_size(mesh, "model")
    rules = dict(DEFAULT_RULES)
    rules["batch"] = ("pod", "data")
    rules["ffn_batch"] = ("pod", "data")          # FFN/MoE-block batch axis
    rules["embed"] = "data"                       # FSDP/ZeRO
    rules["mlp"] = "model"
    rules["mlp_out"] = "model"                    # rg-lru gate outputs
    rules["heads"] = "model" if (cfg.n_heads and
                                 cfg.n_heads % model_sz == 0) else None
    kv_ok = cfg.kv_heads and cfg.kv_heads % model_sz == 0
    rules["kv"] = "model" if kv_ok else None
    # decode caches: if kv heads can't shard, shard the cache's seq dim
    rules["kv_seq"] = None if kv_ok else "model"
    rules["vocab"] = "model" if cfg.vocab % model_sz == 0 else None
    if cfg.n_experts and cfg.moe_strategy in ("expert_parallel",
                                              "expert_parallel_shardmap"):
        rules["experts"] = ("model" if cfg.n_experts % model_sz == 0 else None)
    else:
        rules["experts"] = None
    rules["heads_embed"] = "model"                # rwkv channel projections
    rules["embed_vec"] = None
    rules["embed_out"] = None

    if preset == "fsdp_tp_sp":
        # sequence parallelism: the residual stream stays sequence-sharded
        # over "model" between TP regions — GSPMD turns each TP all-reduce
        # into a reduce-scatter + all-gather pair (half the ring bytes, and
        # norms/elementwise work become sharded too)
        rules["seq"] = "model"
    elif preset == "dp":
        # pure data parallelism: no tensor sharding; batch over every axis
        rules["batch"] = ("pod", "data", "model")
        rules["ffn_batch"] = ("pod", "data", "model")
        for ax in ("mlp", "mlp_out", "heads", "kv", "vocab", "experts",
                   "heads_embed"):
            rules[ax] = None
        rules["kv_seq"] = None
    elif preset == "serve_2d":
        # weight-stationary decode: no FSDP dim (weights never gathered);
        # FFN width sharded over BOTH axes when divisible (314B fits at
        # ~2.5GB/chip), activations gathered over "data" around FFN/MoE
        # blocks and partial-summed back — token bytes ≪ weight bytes.
        rules["ffn_batch"] = None
        rules["embed"] = None
        total = axis_size(mesh, "data") * model_sz
        wide = ("data", "model")
        rules["mlp"] = wide if cfg.d_ff % total == 0 else rules["mlp"]
        if cfg.n_experts and cfg.moe_strategy == "expert_tp":
            rules["mlp"] = wide if cfg.d_expert % total == 0 else rules["mlp"]
        rules["mlp_out"] = wide if cfg.d_model % total == 0 else rules["mlp_out"]
    return rules


def param_shardings(model, cfg: ArchConfig, mesh: Mesh,
                    rules: Optional[Dict] = None):
    """PartitionSpec tree for the model params (from logical axes)."""
    rules = rules or sharding_rules(cfg, mesh)
    axes = model.param_axes()
    return jax.tree.map(
        lambda a: NamedSharding(mesh, spec_for(a, rules, mesh)),
        axes, is_leaf=lambda x: isinstance(x, tuple))


def dp_axes_for(mesh: Mesh, batch: int):
    """Largest ("pod","data") prefix that divides the batch dim."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    cands = [a for a in ("pod", "data") if a in sizes]
    # try full product, then data only, then nothing
    options = [tuple(cands)] + ([("data",)] if "data" in sizes else []) + [()]
    for opt in options:
        prod = int(np.prod([sizes[a] for a in opt])) if opt else 1
        if prod and batch % prod == 0:
            return opt if len(opt) > 1 else (opt[0] if opt else None)
    return None


def batch_shardings(batch_specs, mesh: Mesh) -> Dict:
    """Shard every batch leaf on its leading (batch) dim (when divisible)."""

    def shard(leaf):
        dp = dp_axes_for(mesh, leaf.shape[0])
        spec = [dp] + [None] * (len(leaf.shape) - 1)
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(shard, batch_specs)


def cache_shardings(cache_specs, cfg: ArchConfig, mesh: Mesh,
                    rules: Optional[Dict] = None):
    """Decode-cache shardings: batch on data(+pod); kv heads on model when
    divisible, else the cache sequence dim on model (DESIGN.md §5)."""
    rules = rules or sharding_rules(cfg, mesh)
    model_sz = axis_size(mesh, "model")

    def key_of(path) -> str:
        names = [getattr(k, "key", getattr(k, "idx", "")) for k in path]
        return str(names[-1]) if names else ""

    def spec_of(path, leaf):
        shp = leaf.shape
        nd = len(shp)
        name = key_of(path)
        # caches may carry a leading stacked-layer dim (scan) or not (rem)
        if name in ("k", "v", "cross_k", "cross_v"):   # [(L,)B,KH,T,hd]
            dp = dp_axes_for(mesh, shp[nd - 4])
            kv_ax = rules.get("kv")
            seq_ax = (rules.get("kv_seq")
                      if shp[-2] % model_sz == 0 else None)
            lead = [None] * (nd - 4)
            return NamedSharding(mesh, P(*lead, dp, kv_ax, seq_ax, None))
        if name in ("c_kv", "k_rope"):                 # [(L,)B,T,lora/rope]
            dp = dp_axes_for(mesh, shp[nd - 3])
            seq_ax = "model" if shp[-2] % model_sz == 0 else None
            lead = [None] * (nd - 3)
            return NamedSharding(mesh, P(*lead, dp, seq_ax, None))
        if name == "S":                                # [(L,)B,H,dk,dv]
            dp = dp_axes_for(mesh, shp[nd - 4])
            h_ax = "model" if shp[-3] % model_sz == 0 else None
            lead = [None] * (nd - 4)
            return NamedSharding(mesh, P(*lead, dp, h_ax, None, None))
        if name == "conv":                             # [(L,)B,CONV_W-1,w]
            dp = dp_axes_for(mesh, shp[nd - 3])
            w_ax = "model" if shp[-1] % model_sz == 0 else None
            lead = [None] * (nd - 3)
            return NamedSharding(mesh, P(*lead, dp, None, w_ax))
        # [(L,)B,d] token-shift / h states
        dp = dp_axes_for(mesh, shp[nd - 2])
        d_ax = "model" if shp[-1] % model_sz == 0 else None
        lead = [None] * (nd - 2)
        return NamedSharding(mesh, P(*lead, dp, d_ax))

    return jax.tree_util.tree_map_with_path(spec_of, cache_specs)
