import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape × mesh) cell: build abstract inputs
(ShapeDtypeStruct — no allocation), lower the step function with explicit
in_shardings, compile, and record memory_analysis / cost_analysis / the
HLO-derived roofline inputs. The FIRST TWO LINES of this file force 512
placeholder CPU devices BEFORE any jax import (jax locks the device count on
first init); do not set that flag globally.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --multi-pod     # 2x16x16
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import sharding as shardlib
from repro.configs import ARCH_IDS, get_config, shapes_for
from repro.configs.base import ALL_SHAPES, ArchConfig, ShapeConfig
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import (batch_shardings, cache_shardings,
                               make_production_mesh, param_shardings,
                               sharding_rules)
from repro.models.model import (active_params, build_model, count_params,
                                decode_cache_specs, input_specs)
from repro.optim import make_train_step
from repro.optim.adamw import AdamWState
from repro.optim.train_state import TrainState

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _serve_param_sds(params_sds, compute_dtype):
    dt = jnp.dtype(compute_dtype)

    def cast(s):
        if s.dtype == jnp.float32 and len(s.shape) >= 2:
            return jax.ShapeDtypeStruct(s.shape, dt)
        return s
    return jax.tree.map(cast, params_sds)


def lower_cell(arch_id: str, shape: ShapeConfig, multi_pod: bool,
               overrides: Optional[Dict[str, Any]] = None,
               mla_absorbed: bool = False) -> Dict[str, Any]:
    """Lower + compile one cell; returns the analysis record."""
    cfg = get_config(arch_id)
    if overrides:
        cfg = cfg.with_(**overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = sharding_rules(cfg, mesh)
    model = build_model(cfg, mla_absorbed=mla_absorbed)
    rec: Dict[str, Any] = {
        "arch": arch_id, "shape": shape.name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind,
        "params": count_params(cfg), "active_params": active_params(cfg),
    }

    params_sds = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    pspecs = param_shardings(model, cfg, mesh, rules)

    t0 = time.time()
    with shardlib.use_rules(rules, mesh):
        if shape.kind == "train":
            opt_sds = jax.eval_shape(
                lambda: AdamWState(
                    step=jnp.zeros((), jnp.int32),
                    m=jax.tree.map(
                        lambda s: jax.ShapeDtypeStruct(
                            s.shape, jnp.dtype(cfg.opt_state_dtype)),
                        params_sds),
                    v=jax.tree.map(
                        lambda s: jax.ShapeDtypeStruct(
                            s.shape, jnp.dtype(cfg.opt_state_dtype)),
                        params_sds)))
            state_sds = TrainState(params=params_sds, opt=opt_sds)
            state_sh = TrainState(
                params=pspecs,
                opt=AdamWState(step=NamedSharding(mesh, P()),
                               m=pspecs, v=pspecs))
            batch_sds = input_specs(cfg, shape)["batch"]
            batch_sh = batch_shardings(batch_sds, mesh)
            step = make_train_step(model.loss,
                                   microbatches=cfg.microbatches)
            jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_sds, batch_sds)
        elif shape.kind == "prefill":
            sp_sds = _serve_param_sds(params_sds, cfg.compute_dtype)
            batch_sds = input_specs(cfg, shape)["batch"]
            batch_sh = batch_shardings(batch_sds, mesh)

            def prefill_fn(params, batch):
                return model.prefill(params, batch)

            if cfg.family == "encdec":
                def prefill_fn(params, batch):  # noqa: F811
                    memory = model.encode(params, batch["src_embeds"])
                    cache = model.decode_cache_init(
                        batch["tokens"].shape[0], shape.seq_len,
                        memory=memory, params=params)
                    return memory, cache

            jitted = jax.jit(prefill_fn, in_shardings=(pspecs, batch_sh))
            lowered = jitted.lower(sp_sds, batch_sds)
        else:  # decode
            sp_sds = _serve_param_sds(params_sds, cfg.compute_dtype)
            specs = input_specs(cfg, shape, model=model)
            batch_sds, cache_sds = specs["batch"], specs["cache"]
            batch_sh = batch_shardings(batch_sds, mesh)
            cache_sh = cache_shardings(cache_sds, cfg, mesh, rules)

            def decode_fn(params, batch, cache, pos):
                return model.decode_step(params, batch, cache, pos)

            jitted = jax.jit(decode_fn,
                             in_shardings=(pspecs, batch_sh, cache_sh,
                                           NamedSharding(mesh, P())),
                             donate_argnums=(2,))
            lowered = jitted.lower(sp_sds, batch_sds, cache_sds,
                                   jax.ShapeDtypeStruct((), jnp.int32))

        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

    ma = compiled.memory_analysis()
    if ma is not None:
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
        }
        rec["memory"]["per_device_total"] = (
            rec["memory"]["argument_bytes"] + rec["memory"]["output_bytes"]
            + rec["memory"]["temp_bytes"] - rec["memory"]["alias_bytes"])
    ca = compiled.cost_analysis() or {}
    rec["cost_analysis"] = {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "note": "XLA cost_analysis does not scale while-loop bodies; "
                "see hlo stats for trip-scaled numbers",
    }
    stats = analyze_hlo(compiled.as_text())
    rec["hlo"] = {
        "dot_flops": stats.dot_flops,
        "hbm_bytes": stats.hbm_bytes,
        "collective_bytes": stats.collective_bytes,
        "collective_count": stats.collective_count,
        "total_collective_bytes": stats.total_collective_bytes,
    }
    rec["status"] = "ok"
    return rec


def cell_path(arch_id: str, shape_name: str, multi_pod: bool,
              tag: str = "") -> str:
    mesh = "2x16x16" if multi_pod else "16x16"
    os.makedirs(RESULTS_DIR, exist_ok=True)
    suffix = f"_{tag}" if tag else ""
    return os.path.join(RESULTS_DIR,
                        f"{arch_id}_{shape_name}_{mesh}{suffix}.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="single arch id")
    ap.add_argument("--shape", default=None, help="single shape name")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="", help="results filename tag")
    ap.add_argument("--mla-absorbed", action="store_true")
    ap.add_argument("--override", default=None,
                    help="JSON dict of ArchConfig overrides")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ARCH_IDS
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    overrides = json.loads(args.override) if args.override else None

    failures = []
    for arch_id in archs:
        cfg = get_config(arch_id)
        shapes = shapes_for(cfg)
        skips = [s for s in ALL_SHAPES if s not in shapes]
        for s in skips:
            if args.shape and s.name != args.shape:
                continue
            print(f"SKIP  {arch_id:24s} {s.name:12s} "
                  f"(full-attention arch; see DESIGN.md)")
        for shape in shapes:
            if args.shape and shape.name != args.shape:
                continue
            for mp in meshes:
                path = cell_path(arch_id, shape.name, mp, args.tag)
                if os.path.exists(path) and not args.force:
                    print(f"CACHED {arch_id:24s} {shape.name:12s} "
                          f"{'2x16x16' if mp else '16x16'}")
                    continue
                label = (f"{arch_id:24s} {shape.name:12s} "
                         f"{'2x16x16' if mp else '16x16'}")
                try:
                    rec = lower_cell(arch_id, shape, mp,
                                     overrides=overrides,
                                     mla_absorbed=args.mla_absorbed)
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                    mem = rec.get("memory", {}).get("per_device_total", 0)
                    print(f"OK    {label} lower={rec['lower_s']}s "
                          f"compile={rec['compile_s']}s "
                          f"mem/dev={mem/2**30:.2f}GiB "
                          f"dotTF={rec['hlo']['dot_flops']/1e12:.2f} "
                          f"coll={rec['hlo']['total_collective_bytes']/2**30:.3f}GiB")
                except Exception as e:  # noqa: BLE001
                    failures.append((label, repr(e)))
                    print(f"FAIL  {label}: {e!r}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for l, e in failures:
            print(" ", l, e)
        raise SystemExit(1)
    print("\nALL CELLS OK")


if __name__ == "__main__":
    main()
