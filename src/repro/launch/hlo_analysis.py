"""Post-SPMD HLO analysis for the roofline (launch/dryrun + benchmarks).

``compiled.cost_analysis()`` does NOT scale ``while`` bodies by trip count
(verified empirically: a 10-iteration scan of a matmul reports the FLOPs of
one matmul), so this module re-derives the three roofline inputs directly
from ``compiled.as_text()``:

* dot FLOPs        — every ``dot`` op: 2 × |result| × |contracted dims|,
                     multiplied through the while-loop nest using the
                     ``known_trip_count`` backend_config XLA attaches to
                     scan-derived loops;
* HBM bytes        — fusion-boundary traffic model: for each materializing
                     instruction, bytes = |result| + Σ|operands| (slicing ops
                     counted as 2×|result|; in-place dynamic-update-slice as
                     2×|update|), trip-scaled;
* collective bytes — result sizes of all-gather / all-reduce /
                     reduce-scatter / all-to-all / collective-permute
                     (+ their async -start forms), trip-scaled, per type.

All numbers are PER DEVICE (the HLO is the post-partitioning module).
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLED_SINGLE_RE = re.compile(
    r"(?:body|condition|calls|to_apply)=%([\w.\-]+)")
_CALLED_MULTI_RE = re.compile(
    r"(?:calls|branch_computations)=\{([^}]*)\}")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")
_META_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "partition-id", "replica-id", "iota", "custom-call"}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclass
class Instr:
    name: str
    opcode: str
    result_type: str
    result_bytes: int
    operands: List[str]
    rest: str               # attrs after the operand list

    @property
    def trip_count(self) -> Optional[int]:
        m = _TRIP_RE.search(self.rest)
        return int(m.group(1)) if m else None

    def called(self) -> List[str]:
        out = []
        for m in _CALLED_SINGLE_RE.finditer(self.rest):
            out.append(m.group(1))
        for m in _CALLED_MULTI_RE.finditer(self.rest):
            for nm in m.group(1).split(","):
                nm = nm.strip().lstrip("%")
                if nm and nm not in out:
                    out.append(nm)
        return out


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        mc = _COMP_RE.match(line)
        if mc and ("->" in line):
            cur = Computation(mc.group(1))
            comps[cur.name] = cur
            if line.lstrip().startswith("ENTRY"):
                entry = cur.name
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(line)
        if not mi:
            continue
        name, rtype, opcode, tail = mi.groups()
        # split operand list from attrs: first unmatched ')' closes operands
        depth = 1
        idx = 0
        for idx, ch in enumerate(tail):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        opnds_str, rest = tail[:idx], tail[idx + 1:]
        operands = [t.strip().lstrip("%") for t in re.findall(
            r"%[\w.\-]+", opnds_str)]
        cur.instrs.append(Instr(name, opcode, rtype, _shape_bytes(rtype),
                                operands, rest))
    return comps, entry


@dataclass
class HloStats:
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: Dict[str, float] = field(default_factory=dict)
    collective_count: Dict[str, int] = field(default_factory=dict)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def _instr_bytes(ins: Instr, sizes: Dict[str, int]) -> float:
    op = ins.opcode
    if op in _META_OPS:
        return 0.0
    if op in ("dynamic-slice", "gather", "slice"):
        return 2.0 * ins.result_bytes
    if op == "dynamic-update-slice":
        upd = sizes.get(ins.operands[1], 0) if len(ins.operands) > 1 else 0
        return 2.0 * upd
    if op == "scatter":
        upd = sizes.get(ins.operands[-1], ins.result_bytes)
        return 2.0 * upd
    if op == "while":  # accounted via recursion
        return 0.0
    if op in ("call", "conditional", "fusion") and op != "fusion":
        return 0.0
    total = float(ins.result_bytes)
    for o in ins.operands:
        total += sizes.get(o, 0)
    return total


def _dot_flops(ins: Instr, sizes_dims: Dict[str, List[int]]) -> float:
    res_dims = _shape_dims(ins.result_type)
    n_res = 1
    for d in res_dims:
        n_res *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
    contract = 1
    if m and ins.operands:
        lhs_dims = sizes_dims.get(ins.operands[0], [])
        for di in m.group(1).split(","):
            if di and int(di) < len(lhs_dims):
                contract *= lhs_dims[int(di)]
    return 2.0 * n_res * contract


def analyze_hlo(text: str) -> HloStats:
    comps, entry = parse_hlo(text)
    if entry is None:
        return HloStats()
    sizes = {i.name: i.result_bytes
             for c in comps.values() for i in c.instrs}
    dims = {i.name: _shape_dims(i.result_type)
            for c in comps.values() for i in c.instrs}
    stats = HloStats()
    seen_fusion_comps = set()

    def walk(comp_name: str, mult: float) -> None:
        comp = comps.get(comp_name)
        if comp is None:
            return
        for ins in comp.instrs:
            if ins.opcode == "while":
                tc = ins.trip_count or 1
                for callee in ins.called():
                    walk(callee, mult * tc)
                continue
            if ins.opcode in ("call", "conditional", "async-start"):
                for callee in ins.called():
                    walk(callee, mult)
                continue
            base = ins.opcode.replace("-start", "")
            if base in COLLECTIVES:
                stats.collective_bytes[base] = (
                    stats.collective_bytes.get(base, 0.0)
                    + mult * ins.result_bytes)
                stats.collective_count[base] = (
                    stats.collective_count.get(base, 0) + int(mult))
            if ins.opcode == "dot":
                stats.dot_flops += mult * _dot_flops(ins, dims)
            if ins.opcode == "fusion":
                # dots inside fusions still count (rare on TPU path)
                for callee in ins.called():
                    fc = comps.get(callee)
                    if fc is None:
                        continue
                    for fi in fc.instrs:
                        if fi.opcode == "dot":
                            stats.dot_flops += mult * _dot_flops(fi, dims)
            stats.hbm_bytes += mult * _instr_bytes(ins, sizes)
    walk(entry, 1.0)
    return stats
