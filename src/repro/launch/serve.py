"""Serving driver: batched prefill + decode over the Pangea paged KV cache.

The PagedKVCache (core/kvcache.py) owns HBM page residency with the paper's
Eq.-1 priority (finished/cold sequences evicted first); the jitted decode
step reads pages through block tables (kernels/paged_attention is the TPU
device half; on CPU this driver uses the model's dense decode path per
sequence batch while the page manager exercises the paging policy).
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.configs.base import ArchConfig
from repro.core import PagedKVCache
from repro.models.model import build_model


@dataclass
class Request:
    req_id: int
    prompt: np.ndarray          # [T] int32
    max_new_tokens: int = 16
    generated: List[int] = field(default_factory=list)


class ServeLoop:
    """Static-batch serving with paged KV accounting.

    Each active slot is one sequence; the PagedKVCache tracks its pages and
    offloads cold/finished sequences' pages under HBM pressure.
    """

    def __init__(self, cfg: ArchConfig, *, batch_slots: int = 4,
                 max_len: int = 256, hbm_pages: Optional[int] = None,
                 seed: int = 0):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = self.model.init(jax.random.PRNGKey(seed))
        self.batch_slots = batch_slots
        self.max_len = max_len
        pages_per_seq = -(-max_len // cfg.page_size)
        self.pager = PagedKVCache(
            num_layers=cfg.n_layers,
            hbm_pages=hbm_pages or batch_slots * pages_per_seq,
            page_size=cfg.page_size,
            kv_heads=max(cfg.kv_heads, 1),
            head_dim=cfg.resolved_head_dim or 16)
        self._decode = jax.jit(
            lambda p, b, c, pos: self.model.decode_step(p, b, c, pos))
        self.stats = {"prefill_tokens": 0, "decode_tokens": 0}

    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        cfg = self.cfg
        out: Dict[int, List[int]] = {}
        queue = list(requests)
        t0 = time.time()
        while queue:
            active = queue[:self.batch_slots]
            queue = queue[self.batch_slots:]
            B = len(active)
            plen = max(len(r.prompt) for r in active)
            toks = np.zeros((B, plen), np.int32)
            for i, r in enumerate(active):
                toks[i, :len(r.prompt)] = r.prompt
                self.pager.start_sequence(r.req_id)
                self.pager.ensure_capacity(r.req_id, plen)
                self.pager.advance(r.req_id, plen)
            logits, cache = self.model.prefill(
                self.params, {"tokens": jnp.asarray(toks)},
                max_len=self.max_len)
            self.stats["prefill_tokens"] += B * plen
            last = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
            nmax = max(r.max_new_tokens for r in active)
            for step in range(nmax):
                pos = plen + step
                for r in active:
                    self.pager.ensure_capacity(r.req_id, 1)
                    self.pager.advance(r.req_id, 1)
                    # touch the block table = the decode read pattern
                    self.pager.block_table(
                        r.req_id, -(-self.max_len // cfg.page_size))
                batch = {"tokens": jnp.asarray(last[:, None])}
                logits, cache = self._decode(self.params, batch, cache,
                                             jnp.asarray(pos, jnp.int32))
                last = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
                self.stats["decode_tokens"] += B
                for i, r in enumerate(active):
                    if len(r.generated) < r.max_new_tokens:
                        r.generated.append(int(last[i]))
            for r in active:
                self.pager.finish_sequence(r.req_id)
                out[r.req_id] = r.generated
        dt = max(time.time() - t0, 1e-9)
        self.stats["decode_tok_per_s"] = self.stats["decode_tokens"] / dt
        self.stats.update(self.pager.stats)
        return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()
    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab, args.prompt_len,
                                    dtype=np.int32),
                    max_new_tokens=args.new_tokens)
            for i in range(args.requests)]
    loop = ServeLoop(cfg, max_len=args.prompt_len + args.new_tokens + 8)
    out = loop.run(reqs)
    print(f"served {len(out)} requests; stats: {loop.stats}")


if __name__ == "__main__":
    main()
