"""Training driver: data pipeline (buffer pool) → jitted train_step →
checkpoint manager (async, heterogeneous layouts) → fault-tolerance hooks.

Runs end-to-end on CPU at reduced scale (examples/train_100m.py) and carries
the same structure the production mesh uses (the dry-run lowers exactly this
step function at full scale).
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass
from typing import Any, Dict, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, smoke_config
from repro.configs.base import ArchConfig
from repro.core import BufferPool
from repro.data.pipeline import BatchLoader, synthetic_token_dataset
from repro.models.model import build_model
from repro.optim import make_train_step
from repro.optim.train_state import TrainState, make_train_state
from repro.runtime import StepTimer


@dataclass
class TrainLoopResult:
    losses: list
    steps: int
    restored_from: Optional[int]
    tokens_per_s: float


def run_training(cfg: ArchConfig, *, steps: int = 20, batch_size: int = 8,
                 seq_len: int = 64, lr: float = 3e-4,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 10,
                 microbatches: int = 1, pool_bytes: int = 256 << 20,
                 num_sequences: Optional[int] = None, seed: int = 0,
                 log_every: int = 5,
                 fail_at_step: Optional[int] = None) -> TrainLoopResult:
    """Train on synthetic data staged through the Pangea buffer pool.

    ``fail_at_step`` simulates a crash (raises); calling run_training again
    with the same ckpt_dir restores and continues — the fault-tolerance test
    uses this.
    """
    model = build_model(cfg)
    key = jax.random.PRNGKey(seed)
    params = model.init(key)
    state = make_train_state(params, cfg.opt_state_dtype)
    step_fn = jax.jit(make_train_step(model.loss, lr=lr,
                                      microbatches=microbatches),
                      donate_argnums=(0,))

    mgr = None
    restored_from = None
    if ckpt_dir:
        mgr = CheckpointManager(ckpt_dir, layouts=("row", "col"),
                                num_shards=4)
        last = mgr.latest_step()
        if last is not None:
            state = mgr.restore(state, step=last)
            state = jax.tree.map(jnp.asarray, state)
            restored_from = last

    pool = BufferPool(pool_bytes)
    nseq = num_sequences or batch_size * max(steps, 1)
    ds = synthetic_token_dataset(pool, "train_tokens", vocab=cfg.vocab,
                                 num_sequences=nseq, seq_len=seq_len,
                                 seed=seed)
    timer = StepTimer([0])
    losses = []
    done = int(state.opt.step)
    t_start = time.time()
    tokens = 0

    def batches() -> Iterable[Dict[str, np.ndarray]]:
        while True:
            for b in BatchLoader(ds, batch_size=batch_size):
                yield b

    for batch in batches():
        if done >= steps:
            break
        jb = {k: jnp.asarray(v) for k, v in batch.items()}
        if cfg.rope == "mrope":
            T = jb["tokens"].shape[1]
            jb["positions"] = jnp.broadcast_to(
                jnp.arange(T)[None, None, :],
                (jb["tokens"].shape[0], 3, T)).astype(jnp.int32)
        if cfg.embed_inputs and cfg.family != "encdec":
            jb["embeds"] = state.params["embed"][jb.pop("tokens")]
        if cfg.family == "encdec":
            jb["src_embeds"] = jax.random.normal(
                jax.random.fold_in(key, done),
                (jb["tokens"].shape[0], seq_len, cfg.d_model))
        t0 = time.time()
        state, metrics = step_fn(state, jb)
        loss = float(metrics["loss"])
        timer.record(0, time.time() - t0)
        losses.append(loss)
        tokens += batch_size * seq_len
        done = int(metrics["step"])
        if done % log_every == 0 or done == steps:
            print(f"step {done:5d} loss {loss:.4f} "
                  f"({timer.ewma[0]*1e3:.0f} ms/step)")
        if mgr and done % ckpt_every == 0:
            mgr.save(done, jax.device_get(state), async_=True)
        if fail_at_step is not None and done >= fail_at_step:
            if mgr:
                mgr.wait()
            raise RuntimeError(f"simulated failure at step {done}")
    if mgr:
        mgr.save(done, jax.device_get(state), async_=False)
    dt = max(time.time() - t_start, 1e-9)
    return TrainLoopResult(losses=losses, steps=done,
                           restored_from=restored_from,
                           tokens_per_s=tokens / dt)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    res = run_training(cfg, steps=args.steps, batch_size=args.batch_size,
                       seq_len=args.seq_len, ckpt_dir=args.ckpt_dir,
                       microbatches=args.microbatches)
    print(f"done: {res.steps} steps, final loss {res.losses[-1]:.4f}, "
          f"{res.tokens_per_s:.0f} tok/s")


if __name__ == "__main__":
    main()
