"""Pure-jnp oracles for the linear-scan kernels.

Two recurrences:
* diag_scan   — h_t = a_t ⊙ h_{t-1} + b_t (vector state; RG-LRU).
* gla_scan    — S_t = diag(w_t) S_{t-1} + k_t ⊗ v_t,
                o_t = r_t · (S_{t-1} + diag(u) k_t ⊗ v_t)  (RWKV6 wkv core).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def diag_scan_ref(a: jnp.ndarray, b: jnp.ndarray,
                  h0: Optional[jnp.ndarray] = None
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """a, b: [B, T, D]; h0: [B, D]. Returns (h[B,T,D], h_final[B,D])."""
    B, T, D = a.shape
    if h0 is None:
        h0 = jnp.zeros((B, D), a.dtype)

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    hT, hs = jax.lax.scan(step, h0.astype(jnp.float32),
                          (jnp.moveaxis(a, 1, 0).astype(jnp.float32),
                           jnp.moveaxis(b, 1, 0).astype(jnp.float32)))
    return jnp.moveaxis(hs, 0, 1).astype(a.dtype), hT.astype(a.dtype)


def gla_scan_ref(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                 w: jnp.ndarray, u: jnp.ndarray,
                 s0: Optional[jnp.ndarray] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """RWKV6 wkv (sequential oracle).

    r, k, w: [B, T, Dk]; v: [B, T, Dv]; u: [B, Dk] (per-head bonus);
    w holds LOG decays (log w_t ≤ 0). s0: [B, Dk, Dv].
    Returns (o [B, T, Dv], s_final [B, Dk, Dv]).
    """
    B, T, Dk = r.shape
    Dv = v.shape[-1]
    if s0 is None:
        s0 = jnp.zeros((B, Dk, Dv), jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = inp  # [B, Dk], [B, Dk], [B, Dv], [B, Dk]
        kv = kt[:, :, None] * vt[:, None, :]              # [B, Dk, Dv]
        o = jnp.einsum("bk,bkv->bv", rt, S + u[:, :, None] * kv)
        S = jnp.exp(wt)[:, :, None] * S + kv
        return S, o

    inputs = tuple(jnp.moveaxis(x, 1, 0).astype(jnp.float32)
                   for x in (r, k, v, w))
    ST, os = jax.lax.scan(step, s0.astype(jnp.float32), inputs)
    return jnp.moveaxis(os, 0, 1).astype(v.dtype), ST
