"""Jit'd wrappers for the linear-scan kernels (kernel / xla dispatch)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .kernel import diag_scan_kernel, gla_scan_kernel
from .ref import diag_scan_ref, gla_scan_ref


def diag_scan(a: jnp.ndarray, b: jnp.ndarray,
              h0: Optional[jnp.ndarray] = None, *, impl: str = "xla",
              chunk: int = 256, interpret: bool = True
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    B, T, D = a.shape
    if h0 is None:
        h0 = jnp.zeros((B, D), a.dtype)
    if impl == "kernel":
        pad = (-T) % min(chunk, max(T, 1))
        if pad:
            a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
            b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        h, hT = diag_scan_kernel(a, b, h0, chunk=chunk, interpret=interpret)
        if pad:
            h = h[:, :T]
            hT = h[:, -1]
        return h, hT
    if impl == "xla":
        return diag_scan_ref(a, b, h0)
    raise ValueError(f"unknown impl {impl!r}")


def gla_scan(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, w: jnp.ndarray,
             u: jnp.ndarray, *, impl: str = "xla", chunk: int = 64,
             interpret: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """RWKV6 wkv core. w = LOG decays. See ref.gla_scan_ref for shapes."""
    if impl == "kernel":
        B, T, Dk = r.shape
        c = min(chunk, T)
        pad = (-T) % c
        if pad:
            widths = ((0, 0), (0, pad), (0, 0))
            r = jnp.pad(r, widths)
            k = jnp.pad(k, widths)
            v = jnp.pad(v, widths)
            w = jnp.pad(w, widths)  # log-decay 0 = no decay; k=0 → no update
        o, S = gla_scan_kernel(r, k, v, w, u, chunk=c, interpret=interpret)
        return (o[:, :T] if pad else o), S
    if impl == "xla":
        return gla_scan_ref(r, k, v, w, u)
    if impl == "xla_chunked":
        return _gla_chunked_xla(r, k, v, w, u, chunk=chunk)
    raise ValueError(f"unknown impl {impl!r}")


def _gla_chunked_xla(r, k, v, w, u, *, chunk: int = 64):
    """Chunk-parallel GLA in pure XLA (lax.scan over chunks, matmuls within):
    the same math as the Pallas kernel — used for dry-run lowering so the HLO
    contains the real matmul structure (and its FLOPs) instead of a
    length-T sequential loop."""
    B, T, Dk = r.shape
    Dv = v.shape[-1]
    c = min(chunk, T)
    pad = (-T) % c
    if pad:
        widths = ((0, 0), (0, pad), (0, 0))
        r, k, v, w = (jnp.pad(x, widths) for x in (r, k, v, w))
    Tp = r.shape[1]
    nc = Tp // c

    def reshape(x):
        return jnp.moveaxis(
            x.reshape(B, nc, c, x.shape[-1]), 1, 0).astype(jnp.float32)

    rs, ks, vs, ws = map(reshape, (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(S, inp):
        rc, kc, vc, wc = inp                 # [B, c, D*]
        cum = jnp.cumsum(wc, axis=1)
        ex_cum = cum - wc
        c_last = cum[:, -1:, :]
        q_inter = rc * jnp.exp(ex_cum)
        q_intra = rc * jnp.exp(ex_cum - c_last)
        k_intra = kc * jnp.exp(c_last - cum)
        o = jnp.einsum("blk,bkv->blv", q_inter, S)
        A = jnp.einsum("bik,bjk->bij", q_intra, k_intra)
        ii = jnp.arange(c)
        A = jnp.where(ii[None, :, None] > ii[None, None, :], A, 0.0)
        bonus = jnp.einsum("blk,bk,blk->bl", rc, uf, kc)
        o = o + jnp.einsum("bij,bjv->biv", A, vc) + bonus[..., None] * vc
        S = jnp.exp(c_last).swapaxes(1, 2) * S + jnp.einsum(
            "blk,blv->bkv", k_intra, vc)
        return S, o

    S0 = jnp.zeros((B, Dk, Dv), jnp.float32)
    S, os = jax.lax.scan(step, S0, (rs, ks, vs, ws))
    o = jnp.moveaxis(os, 0, 1).reshape(B, Tp, Dv)[:, :T].astype(v.dtype)
    return o, S
