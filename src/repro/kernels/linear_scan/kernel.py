"""Linear-scan Pallas TPU kernels: diagonal recurrence (RG-LRU) and chunked
matrix-state GLA (RWKV6 wkv core).

Hardware adaptation (DESIGN.md §2): the GPU implementations of these models
use warp-level scans; on TPU we tile time into VMEM-resident chunks and carry
the recurrent state in VMEM scratch across a sequential grid dimension. The
GLA chunk math uses the decay-telescoped factorization

    A_ij = (r_i ∘ e^{c_i - w_i - c_L}) · (k_j ∘ e^{c_L - c_j}),  c = cumsum(log w)

in which both factors have non-positive exponents — numerically stable for
any chunk length (no 1/cumprod blow-up), and the contraction is an MXU matmul.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ---------------------------------------------------------------------------
# Diagonal scan: h_t = a_t * h_{t-1} + b_t        (RG-LRU)
# ---------------------------------------------------------------------------
def _diag_kernel(a_ref, b_ref, h0_ref, o_ref, hT_ref, h_ref, *, chunk: int):
    c = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(c == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)[None]

    def body(i, h):
        h = a_ref[0, i].astype(jnp.float32) * h + b_ref[0, i].astype(jnp.float32)
        o_ref[0, i] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, body, h_ref[0])
    h_ref[...] = h[None]

    @pl.when(c == nc - 1)
    def _fin():
        hT_ref[0] = h.astype(hT_ref.dtype)


def diag_scan_kernel(a: jnp.ndarray, b: jnp.ndarray, h0: jnp.ndarray, *,
                     chunk: int = 256, interpret: bool = False
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """a, b: [B, T, D] (T % chunk == 0); h0: [B, D] -> (h, h_final)."""
    B, T, D = a.shape
    chunk = min(chunk, T)
    assert T % chunk == 0
    nc = T // chunk
    grid = (B, nc)
    out = pl.pallas_call(
        functools.partial(_diag_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, D), lambda b_, c: (b_, c, 0)),
            pl.BlockSpec((1, chunk, D), lambda b_, c: (b_, c, 0)),
            pl.BlockSpec((1, D), lambda b_, c: (b_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, D), lambda b_, c: (b_, c, 0)),
            pl.BlockSpec((1, D), lambda b_, c: (b_, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, D), a.dtype),
            jax.ShapeDtypeStruct((B, D), a.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((1, D), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
    return out[0], out[1]


# ---------------------------------------------------------------------------
# Chunked GLA / RWKV6 wkv:
#   S_t = diag(exp(w_t)) S_{t-1} + k_t v_t^T ;  o_t = r_t (S_{t-1} + u k_t v_t^T)
# ---------------------------------------------------------------------------
def _gla_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, sT_ref, s_ref, *,
                chunk: int):
    c = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(c == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0].astype(jnp.float32)      # [L, Dk]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)      # [L, Dv]
    w = w_ref[0].astype(jnp.float32)      # [L, Dk] log decays (<= 0)
    u = u_ref[0].astype(jnp.float32)      # [1? -> Dk] bonus
    L = r.shape[0]

    cum = jnp.cumsum(w, axis=0)           # inclusive: c_i
    ex_cum = cum - w                      # exclusive: c_{i-1}
    c_last = cum[-1:]                     # [1, Dk]

    q_inter = r * jnp.exp(ex_cum)                       # decay start→i-1
    q_intra = r * jnp.exp(ex_cum - c_last)              # ≤ |r|
    k_intra = k * jnp.exp(c_last - cum)                 # ≤ |k|

    S = s_ref[...]                                      # [Dk, Dv]
    o = jax.lax.dot_general(q_inter, S, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [L, Dv]

    A = jax.lax.dot_general(q_intra, k_intra, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [L, L]
    ii = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    A = jnp.where(jj < ii, A, 0.0)                      # strict lower triangle
    bonus = jnp.sum(r * u * k, axis=-1)                 # [L] diagonal term
    o = o + jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    o = o + bonus[:, None] * v
    o_ref[0] = o.astype(o_ref.dtype)

    # state update: S_L = diag(e^{c_L}) S_0 + Σ_j (k_j e^{c_L - c_j}) v_j^T
    S_new = jnp.exp(c_last).T * S + jax.lax.dot_general(
        k_intra, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    s_ref[...] = S_new

    @pl.when(c == nc - 1)
    def _fin():
        sT_ref[0] = S_new.astype(sT_ref.dtype)


def gla_scan_kernel(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    w: jnp.ndarray, u: jnp.ndarray, *, chunk: int = 64,
                    interpret: bool = False
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """r,k,w: [B,T,Dk]; v: [B,T,Dv]; u: [B,Dk] -> (o [B,T,Dv], S [B,Dk,Dv]).

    B is typically batch×heads. T % chunk == 0 (pad upstream).
    """
    B, T, Dk = r.shape
    Dv = v.shape[-1]
    chunk = min(chunk, T)
    assert T % chunk == 0, (T, chunk)
    nc = T // chunk
    out = pl.pallas_call(
        functools.partial(_gla_kernel, chunk=chunk),
        grid=(B, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, Dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, Dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, Dv), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, Dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, Dk), lambda b, c: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, Dv), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, Dk, Dv), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, Dv), v.dtype),
            jax.ShapeDtypeStruct((B, Dk, Dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((Dk, Dv), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
    return out[0], out[1]
