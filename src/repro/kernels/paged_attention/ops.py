"""Jit'd wrapper for paged decode attention (kernel / xla fallback)."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from .kernel import paged_attention_kernel
from .ref import paged_attention_ref


def paged_attention(q: jnp.ndarray, kv_pages: jnp.ndarray,
                    block_tables: jnp.ndarray, lengths: jnp.ndarray, *,
                    scale: Optional[float] = None, impl: str = "xla",
                    interpret: bool = True) -> jnp.ndarray:
    """Decode attention over a paged KV pool.

    impl: "kernel" (Pallas, interpret on CPU) or "xla" (gather-based; lowers
    everywhere — used by the decode dry-run).
    """
    if impl == "kernel":
        return paged_attention_kernel(q, kv_pages, block_tables, lengths,
                                      scale=scale, interpret=interpret)
    if impl == "xla":
        return paged_attention_ref(q, kv_pages, block_tables, lengths,
                                   scale=scale)
    raise ValueError(f"unknown impl {impl!r}")
