"""Paged decode-attention Pallas TPU kernel.

The device half of the Pangea KV buffer pool: attention reads KV directly
from the page pool via a scalar-prefetched block table — no gather/copy into a
contiguous buffer (the monolithic no-redundant-copies principle applied to
HBM). Grid ``(B, max_pages)``, pages sequential with online-softmax scratch
carried across page steps; the block table is prefetched to SMEM so each
page's DMA address is known before the step runs.

TARGET: TPU (VMEM block = one KV page). Validated with interpret=True on CPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(bt_ref, len_ref, q_ref, kv_ref, o_ref, acc_ref, m_ref,
                  l_ref, *, page_size: int, scale: float, kv_heads: int,
                  group: int):
    b = pl.program_id(0)
    p = pl.program_id(1)
    np_ = pl.num_programs(1)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]
    live = p * page_size < length

    @pl.when(live)
    def _compute():
        H = kv_heads * group
        q = q_ref[0].astype(jnp.float32)                  # [H, D]
        D = q.shape[-1]
        qg = q.reshape(kv_heads, group, D)
        k = kv_ref[0, :, 0].astype(jnp.float32)           # [page, KH, D]
        v = kv_ref[0, :, 1].astype(jnp.float32)
        kt = jnp.swapaxes(k, 0, 1)                        # [KH, page, D]
        vt = jnp.swapaxes(v, 0, 1)
        # s[kh, g, t] — batched over kv head
        s = jax.lax.dot_general(
            qg, kt, (((2,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.float32) * scale   # [KH, G, page]
        pos = p * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (kv_heads, group, page_size), 2)
        s = jnp.where(pos < length, s, NEG_INF)

        m_prev = m_ref[...].reshape(kv_heads, group, 1)
        l_prev = l_ref[...].reshape(kv_heads, group, 1)
        acc_prev = acc_ref[...].reshape(kv_heads, group, D)
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        pexp = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + pexp.sum(-1, keepdims=True)
        acc_new = acc_prev * corr + jax.lax.dot_general(
            pexp, vt, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)           # [KH, G, D]
        m_ref[...] = m_new.reshape(H, 1)
        l_ref[...] = l_new.reshape(H, 1)
        acc_ref[...] = acc_new.reshape(H, D)

    @pl.when(p == np_ - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_attention_kernel(q: jnp.ndarray, kv_pages: jnp.ndarray,
                           block_tables: jnp.ndarray, lengths: jnp.ndarray, *,
                           scale: Optional[float] = None,
                           interpret: bool = False) -> jnp.ndarray:
    """q: [B, H, D]; kv_pages: [P, page, 2, KH, D];
    block_tables: [B, max_pages]; lengths: [B]. Returns [B, H, D]."""
    B, H, D = q.shape
    P, page, _, KH, _ = kv_pages.shape
    max_pages = block_tables.shape[1]
    group = H // KH
    if scale is None:
        scale = D ** -0.5

    kernel = functools.partial(_paged_kernel, page_size=page, scale=scale,
                               kv_heads=KH, group=group)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, max_pages),
        in_specs=[
            pl.BlockSpec((1, H, D), lambda b, p, bt, ln: (b, 0, 0)),
            pl.BlockSpec((1, page, 2, KH, D),
                         lambda b, p, bt, ln: (jnp.maximum(bt[b, p], 0),
                                               0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, D), lambda b, p, bt, ln: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((H, D), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32), q, kv_pages)
