"""Pure-jnp oracle for paged decode attention."""
from __future__ import annotations

import jax.numpy as jnp


def paged_attention_ref(q: jnp.ndarray, kv_pages: jnp.ndarray,
                        block_tables: jnp.ndarray, lengths: jnp.ndarray,
                        scale=None) -> jnp.ndarray:
    """q: [B, H, D]; kv_pages: [P, page, 2, KH, D];
    block_tables: [B, max_pages] int32 (physical page ids, -1 absent);
    lengths: [B] int32. Returns [B, H, D]."""
    B, H, D = q.shape
    P, page, _, KH, _ = kv_pages.shape
    max_pages = block_tables.shape[1]
    group = H // KH
    if scale is None:
        scale = D ** -0.5
    # gather each sequence's pages -> [B, max_pages, page, 2, KH, D]
    safe = jnp.maximum(block_tables, 0)
    gathered = kv_pages[safe]
    k = gathered[..., 0, :, :].reshape(B, max_pages * page, KH, D)
    v = gathered[..., 1, :, :].reshape(B, max_pages * page, KH, D)
    kk = jnp.repeat(k, group, axis=2)   # [B, T, H, D]
    vv = jnp.repeat(v, group, axis=2)
    s = jnp.einsum("bhd,bthd->bht", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    pos = jnp.arange(max_pages * page)[None, :]
    mask = pos < lengths[:, None]
    s = jnp.where(mask[:, None, :], s, -1e30)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    o = jnp.einsum("bht,bthd->bhd", p, vv.astype(jnp.float32))
    return (o / jnp.maximum(p.sum(-1, keepdims=True), 1e-20)).astype(q.dtype)
