"""Pure-jnp oracle for flash attention (causal / sliding-window / GQA).

GQA is handled by grouping query heads per kv head (einsum batch dim) rather
than ``jnp.repeat``-ing k/v — identical math, but no materialized repeat, so
under SPMD the kv tensors keep their sharding (repeat's reshape+broadcast
forces a full rematerialization of sequence-sharded KV caches; found on the
grok decode cell — see EXPERIMENTS.md §Perf)."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True, window: Optional[int] = None,
                  scale: Optional[float] = None,
                  q_offset: int = 0) -> jnp.ndarray:
    """Naive softmax attention.

    q: [B, H, Tq, D]; k, v: [B, KH, Tk, D] with H % KH == 0 (GQA).
    ``window``: sliding-window size (keys within ``window`` positions before
    the query, inclusive). ``q_offset``: global position of q[..., 0, :]
    relative to k (decode: Tk - Tq).
    """
    B, H, Tq, D = q.shape
    KH, Tk = k.shape[1], k.shape[2]
    G = H // KH
    if scale is None:
        scale = D ** -0.5
    qg = q.reshape(B, KH, G, Tq, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgqd,bktd->bkgqt", qg, kf) * scale
    q_pos = jnp.arange(Tq)[:, None] + q_offset
    k_pos = jnp.arange(Tk)[None, :]
    mask = jnp.ones((Tq, Tk), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jnp.nan_to_num(jnp.exp(s - s.max(axis=-1, keepdims=True)))
    o = jnp.einsum("bkgqt,bktd->bkgqd", p, vf)
    denom = p.sum(axis=-1, keepdims=True)
    o = o / jnp.maximum(denom, 1e-20)
    Dv = v.shape[-1]
    return o.reshape(B, H, Tq, Dv).astype(q.dtype)
