"""Jit'd public wrapper for flash attention.

Dispatches between the Pallas TPU kernel (on TPU, or interpret=True for CPU
validation) and a chunked pure-XLA path (``lax.scan`` over kv blocks with
online softmax — same O(T) memory; used for dry-run lowering on CPU and as a
portable fallback). Handles padding to block multiples and GQA.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import flash_attention_kernel
from .ref import attention_ref


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    scale: Optional[float] = None, q_offset: int = 0,
                    impl: str = "xla", block_q: int = 128,
                    block_k: int = 128, interpret: bool = True,
                    p_bf16: bool = False) -> jnp.ndarray:
    """q [B,H,Tq,D], k/v [B,KH,Tk,D] -> [B,H,Tq,D].

    impl: "kernel" (Pallas; interpret=True on CPU), "xla" (chunked scan,
    lowers everywhere), "naive" (reference; O(T^2) memory — tests only).
    ``p_bf16``: cast softmax weights to bf16 for the PV matmul (halves the
    p-matrix HBM traffic; standard flash-kernel practice).
    """
    if impl == "naive":
        return attention_ref(q, k, v, causal=causal, window=window,
                             scale=scale, q_offset=q_offset)
    if impl == "kernel":
        Tq, Tk = q.shape[2], k.shape[2]
        bq, bk = min(block_q, Tq), min(block_k, Tk)
        qp = _pad_to(q, 2, bq)
        kp = _pad_to(k, 2, bk)
        vp = _pad_to(v, 2, bk)
        out = flash_attention_kernel(
            qp, kp, vp, causal=causal, window=window, scale=scale,
            q_offset=q_offset, kv_len=Tk, block_q=bq, block_k=bk,
            interpret=interpret)
        return out[:, :, :Tq]
    if impl == "xla":
        return _chunked_attention(q, k, v, causal, window, scale, q_offset,
                                  block_k, p_bf16)
    raise ValueError(f"unknown impl {impl!r}")


def _chunk_mask(Tq, Tk, bk, ki, q_offset, causal, window):
    q_pos = q_offset + jnp.arange(Tq)
    k_pos = ki * bk + jnp.arange(bk)
    mask = (k_pos < Tk)[None, :]
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    return mask  # [Tq, bk]


def _attn_fwd_core(q, k, v, causal, window, scale, q_offset, block_k,
                   p_bf16=False):
    B, H, Tq, D = q.shape
    _, KH, Tk, _ = k.shape
    Dv = v.shape[-1]
    group = H // KH
    bk = min(block_k, Tk)
    kp = _pad_to(k, 2, bk)
    vp = _pad_to(v, 2, bk)
    nk = kp.shape[2] // bk
    qf = q.astype(jnp.float32)

    ks = jnp.moveaxis(kp.reshape(B, KH, nk, bk, D), 2, 0)
    vs = jnp.moveaxis(vp.reshape(B, KH, nk, bk, Dv), 2, 0)

    def step(carry, inputs):
        m, l, acc = carry
        ki, kb, vb = inputs
        kb = jnp.repeat(kb.astype(jnp.float32), group, axis=1)
        vb = jnp.repeat(vb.astype(jnp.float32), group, axis=1)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kb) * scale
        mask = _chunk_mask(Tq, Tk, bk, ki, q_offset, causal, window)
        s = jnp.where(mask[None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(-1, keepdims=True)
        if p_bf16:
            p = p.astype(jnp.bfloat16)
        acc = acc * corr + jnp.einsum("bhqk,bhkd->bhqd", p, vb,
                                      preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((B, H, Tq, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((B, H, Tq, 1), jnp.float32)
    acc0 = jnp.zeros((B, H, Tq, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0),
                                  (jnp.arange(nk), ks, vs))
    l = jnp.maximum(l, 1e-20)
    out = (acc / l).astype(q.dtype)
    return out, m, l


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _chunked_attention(q, k, v, causal, window, scale, q_offset,
                       block_k=512, p_bf16=False):
    """Online-softmax attention as a lax.scan over kv chunks with a
    flash-style custom VJP: the backward pass recomputes per-chunk scores
    instead of saving them, so training memory stays O(Tq·D) rather than
    O(Tq·Tk) (the standard flash-attention backward, in pure XLA). Compiles
    to a compact HLO loop (used for the 32k prefill dry-run). Supports
    Dv != Dk (MLA)."""
    if scale is None:
        scale = q.shape[-1] ** -0.5
    out, _, _ = _attn_fwd_core(q, k, v, causal, window, scale, q_offset,
                               block_k, p_bf16)
    return out


def _chunked_attention_fwd(q, k, v, causal, window, scale, q_offset,
                           block_k=512, p_bf16=False):
    if scale is None:
        scale = q.shape[-1] ** -0.5
    out, m, l = _attn_fwd_core(q, k, v, causal, window, scale, q_offset,
                               block_k, p_bf16)
    return out, (q, k, v, out, m, l)


def _chunked_attention_bwd(causal, window, scale, q_offset, block_k, p_bf16,
                           res, dout):
    q, k, v, out, m, l = res
    B, H, Tq, D = q.shape
    _, KH, Tk, _ = k.shape
    Dv = v.shape[-1]
    group = H // KH
    if scale is None:
        scale = D ** -0.5
    bk = min(block_k, Tk)
    kp = _pad_to(k, 2, bk)
    vp = _pad_to(v, 2, bk)
    nk = kp.shape[2] // bk
    qf = q.astype(jnp.float32)
    do = dout.astype(jnp.float32)
    # delta_i = sum_d dout_i * out_i  (flash-attn bwd identity)
    delta = jnp.sum(do * out.astype(jnp.float32), axis=-1, keepdims=True)

    ks = jnp.moveaxis(kp.reshape(B, KH, nk, bk, D), 2, 0)
    vs = jnp.moveaxis(vp.reshape(B, KH, nk, bk, Dv), 2, 0)

    def step(dq, inputs):
        ki, kb, vb = inputs
        kbr = jnp.repeat(kb.astype(jnp.float32), group, axis=1)
        vbr = jnp.repeat(vb.astype(jnp.float32), group, axis=1)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kbr) * scale
        mask = _chunk_mask(Tq, Tk, bk, ki, q_offset, causal, window)
        s = jnp.where(mask[None, None], s, -1e30)
        p = jnp.exp(s - m) / l                        # true softmax weights
        if p_bf16:
            p = p.astype(jnp.bfloat16)
        dv_c = jnp.einsum("bhqk,bhqd->bhkd", p, do,
                          preferred_element_type=jnp.float32)  # [B,H,bk,Dv]
        dp = jnp.einsum("bhqd,bhkd->bhqk", do, vbr)
        ds = p * (dp - delta) * scale
        dq = dq + jnp.einsum("bhqk,bhkd->bhqd", ds, kbr)
        dk_c = jnp.einsum("bhqk,bhqd->bhkd", ds, qf)
        # fold GQA groups back into kv heads
        dk_c = dk_c.reshape(B, KH, group, bk, D).sum(axis=2)
        dv_c = dv_c.reshape(B, KH, group, bk, Dv).sum(axis=2)
        return dq, (dk_c, dv_c)

    dq0 = jnp.zeros((B, H, Tq, D), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(step, dq0, (jnp.arange(nk), ks, vs))
    dk = jnp.moveaxis(dks, 0, 2).reshape(B, KH, nk * bk, D)[:, :, :Tk]
    dv = jnp.moveaxis(dvs, 0, 2).reshape(B, KH, nk * bk, Dv)[:, :, :Tk]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_chunked_attention.defvjp(_chunked_attention_fwd, _chunked_attention_bwd)
