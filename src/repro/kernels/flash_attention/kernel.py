"""Flash attention Pallas TPU kernel (online softmax, tiled for VMEM/MXU).

Grid: ``(B, H, num_q_blocks, num_kv_blocks)`` with the kv dimension innermost
and sequential ("arbitrary"); accumulators (running max / sum / output) are
VMEM scratch persisted across kv steps. Causal and sliding-window blocks that
are fully masked are skipped via ``pl.when`` (structural win: the compiler
drops their DMAs). Block shapes default to 128×128 (MXU-aligned); head_dim is
the lane dimension and should be a multiple of 128 for peak MXU utilization —
smaller head dims still work (padded lanes).

TARGET: TPU. On this CPU container the kernel is validated with
``interpret=True`` (see ops.py / tests).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: Optional[int],
                  block_q: int, block_k: int, kv_len: int, q_offset: int):
    q_idx = pl.program_id(2)
    k_idx = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(k_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # global positions of this block's rows/cols
    q_start = q_idx * block_q + q_offset
    k_start = k_idx * block_k

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # [bq, d]
        k = k_ref[0, 0].astype(jnp.float32)            # [bk, d]
        v = v_ref[0, 0].astype(jnp.float32)            # [bk, d]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = k_pos < kv_len                          # padding
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                            # [bq, 1]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)                         # [bq, bk]
        corr = jnp.exp(m_prev - m_new)                 # [bq, 1]
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal or window is not None:
        # structural block skipping
        live = jnp.array(True)
        if causal:
            live &= k_start <= q_start + block_q - 1
        if window is not None:
            live &= k_start + block_k - 1 > q_start - window
        pl.when(live)(_compute)
    else:
        _compute()

    @pl.when(k_idx == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0, 0, ...] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_kernel(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                           causal: bool = True, window: Optional[int] = None,
                           scale: Optional[float] = None, q_offset: int = 0,
                           kv_len: Optional[int] = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False) -> jnp.ndarray:
    """q: [B, H, Tq, D] (Tq % block_q == 0); k,v: [B, KH, Tk, D]
    (Tk % block_k == 0). ``kv_len``: true (unpadded) key count."""
    B, H, Tq, D = q.shape
    _, KH, Tk, _ = k.shape
    assert H % KH == 0, (H, KH)
    group = H // KH
    if scale is None:
        scale = D ** -0.5
    if kv_len is None:
        kv_len = Tk
    nq = Tq // block_q
    nk = Tk // block_k

    grid = (B, H, nq, nk)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, kv_len=kv_len, q_offset=q_offset)

    kwargs = {}
    params = _tpu_params()
    if params is not None and not interpret:
        kwargs["compiler_params"] = params
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Tq, D), q.dtype),
        scratch_shapes=[
            _vmem((block_q, D)),
            _vmem((block_q, 1)),
            _vmem((block_q, 1)),
        ],
        interpret=interpret,
        **kwargs,
    )(q, k, v)
    return out


def _vmem(shape):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)


def _tpu_params():
    try:
        from jax.experimental.pallas import tpu as pltpu
        return pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"))
    except Exception:
        return None
