"""Pure-jnp oracle for MoE shuffle dispatch/combine (dense one-hot einsum)."""
from __future__ import annotations

import jax.numpy as jnp


def _mask(expert_id: jnp.ndarray, slot: jnp.ndarray, num_experts: int,
          capacity: int) -> jnp.ndarray:
    """[T, K] assignments -> dense dispatch mask [T, E, C]. expert_id < 0
    (dropped token) contributes nothing."""
    eo = expert_id[..., None] == jnp.arange(num_experts)[None, None, :]
    so = slot[..., None] == jnp.arange(capacity)[None, None, :]
    valid = (expert_id >= 0) & (slot >= 0) & (slot < capacity)
    m = eo[:, :, :, None] & so[:, :, None, :] & valid[:, :, None, None]
    return m.astype(jnp.float32).sum(axis=1)  # [T, E, C]


def dispatch_ref(x: jnp.ndarray, expert_id: jnp.ndarray, slot: jnp.ndarray,
                 num_experts: int, capacity: int) -> jnp.ndarray:
    """x: [T, D] -> expert buffers [E, C, D]."""
    m = _mask(expert_id, slot, num_experts, capacity)
    return jnp.einsum("tec,td->ecd", m, x.astype(jnp.float32)).astype(x.dtype)


def combine_ref(y: jnp.ndarray, expert_id: jnp.ndarray, slot: jnp.ndarray,
                gates: jnp.ndarray) -> jnp.ndarray:
    """y: [E, C, D] expert outputs -> [T, D] gated combine."""
    E, C, D = y.shape
    eo = expert_id[..., None] == jnp.arange(E)[None, None, :]
    so = slot[..., None] == jnp.arange(C)[None, None, :]
    valid = (expert_id >= 0) & (slot >= 0) & (slot < C)
    mg = (eo[:, :, :, None] * so[:, :, None, :]
          * valid[:, :, None, None]).astype(jnp.float32)
    mg = (mg * gates[:, :, None, None]).sum(axis=1)  # [T, E, C]
    return jnp.einsum("tec,ecd->td", mg, y.astype(jnp.float32)).astype(y.dtype)
