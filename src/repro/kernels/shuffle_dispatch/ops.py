"""Jit'd wrappers + slot assignment for MoE shuffle dispatch/combine."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import combine_kernel, dispatch_kernel
from .ref import combine_ref, dispatch_ref

# Fused hash-partition + incremental-CRC host pass (PR 7): the numpy-only
# implementation lives with the columnar page code so the cluster runtime can
# fall back to it when this package's jax import is unavailable; re-exported
# here so kernels/ stays the single import point for dispatch math.
from ...core.columnar import fused_partition_crc as host_partition_crc  # noqa: F401,E402


def host_dispatch_plan(partition_ids: np.ndarray, num_partitions: int
                       ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Host-side slot assignment for node-to-node shuffle transfers — the CPU
    analogue of :func:`compute_slots`: one stable pass groups a batch by
    destination partition. Returns ``(order, counts, offsets)`` such that
    ``batch[order][offsets[p]:offsets[p+1]]`` is partition ``p``'s contiguous
    slice (the runtime ``Cluster`` shuffle routes map output with this)."""
    partition_ids = np.asarray(partition_ids)
    order = np.argsort(partition_ids, kind="stable")
    counts = np.bincount(partition_ids, minlength=num_partitions)
    offsets = np.empty(len(counts) + 1, np.int64)
    offsets[0] = 0
    np.cumsum(counts, out=offsets[1:])
    return order, counts, offsets


def compute_slots(expert_id: jnp.ndarray, num_experts: int,
                  capacity: int) -> jnp.ndarray:
    """Position of each (token, k) within its expert's capacity buffer.

    Tokens beyond capacity get slot >= capacity (dropped downstream) — the
    'virtual shuffle buffer is full' case. expert_id: [T, K] -> slots [T, K].
    """
    T, K = expert_id.shape
    flat = expert_id.reshape(-1)                             # priority order
    onehot = (flat[:, None] == jnp.arange(num_experts)[None, :]).astype(jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot                # exclusive count
    slot = jnp.take_along_axis(
        pos, jnp.clip(flat, 0, num_experts - 1)[:, None], axis=1)[:, 0]
    slot = jnp.where(flat >= 0, slot, -1)
    return slot.reshape(T, K)


def dispatch(x: jnp.ndarray, expert_id: jnp.ndarray, slot: jnp.ndarray,
             num_experts: int, capacity: int, *, impl: str = "xla",
             interpret: bool = True) -> jnp.ndarray:
    if impl == "kernel":
        return dispatch_kernel(x, expert_id, slot, num_experts, capacity,
                               interpret=interpret)
    if impl == "xla":
        return dispatch_ref(x, expert_id, slot, num_experts, capacity)
    raise ValueError(impl)


def combine(y: jnp.ndarray, expert_id: jnp.ndarray, slot: jnp.ndarray,
            gates: jnp.ndarray, num_tokens: int, *, impl: str = "xla",
            interpret: bool = True) -> jnp.ndarray:
    if impl == "kernel":
        return combine_kernel(y, expert_id, slot, gates, num_tokens,
                              interpret=interpret)
    if impl == "xla":
        return combine_ref(y, expert_id, slot, gates)
    raise ValueError(impl)
