"""MoE shuffle-dispatch Pallas TPU kernel — the device half of Pangea's
shuffle service (paper §8).

Hardware adaptation: a GPU implementation scatters tokens with atomics; the
TPU-native formulation builds block-local one-hot masks in VMEM and uses MXU
matmuls (``maskᵀ @ tokens``) to materialize per-expert buffers — scatter
becomes a matmul, which is exactly how the MXU wants it. Grid is
``(experts, token_blocks)``, token blocks sequential, accumulating into a
VMEM scratch buffer; one expert's buffer [C, D] is written per grid row.

The combine kernel is the transpose: grid ``(token_blocks, experts)``,
accumulating gated gathers as ``mask @ expert_out``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _dispatch_kernel(eid_ref, slot_ref, x_ref, o_ref, acc_ref, *,
                     capacity: int, block_t: int, topk: int):
    e = pl.program_id(0)
    t = pl.program_id(1)
    nt = pl.num_programs(1)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)                       # [bt, D]
    slots = jax.lax.broadcasted_iota(jnp.int32, (block_t, capacity), 1)
    mask = jnp.zeros((block_t, capacity), jnp.float32)
    for kk in range(topk):                                   # small, unrolled
        eid = eid_ref[:, kk]                                 # [bt]
        sl = slot_ref[:, kk]
        hit = (eid == e) & (sl >= 0) & (sl < capacity)
        mask += jnp.where(hit[:, None] & (slots == sl[:, None]), 1.0, 0.0)
    acc_ref[...] += jax.lax.dot_general(
        mask, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                  # [C, D]

    @pl.when(t == nt - 1)
    def _fin():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def dispatch_kernel(x: jnp.ndarray, expert_id: jnp.ndarray,
                    slot: jnp.ndarray, num_experts: int, capacity: int, *,
                    block_t: int = 256, interpret: bool = False) -> jnp.ndarray:
    """x: [T, D]; expert_id/slot: [T, K] -> [E, C, D]."""
    T, D = x.shape
    K = expert_id.shape[1]
    block_t = min(block_t, T)
    assert T % block_t == 0, (T, block_t)
    nt = T // block_t
    return pl.pallas_call(
        functools.partial(_dispatch_kernel, capacity=capacity,
                          block_t=block_t, topk=K),
        grid=(num_experts, nt),
        in_specs=[
            pl.BlockSpec((block_t, K), lambda e, t: (t, 0)),
            pl.BlockSpec((block_t, K), lambda e, t: (t, 0)),
            pl.BlockSpec((block_t, D), lambda e, t: (t, 0)),
        ],
        out_specs=pl.BlockSpec((1, capacity, D), lambda e, t: (e, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_experts, capacity, D), x.dtype),
        scratch_shapes=[pltpu.VMEM((capacity, D), jnp.float32)],
        interpret=interpret,
    )(expert_id.astype(jnp.int32), slot.astype(jnp.int32), x)


def _combine_kernel(eid_ref, slot_ref, gate_ref, y_ref, o_ref, acc_ref, *,
                    capacity: int, block_t: int, topk: int):
    t = pl.program_id(0)
    e = pl.program_id(1)
    ne = pl.num_programs(1)

    @pl.when(e == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    y = y_ref[0].astype(jnp.float32)                         # [C, D]
    slots = jax.lax.broadcasted_iota(jnp.int32, (block_t, capacity), 1)
    mask = jnp.zeros((block_t, capacity), jnp.float32)
    for kk in range(topk):
        eid = eid_ref[:, kk]
        sl = slot_ref[:, kk]
        g = gate_ref[:, kk].astype(jnp.float32)
        hit = (eid == e) & (sl >= 0) & (sl < capacity)
        mask += jnp.where(hit[:, None] & (slots == sl[:, None]),
                          g[:, None], 0.0)
    acc_ref[...] += jax.lax.dot_general(
        mask, y, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                  # [bt, D]

    @pl.when(e == ne - 1)
    def _fin():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def combine_kernel(y: jnp.ndarray, expert_id: jnp.ndarray, slot: jnp.ndarray,
                   gates: jnp.ndarray, num_tokens: int, *, block_t: int = 256,
                   interpret: bool = False) -> jnp.ndarray:
    """y: [E, C, D]; expert_id/slot/gates: [T, K] -> [T, D]."""
    E, C, D = y.shape
    T, K = expert_id.shape
    block_t = min(block_t, T)
    assert T % block_t == 0
    nt = T // block_t
    return pl.pallas_call(
        functools.partial(_combine_kernel, capacity=C, block_t=block_t,
                          topk=K),
        grid=(nt, E),
        in_specs=[
            pl.BlockSpec((block_t, K), lambda t, e: (t, 0)),
            pl.BlockSpec((block_t, K), lambda t, e: (t, 0)),
            pl.BlockSpec((block_t, K), lambda t, e: (t, 0)),
            pl.BlockSpec((1, C, D), lambda t, e: (e, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_t, D), lambda t, e: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((T, D), y.dtype),
        scratch_shapes=[pltpu.VMEM((block_t, D), jnp.float32)],
        interpret=interpret,
    )(expert_id.astype(jnp.int32), slot.astype(jnp.int32),
      gates, y)
