"""Data pipeline = the sequential read/write service applied to training.

Tokenized shards are stored as locality-set pages in the unified buffer pool
(write-through user data, paper §3.1), optionally with heterogeneously
partitioned replicas (e.g. by length bucket) registered in the statistics
catalog. The loader stages batches through the pool — when the dataset
exceeds the pool budget, the data-aware paging policy (MRU for sequential
scans) decides residency, which is exactly the paper's Fig.-6/7 experiment.

Also hosts the straggler-mitigation hook: per-host shard ownership with
re-dispatch of a slow host's pending pages (runtime/ drives it).
"""
from __future__ import annotations

import threading
import queue
from dataclasses import dataclass
from typing import Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from ..core.attributes import (AttributeSet, DurabilityType, ReadingPattern,
                               WritingPattern)
from ..core.buffer_pool import BufferPool
from ..core.locality_set import LocalitySet
from ..core.replication import (DistributedSet, PartitionScheme,
                                partition_set, random_dispatch,
                                register_replica)
from ..core.services import SequentialWriter, get_page_iterators
from ..core.statistics import ReplicaInfo, StatisticsDB


def user_data_attrs() -> AttributeSet:
    return AttributeSet(durability=DurabilityType.WRITE_THROUGH,
                        writing=WritingPattern.SEQUENTIAL_WRITE,
                        reading=ReadingPattern.SEQUENTIAL_READ)


@dataclass
class TokenDataset:
    """A tokenized dataset persisted as a locality set of sequence records."""

    pool: BufferPool
    ls: LocalitySet
    seq_len: int
    num_sequences: int

    @property
    def dtype(self) -> np.dtype:
        return np.dtype((np.int32, (self.seq_len,)))


def write_token_dataset(pool: BufferPool, name: str, tokens: np.ndarray,
                        page_size: int = 1 << 20) -> TokenDataset:
    """tokens: [N, seq_len] int32 -> write-through locality set."""
    n, seq_len = tokens.shape
    ls = pool.create_set(name, page_size, user_data_attrs())
    dt = np.dtype((np.int32, (seq_len,)))
    w = SequentialWriter(pool, ls, dt)
    w.append_batch(tokens.astype(np.int32))
    w.close()
    return TokenDataset(pool, ls, seq_len, n)


def synthetic_token_dataset(pool: BufferPool, name: str, *, vocab: int,
                            num_sequences: int, seq_len: int,
                            seed: int = 0) -> TokenDataset:
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, vocab, (num_sequences, seq_len), dtype=np.int32)
    return write_token_dataset(pool, name, toks)


class BatchLoader:
    """Sequential-read-service loader with background prefetch.

    Yields {"tokens": [B, T], "labels": [B, T]} numpy batches. The prefetch
    thread pulls pages through the buffer pool (pin → copy → unpin), so cold
    pages come back from the spill store transparently.
    """

    def __init__(self, ds: TokenDataset, batch_size: int,
                 num_workers: int = 1, prefetch: int = 2,
                 drop_last: bool = True, seed: Optional[int] = None):
        self.ds = ds
        self.batch_size = batch_size
        self.num_workers = num_workers
        self.prefetch = prefetch
        self.drop_last = drop_last
        self.seed = seed

    def _record_stream(self) -> Iterator[np.ndarray]:
        its = get_page_iterators(self.ds.pool, self.ds.ls, self.ds.dtype,
                                 self.num_workers)
        for it in its:
            for recs in it:
                yield recs

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        q: "queue.Queue" = queue.Queue(maxsize=self.prefetch)
        stop = object()

        def producer():
            buf: List[np.ndarray] = []
            have = 0
            try:
                for recs in self._record_stream():
                    buf.append(np.asarray(recs))
                    have += len(recs)
                    while have >= self.batch_size:
                        allr = np.concatenate(buf) if len(buf) > 1 else buf[0]
                        batch, rest = (allr[:self.batch_size],
                                       allr[self.batch_size:])
                        buf = [rest] if len(rest) else []
                        have = len(rest)
                        toks = batch
                        q.put({"tokens": toks,
                               "labels": np.concatenate(
                                   [toks[:, 1:],
                                    np.full((len(toks), 1), -100,
                                            np.int32)], axis=1)})
                if buf and not self.drop_last:
                    allr = np.concatenate(buf) if len(buf) > 1 else buf[0]
                    q.put({"tokens": allr,
                           "labels": np.concatenate(
                               [allr[:, 1:],
                                np.full((len(allr), 1), -100, np.int32)],
                               axis=1)})
            finally:
                q.put(stop)

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        while True:
            item = q.get()
            if item is stop:
                break
            yield item


# ---------------------------------------------------------------------------
# Heterogeneous dataset replicas (paper §7 applied to training data)
# ---------------------------------------------------------------------------
def register_dataset_replicas(
        stats: StatisticsDB, name: str, records: np.ndarray,
        num_nodes: int, schemes: Sequence[PartitionScheme]):
    """Partition a dataset under several schemes; register each replica and
    its conflicting-object guards. Training picks the replica co-partitioned
    with its sampling key (e.g. length buckets) via ``stats.best_replica``."""
    source = random_dispatch(name, records, num_nodes)
    stats.register_replica(name, ReplicaInfo(
        set_name=name, partition_key=None, num_partitions=num_nodes,
        num_nodes=num_nodes))
    regs = []
    for scheme in schemes:
        target = partition_set(source, f"{name}_by_{scheme.name}", scheme)
        regs.append(register_replica(source, target, scheme, stats, name))
    return source, regs


# ---------------------------------------------------------------------------
# Cluster-backed pipelines (runtime/cluster.py): the same staging path, but
# records live in N per-node buffer pools instead of one.
# ---------------------------------------------------------------------------
def token_record_dtype(seq_len: int) -> np.dtype:
    """Sequence records routed across the cluster by their id (stable hash
    placement regardless of content)."""
    return np.dtype([("seq_id", np.int64), ("tokens", np.int32, (seq_len,))])


def write_sharded_token_dataset(cluster, name: str, tokens: np.ndarray,
                                page_size: int = 1 << 18,
                                replication_factor: Optional[int] = None):
    """tokens: [N, seq_len] int32 -> a ShardedSet spread over every node's
    pool (with chain replicas when the cluster is configured for them)."""
    n, seq_len = tokens.shape
    recs = np.zeros(n, token_record_dtype(seq_len))
    recs["seq_id"] = np.arange(n)
    recs["tokens"] = tokens.astype(np.int32)
    return cluster.create_sharded_set(
        name, recs, key_fn=lambda r: r["seq_id"], page_size=page_size,
        replication_factor=replication_factor, partition_key="seq_id")


class DistributedBatchLoader:
    """Batch iterator over a sharded token dataset: streams each shard
    through the pool that holds it and yields the same {"tokens", "labels"}
    batches as the single-pool BatchLoader.

    Scheduler-driven since PR 2: the shard read plan comes from the cluster
    scheduler (a dead owner's shard is read from a CRC-verified replica
    holder instead of failing), and up to ``prefetch`` shard reads run ahead
    as transfer-engine jobs, overlapping the consumer the way the
    single-pool ``BatchLoader``'s producer thread does."""

    def __init__(self, cluster, sset, batch_size: int, drop_last: bool = True,
                 prefetch: int = 2):
        self.cluster = cluster
        self.sset = sset
        self.batch_size = batch_size
        self.drop_last = drop_last
        self.prefetch = max(0, prefetch)

    def _read_reserved(self, node_id: int, cancelled: threading.Event):
        # charge the staged shard to the driver's MemoryManager while it sits
        # in the prefetch window, so loader pressure shows up in the same
        # high-water accounting as remesh streaming. ``cancelled`` is set
        # when the consumer abandons the stream: a worker still in flight
        # then skips (or immediately returns) its reservation, so nothing
        # can leak past the drain below.
        shard = self.cluster.read_shard(self.sset, node_id)
        if cancelled.is_set():
            return shard, None
        res = self.cluster.driver_memory.reserve(shard.nbytes)
        if cancelled.is_set():
            res.release()
            return shard, None
        return shard, res

    def _shard_stream(self) -> Iterator[np.ndarray]:
        # read_shard resolves each shard's source through the cluster
        # scheduler (primary, or a CRC-verified replica when the owner is
        # dead), so shard order is all the plan we need here
        order = sorted(self.sset.shards)
        cancelled = threading.Event()
        if self.prefetch == 0:
            for node_id in order:
                shard, res = self._read_reserved(node_id, cancelled)
                try:
                    yield shard
                finally:
                    if res is not None:
                        res.release()
            return
        engine = self.cluster.transfer
        window: List = []
        try:
            for node_id in order:
                window.append(engine.submit(self._read_reserved,
                                            node_id, cancelled,
                                            label=f"prefetch{node_id}"))
                if len(window) >= self.prefetch:
                    shard, res = window.pop(0).result()
                    try:
                        yield shard
                    finally:
                        if res is not None:
                            res.release()
            while window:
                shard, res = window.pop(0).result()
                try:
                    yield shard
                finally:
                    if res is not None:
                        res.release()
        finally:
            # consumer abandoned the iterator mid-stream: stop in-flight
            # workers from reserving, then release what already landed
            cancelled.set()
            for fut in window:
                try:
                    _shard, res = fut.result(timeout=30)
                except Exception:
                    continue
                if res is not None:
                    res.release()

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        buf: List[np.ndarray] = []
        have = 0
        for shard in self._shard_stream():
            if len(shard) == 0:
                continue
            buf.append(shard["tokens"])
            have += len(shard)
            while have >= self.batch_size:
                allr = np.concatenate(buf) if len(buf) > 1 else buf[0]
                batch, rest = (allr[:self.batch_size],
                               allr[self.batch_size:])
                buf = [rest] if len(rest) else []
                have = len(rest)
                yield self._batch(batch)
        if have and not self.drop_last:
            allr = np.concatenate(buf) if len(buf) > 1 else buf[0]
            yield self._batch(allr)

    @staticmethod
    def _batch(toks: np.ndarray) -> Dict[str, np.ndarray]:
        labels = np.concatenate(
            [toks[:, 1:], np.full((len(toks), 1), -100, np.int32)], axis=1)
        return {"tokens": toks, "labels": labels}


def cluster_aggregate(cluster, name: str, records: np.ndarray,
                      key_field: str, val_field: str,
                      num_reducers: Optional[int] = None,
                      page_size: int = 1 << 18,
                      replication_factor: Optional[int] = None,
                      keep_dataset: bool = False,
                      partition_field: Optional[str] = None,
                      force_shuffle: bool = False):
    """The end-to-end hash-aggregation workload (paper §9's Spark
    comparison), driven through the cluster scheduler: stage ``records`` as a
    sharded locality set partitioned on ``partition_field`` (default: the
    aggregation key — the storage layer sees the query, so it stages the
    data co-partitioned and the scheduler elides the shuffle entirely, the
    paper's §9.2.2 result). Pass a different ``partition_field`` or
    ``force_shuffle=True`` to exercise the full shuffle path with
    locality-aware reducer placement. Returns ``(keys, summed_vals)`` sorted
    by key."""
    from ..runtime.cluster import cluster_hash_aggregate
    partition_field = partition_field or key_field
    sset = cluster.create_sharded_set(
        name, records, key_fn=lambda r: r[partition_field],
        page_size=page_size, replication_factor=replication_factor,
        partition_key=partition_field)
    try:
        return cluster_hash_aggregate(cluster, sset, key_field, val_field,
                                      num_reducers=num_reducers,
                                      force_shuffle=force_shuffle)
    finally:
        if not keep_dataset:
            cluster.drop_sharded_set(sset)


def cluster_join(cluster, name: str, build_records: np.ndarray,
                 probe_records: np.ndarray, key_field: str,
                 build_partition_field: Optional[str] = None,
                 probe_partition_field: Optional[str] = None,
                 page_size: int = 1 << 18,
                 replication_factor: Optional[int] = None,
                 keep_datasets: bool = False,
                 num_reducers: Optional[int] = None,
                 step_timer=None):
    """The end-to-end distributed equi-join (paper §9.2.2), driven through
    the cluster scheduler: stage both sides as sharded locality sets, then
    join on ``key_field`` moving only what the scheduler cannot prove is
    already in place.

    Both sides default to partitioning on the join key — the storage layer
    sees the query, stages the data co-partitioned, and the scheduler elides
    the shuffle entirely (``report.net_bytes == 0``, the paper's flagship
    result). Pass a different ``build_partition_field`` /
    ``probe_partition_field`` to stage a side non-co-partitioned: one
    non-co side shuffles *only that side* (routed by the co side's own
    scheme); both non-co shuffles both with byte-weighted, pressure-aware
    reducer placement. Straggler re-execution rides along via
    ``step_timer``, exactly as the aggregation path.

    Returns ``(records, report)``: the canonical-sorted joined records
    (byte-identical to the single-pool ``core.services.join_records``
    reference) and the ``runtime.join.JoinReport``."""
    from ..runtime.join import ClusterJoin

    def _staged(tag: str, records: np.ndarray, partition_field: str):
        return cluster.create_sharded_set(
            f"{name}.{tag}", records,
            key_fn=lambda r, f=partition_field: np.asarray(r[f]).astype(np.int64),
            page_size=page_size, replication_factor=replication_factor,
            partition_key=partition_field)

    build = _staged("build", build_records,
                    build_partition_field or key_field)
    probe = _staged("probe", probe_records,
                    probe_partition_field or key_field)
    try:
        return ClusterJoin(cluster, build, probe, key_field,
                           num_reducers=num_reducers,
                           step_timer=step_timer).execute()
    finally:
        if not keep_datasets:
            cluster.drop_sharded_set(build)
            cluster.drop_sharded_set(probe)
