"""Encoder-decoder LM (seamless-m4t backbone).

The audio frontend is a STUB per the assignment: inputs are precomputed frame
embeddings [B, S, d]. Positions are sinusoidal (added at embed time; the
backbone config uses rope="none"). Decoder layers: causal self-attention +
cross-attention over the encoder memory + FFN.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..sharding import constrain
from . import blocks
from .common import cross_entropy_loss
from .lm import _stack_init

Pytree = Any


def sinusoidal(T: int, d: int, offset=0) -> jnp.ndarray:
    pos = (jnp.arange(T) + offset)[:, None].astype(jnp.float32)
    i = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / jnp.power(10000.0, 2 * i / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


class EncDecLM:
    def __init__(self, cfg: ArchConfig, attn_impl: str = "xla"):
        self.cfg = cfg
        self.attn_impl = attn_impl

    # ------------------------------------------------------------------ init
    def _enc_layer_init(self, key):
        k1, k2 = jax.random.split(key)
        p, a = {}, {}
        p["attn"], a["attn"] = blocks.attn_init(k1, self.cfg)
        p["ffn"], a["ffn"] = blocks.ffn_init(k2, self.cfg)
        return p, a

    def _dec_layer_init(self, key):
        k1, k2, k3 = jax.random.split(key, 3)
        p, a = {}, {}
        p["self"], a["self"] = blocks.attn_init(k1, self.cfg)
        p["cross"], a["cross"] = blocks.attn_init(k2, self.cfg)
        p["ffn"], a["ffn"] = blocks.ffn_init(k3, self.cfg)
        return p, a

    def init_with_axes(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 5)
        params, axes = {}, {}
        emb = jax.random.normal(ks[0], (cfg.vocab, cfg.d_model)) * 0.02
        params["embed"], axes["embed"] = emb, ("vocab", None)
        unemb = (jax.random.normal(ks[1], (cfg.d_model, cfg.vocab))
                 * (1.0 / math.sqrt(cfg.d_model)))
        params["unembed"], axes["unembed"] = unemb, ("embed", "vocab")
        params["enc"], axes["enc"] = _stack_init(
            self._enc_layer_init, ks[2], cfg.n_encoder_layers)
        params["dec"], axes["dec"] = _stack_init(
            self._dec_layer_init, ks[3], cfg.n_layers)
        params["enc_norm"], axes["enc_norm"] = blocks._norm_init(
            cfg, cfg.d_model)
        params["final_norm"], axes["final_norm"] = blocks._norm_init(
            cfg, cfg.d_model)
        return params, axes

    def init(self, key):
        return self.init_with_axes(key)[0]

    def param_axes(self):
        box = {}

        def f():
            p, a = self.init_with_axes(jax.random.PRNGKey(0))
            box["axes"] = a
            return p

        jax.eval_shape(f)
        return box["axes"]

    def _compute_cast(self, params):
        dt = jnp.dtype(self.cfg.compute_dtype)
        return jax.tree.map(
            lambda w: w.astype(dt) if (w.dtype == jnp.float32 and w.ndim >= 2)
            else w, params)

    # ------------------------------------------------------------- encoder
    def encode(self, params, src_embeds) -> jnp.ndarray:
        cfg = self.cfg
        B, S, d = src_embeds.shape
        x = src_embeds.astype(jnp.dtype(cfg.compute_dtype))
        x = x + sinusoidal(S, d).astype(x.dtype)
        x = constrain(x, ("batch", "seq", None))
        positions = jnp.arange(S)

        def body(h, lp):
            h, _ = blocks.attn_apply(lp["attn"], h, cfg=cfg,
                                     positions=positions, causal=False,
                                     attn_impl=self.attn_impl)
            h = blocks.ffn_apply(lp["ffn"], h, cfg=cfg)
            return h, None

        if cfg.remat == "layer":
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["enc"])
        return blocks.apply_norm(cfg, params.get("enc_norm"), x)

    def _cross_kv(self, lp, memory):
        """Per-layer cross-attention k/v from encoder memory, head-major."""
        cfg = self.cfg
        k = jnp.einsum("bsd,dhk->bshk", memory, lp["cross"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", memory, lp["cross"]["wv"])
        return k.swapaxes(1, 2), v.swapaxes(1, 2)

    # ------------------------------------------------------------- decoder
    def _decoder(self, params, tokens, memory, cache=None, pos=0):
        cfg = self.cfg
        B, T = tokens.shape
        x = params["embed"][tokens].astype(jnp.dtype(cfg.compute_dtype))
        x = x + sinusoidal(T, cfg.d_model, offset=pos).astype(x.dtype)
        x = constrain(x, ("batch", "seq", None))
        positions = jnp.arange(T) + pos

        if cache is None:
            def body(h, lp):
                h, _ = blocks.attn_apply(lp["self"], h, cfg=cfg,
                                         positions=positions, causal=True,
                                         attn_impl=self.attn_impl)
                kv = self._cross_kv(lp, memory)
                h, _ = blocks.attn_apply(lp["cross"], h, cfg=cfg,
                                         positions=positions,
                                         kv_memory=kv)
                h = blocks.ffn_apply(lp["ffn"], h, cfg=cfg)
                return h, None
            if cfg.remat == "layer":
                body = jax.checkpoint(body)
            x, _ = jax.lax.scan(body, x, params["dec"])
            new_cache = None
        else:
            def body(h, pc):
                lp, lc = pc
                h, sc = blocks.attn_apply(lp["self"], h, cfg=cfg,
                                          positions=positions,
                                          cache=lc["self"], pos=pos,
                                          attn_impl=self.attn_impl)
                h, _ = blocks.attn_apply(lp["cross"], h, cfg=cfg,
                                         positions=positions,
                                         kv_memory=(lc["cross_k"],
                                                    lc["cross_v"]))
                h = blocks.ffn_apply(lp["ffn"], h, cfg=cfg)
                return h, {"self": sc, "cross_k": lc["cross_k"],
                           "cross_v": lc["cross_v"]}
            x, new_cache = jax.lax.scan(body, x, (params["dec"], cache))

        x = blocks.apply_norm(cfg, params.get("final_norm"), x)
        logits = jnp.einsum("btd,dv->btv", x, params["unembed"])
        return constrain(logits, ("batch", "seq", "vocab")), new_cache

    # ------------------------------------------------------------- public
    def forward(self, params, batch):
        params = self._compute_cast(params)
        memory = self.encode(params, batch["src_embeds"])
        logits, _ = self._decoder(params, batch["tokens"], memory)
        return logits, jnp.zeros((), jnp.float32)

    def loss(self, params, batch):
        logits, _ = self.forward(params, batch)
        return cross_entropy_loss(logits, batch["labels"])

    def decode_cache_init(self, batch: int, max_len: int,
                          memory: Optional[jnp.ndarray] = None,
                          params=None) -> Pytree:
        """Self-attn cache (+ per-layer cross kv if memory given)."""
        cfg = self.cfg
        dt = jnp.dtype(cfg.kv_cache_dtype)
        L = cfg.n_layers
        hd = cfg.resolved_head_dim
        self_c = {"k": jnp.zeros((L, batch, cfg.kv_heads, max_len, hd), dt),
                  "v": jnp.zeros((L, batch, cfg.kv_heads, max_len, hd), dt)}
        if memory is None:
            S = 1
            ck = jnp.zeros((L, batch, cfg.kv_heads, S, hd), dt)
            cv = jnp.zeros((L, batch, cfg.kv_heads, S, hd), dt)
        else:
            params = self._compute_cast(params)

            def kv_body(_, lp):
                return None, self._cross_kv(lp, memory)
            _, (ck, cv) = jax.lax.scan(kv_body, None, params["dec"])
            ck, cv = ck.astype(dt), cv.astype(dt)
        return {"self": self_c, "cross_k": ck, "cross_v": cv}

    def decode_step(self, params, batch, cache, pos):
        params = self._compute_cast(params)
        logits, new_cache = self._decoder(params, batch["tokens"], None,
                                          cache=cache, pos=pos)
        return logits, new_cache
