"""Expert-parallel MoE via shard_map: sort-based dispatch, no dense mask.

The einsum-dispatch MoE (blocks.moe_apply) materializes a [B, T, E, C] mask
and pays ~2·E·C·d FLOPs/token for dispatch+combine — for many-small-expert
models (deepseek: E=64, d_expert=1408) that rivals the expert FLOPs
themselves. This path is the paper's shuffle service done properly on TPU:

* routing (softmax/top-k) stays in plain pjit-land;
* inside ``shard_map`` each "model" shard holds E/n_model experts and every
  shard sees the (data-sharded, model-replicated) tokens, so dispatch is a
  local sort-based GATHER into [E_local, C, d] buffers (argsort by expert +
  static index matrix), expert FFN is a local batched matmul, and combine is
  a gated scatter-add followed by ONE psum over "model" per layer;
* comms per layer = a single [B, T, d] all-reduce (the same bytes the TP
  baseline pays), with zero dispatch-mask FLOPs or traffic.

Limitation: expert weights are sharded over "model" only in this path (no
FSDP dim inside the shard_map region); selected with
``moe_strategy="expert_parallel_shardmap"``.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig
from ..sharding import get_mesh, get_rules
from . import blocks


def moe_shardmap_init(key, cfg: ArchConfig):
    """Same parameter structure as blocks.moe_init but expert weights carry
    only the "experts"->model sharding (shard_map needs whole experts)."""
    p, a = blocks.moe_init(key, cfg)
    for w in ("w1", "w3", "w2"):
        ax = list(a[w])
        a[w] = ("experts",) + (None,) * (len(ax) - 1)
    return p, a


def _dispatch_indices(eid_flat: jnp.ndarray, E: int, C: int
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Flat (token·K) expert assignments -> per-expert index matrix.

    Returns (idx [E, C] into the flat assignment array, valid [E, C]).
    Stable grouping: tokens keep arrival order within an expert.
    """
    N = eid_flat.shape[0]
    order = jnp.argsort(eid_flat * (N + 1) + jnp.arange(N))
    counts = jnp.bincount(jnp.maximum(eid_flat, 0), length=E,
                          minlength=E)
    offsets = jnp.cumsum(counts) - counts              # exclusive
    pos = offsets[:, None] + jnp.arange(C)[None, :]    # [E, C]
    valid = jnp.arange(C)[None, :] < counts[:, None]
    idx = jnp.take(order, jnp.clip(pos, 0, N - 1), axis=0)
    return jnp.where(valid, idx, 0), valid


def moe_shardmap_apply(p, x, *, cfg: ArchConfig, mesh=None):
    """Drop-in replacement for blocks.moe_apply (same (y, aux) contract)."""
    mesh = mesh or get_mesh()
    B, T, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    Ntok = B * T
    # capacity is per dp-shard: inside shard_map each shard sees its local
    # tokens only (sizing from the global count would inflate buffers by
    # the dp degree)
    dp_size = 1
    if mesh is not None:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        dp_size = sizes.get("pod", 1) * sizes.get("data", 1)
    n_loc = max(Ntok // dp_size, 1)
    C = max(4, -(-int(n_loc * K * cfg.capacity_factor / E) // 4) * 4)

    h = blocks.apply_norm(cfg, p.get("norm"), x)
    logits = jnp.einsum("btd,de->bte", h, p["w_router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eid = jax.lax.top_k(probs, K)
    gates = (gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
             ).astype(h.dtype)

    density = jnp.zeros((E,)).at[eid.reshape(-1)].add(1.0) / (Ntok * K)
    aux = ((density * probs.mean(axis=(0, 1))).sum() * E).astype(jnp.float32)

    if mesh is None or "model" not in mesh.axis_names:
        # single-device / no-mesh fallback: local math, no shard_map
        y = _local_moe(p, x, h, eid, gates, cfg, C)
        return y, aux

    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    tok_spec = P(dp if len(dp) > 1 else (dp[0] if dp else None))
    n_model = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    E_loc = E // n_model
    assert E % n_model == 0, (E, n_model)

    def local_fn(hf, eidf, gatesf, w1, w3, w2):
        # hf: [N_loc, d] (model-replicated); w*: [E_loc, ...]
        N_loc = hf.shape[0]
        flat_e = eidf.reshape(-1)                       # [N_loc*K]
        idx, valid = _dispatch_indices(flat_e, E, C)    # over GLOBAL experts
        shard = jax.lax.axis_index("model")
        my_idx = jax.lax.dynamic_slice_in_dim(idx, shard * E_loc, E_loc, 0)
        my_valid = jax.lax.dynamic_slice_in_dim(valid, shard * E_loc,
                                                E_loc, 0)
        tok = my_idx // K                               # flat -> token id
        buf = jnp.take(hf, tok, axis=0)                 # [E_loc, C, d]
        buf = buf * my_valid[..., None].astype(buf.dtype)
        g1 = jnp.einsum("ecd,edf->ecf", buf, w1)
        u1 = jnp.einsum("ecd,edf->ecf", buf, w3)
        out = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g1) * u1, w2)
        gsel = jnp.take(gatesf.reshape(-1), my_idx) * my_valid.astype(
            gatesf.dtype)
        contrib = out * gsel[..., None]
        y = jnp.zeros((N_loc, d), out.dtype).at[tok.reshape(-1)].add(
            contrib.reshape(-1, d))
        return jax.lax.psum(y, "model")                 # sum expert shards

    hf = h.reshape(Ntok, d)
    eidf = eid.reshape(Ntok, K)
    gatesf = gates.reshape(Ntok, K)
    from jax.experimental.shard_map import shard_map
    y = shard_map(
        local_fn, mesh=mesh,
        in_specs=(tok_spec, tok_spec, tok_spec,
                  P("model"), P("model"), P("model")),
        out_specs=tok_spec,
        check_rep=False,
    )(hf, eidf, gatesf, p["w1"], p["w3"], p["w2"])
    y = y.reshape(B, T, d).astype(x.dtype)

    if cfg.n_shared_experts:
        sp = p["shared"]
        g = jnp.einsum("btd,df->btf", h, sp["w1"])
        u = jnp.einsum("btd,df->btf", h, sp["w3"])
        y = y + jnp.einsum("btf,fd->btd", jax.nn.silu(g) * u, sp["w2"])
    return x + y, aux


def _local_moe(p, x, h, eid, gates, cfg: ArchConfig, C: int):
    """No-mesh fallback with identical dispatch semantics (global-flat
    capacity order) — used for correctness tests on one device."""
    B, T, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    hf = h.reshape(-1, d)
    flat_e = eid.reshape(-1)
    idx, valid = _dispatch_indices(flat_e, E, C)
    tok = idx // K
    buf = jnp.take(hf, tok, axis=0) * valid[..., None].astype(hf.dtype)
    g1 = jnp.einsum("ecd,edf->ecf", buf, p["w1"])
    u1 = jnp.einsum("ecd,edf->ecf", buf, p["w3"])
    out = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g1) * u1, p["w2"])
    gsel = jnp.take(gates.reshape(-1), idx) * valid.astype(gates.dtype)
    y = jnp.zeros((B * T, d), out.dtype).at[tok.reshape(-1)].add(
        (out * gsel[..., None]).reshape(-1, d))
    y = y.reshape(B, T, d).astype(x.dtype)
    if cfg.n_shared_experts:
        sp = p["shared"]
        g = jnp.einsum("btd,df->btf", h, sp["w1"])
        u = jnp.einsum("btd,df->btf", h, sp["w3"])
        y = y + jnp.einsum("btf,fd->btd", jax.nn.silu(g) * u, sp["w2"])
    return x + y
