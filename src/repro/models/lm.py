"""Decoder-only LM assembly (dense / MoE / MLA / SSM / hybrid / VLM).

Layers are stacked (vmapped init) and applied with ``lax.scan`` so the lowered
HLO stays compact — a 64-layer 314B model compiles as one scanned body, which
is what lets the 40-cell × 2-mesh dry-run finish on a CPU host.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..sharding import constrain
from . import blocks
from .common import cross_entropy_loss

Pytree = Any

AUX_COEF = 0.01


def _stack_init(fn, key, n: int):
    """vmap a per-layer init over n keys -> stacked params + layer axes."""
    keys = jax.random.split(key, n)
    params = jax.vmap(lambda k: fn(k)[0])(keys)
    # derive axes without materializing a layer (strings via side channel)
    box = {}

    def params_only(k):
        p, a = fn(k)
        box["axes"] = a
        return p

    jax.eval_shape(params_only, key)
    axes = jax.tree.map(lambda a: ("layers", *a) if isinstance(a, tuple)
                        else a, box["axes"],
                        is_leaf=lambda x: isinstance(x, tuple))
    return params, axes


class LM:
    """Config-driven language model. All state is explicit (pure functions)."""

    def __init__(self, cfg: ArchConfig, attn_impl: str = "xla",
                 scan_impl: str = "xla_chunked", mla_absorbed: bool = False):
        self.cfg = cfg
        self.attn_impl = attn_impl
        self.scan_impl = scan_impl
        self.mla_absorbed = mla_absorbed

    # ------------------------------------------------------------------ init
    def _layer_init(self, key):
        cfg = self.cfg
        p, a = {}, {}
        if cfg.family == "ssm":
            p["rwkv"], a["rwkv"] = blocks.rwkv_init(key, cfg)
            return p, a
        k1, k2 = jax.random.split(key)
        if cfg.kv_lora:
            p["attn"], a["attn"] = blocks.mla_init(k1, cfg)
        else:
            p["attn"], a["attn"] = blocks.attn_init(k1, cfg)
        if cfg.n_experts:
            if cfg.moe_strategy == "expert_parallel_shardmap":
                from .moe_shardmap import moe_shardmap_init
                p["moe"], a["moe"] = moe_shardmap_init(k2, cfg)
            else:
                p["moe"], a["moe"] = blocks.moe_init(k2, cfg)
        else:
            p["ffn"], a["ffn"] = blocks.ffn_init(k2, cfg)
        return p, a

    def _superblock_init(self, key):
        """Hybrid (recurrentgemma) superblock: pattern of temporal blocks,
        each followed by an FFN."""
        cfg = self.cfg
        p, a = {}, {}
        ks = jax.random.split(key, 2 * len(cfg.block_pattern))
        for i, kind in enumerate(cfg.block_pattern):
            if kind == "rec":
                p[f"t{i}"], a[f"t{i}"] = blocks.rglru_init(ks[2 * i], cfg)
            else:
                p[f"t{i}"], a[f"t{i}"] = blocks.attn_init(ks[2 * i], cfg)
            p[f"mlp{i}"], a[f"mlp{i}"] = blocks.ffn_init(ks[2 * i + 1], cfg)
        return p, a

    def init_with_axes(self, key) -> Tuple[Pytree, Pytree]:
        cfg = self.cfg
        keys = jax.random.split(key, 8)
        params: Dict[str, Any] = {}
        axes: Dict[str, Any] = {}
        if not cfg.embed_inputs or cfg.vocab:
            emb = jax.random.normal(keys[0], (cfg.vocab, cfg.d_model)) * 0.02
            params["embed"], axes["embed"] = emb, ("vocab", None)
        unemb = (jax.random.normal(keys[1], (cfg.d_model, cfg.vocab))
                 * (1.0 / math.sqrt(cfg.d_model)))
        params["unembed"], axes["unembed"] = unemb, ("embed", "vocab")
        params["final_norm"], axes["final_norm"] = blocks._norm_init(
            cfg, cfg.d_model)

        if cfg.family == "hybrid":
            pat = len(cfg.block_pattern)
            n_super, n_rem = divmod(cfg.n_layers, pat)
            params["layers"], axes["layers"] = _stack_init(
                self._superblock_init, keys[2], n_super)
            rem_p, rem_a = [], []
            for i in range(n_rem):
                rp, ra = {}, {}
                rp["t"], ra["t"] = blocks.rglru_init(
                    jax.random.fold_in(keys[3], i), cfg)
                rp["mlp"], ra["mlp"] = blocks.ffn_init(
                    jax.random.fold_in(keys[4], i), cfg)
                rem_p.append(rp)
                rem_a.append(ra)
            params["rem"], axes["rem"] = rem_p, rem_a
        else:
            params["layers"], axes["layers"] = _stack_init(
                self._layer_init, keys[2], cfg.n_layers)
        return params, axes

    def init(self, key) -> Pytree:
        return self.init_with_axes(key)[0]

    def param_axes(self) -> Pytree:
        box = {}

        def f():
            p, a = self.init_with_axes(jax.random.PRNGKey(0))
            box["axes"] = a
            return p

        jax.eval_shape(f)
        return box["axes"]

    # ------------------------------------------------------------- forward
    def _compute_cast(self, params):
        dt = jnp.dtype(self.cfg.compute_dtype)

        def cast(w):
            if w.dtype == jnp.float32 and w.ndim >= 2:
                return w.astype(dt)
            return w
        return jax.tree.map(cast, params)

    def _layer_apply(self, p, x, positions, cache=None, pos=None):
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        new_cache = {}
        if cfg.family == "ssm":
            x, st = blocks.rwkv_apply(p["rwkv"], x, cfg=cfg,
                                      state=cache, scan_impl=self.scan_impl)
            return x, (st if cache is not None else None), aux
        if cfg.kv_lora:
            x, c = blocks.mla_apply(p["attn"], x, cfg=cfg, positions=positions,
                                    cache=cache, pos=pos,
                                    attn_impl=self.attn_impl,
                                    absorbed=self.mla_absorbed)
        else:
            x, c = blocks.attn_apply(p["attn"], x, cfg=cfg,
                                     positions=positions, cache=cache,
                                     pos=pos, attn_impl=self.attn_impl)
        new_cache = c
        if cfg.n_experts:
            if cfg.moe_strategy == "expert_parallel_shardmap":
                from .moe_shardmap import moe_shardmap_apply
                x, aux = moe_shardmap_apply(p["moe"], x, cfg=cfg)
            else:
                x, aux = blocks.moe_apply(p["moe"], x, cfg=cfg)
        else:
            x = blocks.ffn_apply(p["ffn"], x, cfg=cfg)
        return x, new_cache, aux

    def _superblock_apply(self, p, x, positions, cache=None, pos=None):
        cfg = self.cfg
        new_cache = {}
        for i, kind in enumerate(cfg.block_pattern):
            if kind == "rec":
                x, st = blocks.rglru_apply(
                    p[f"t{i}"], x, cfg=cfg,
                    state=cache[f"t{i}"] if cache is not None else None,
                    scan_impl="xla")
                new_cache[f"t{i}"] = st
            else:
                x, c = blocks.attn_apply(
                    p[f"t{i}"], x, cfg=cfg, positions=positions,
                    cache=cache[f"t{i}"] if cache is not None else None,
                    pos=pos, attn_impl=self.attn_impl)
                new_cache[f"t{i}"] = c
            x = blocks.ffn_apply(p[f"mlp{i}"], x, cfg=cfg, act="gelu")
        return x, (new_cache if cache is not None else None)

    def _embed(self, params, batch):
        cfg = self.cfg
        if cfg.embed_inputs and "embeds" in batch:
            x = batch["embeds"].astype(jnp.dtype(cfg.compute_dtype))
        else:
            x = params["embed"][batch["tokens"]].astype(
                jnp.dtype(cfg.compute_dtype))
        return constrain(x, ("batch", "seq", None))

    def _positions(self, batch, T: int, offset: int = 0):
        if self.cfg.rope == "mrope":
            if "positions" in batch:
                return batch["positions"]
            pos = jnp.arange(T) + offset
            B = batch.get("tokens", batch.get("embeds")).shape[0]
            return jnp.broadcast_to(pos[None, None, :], (B, 3, T))
        return jnp.arange(T) + offset

    def forward(self, params, batch) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Full-sequence forward. Returns (logits, aux_loss)."""
        cfg = self.cfg
        params = self._compute_cast(params)
        x = self._embed(params, batch)
        T = x.shape[1]
        positions = self._positions(batch, T)

        if cfg.family == "hybrid":
            def body(carry, lp):
                h = carry
                h, _ = self._superblock_apply(lp, h, positions)
                return h, None
            if cfg.remat == "layer":
                body = jax.checkpoint(body)
            x, _ = jax.lax.scan(body, x, params["layers"])
            for rp in params["rem"]:
                x, _ = blocks.rglru_apply(rp["t"], x, cfg=cfg, scan_impl="xla")
                x = blocks.ffn_apply(rp["mlp"], x, cfg=cfg, act="gelu")
            aux_total = jnp.zeros((), jnp.float32)
        else:
            def body(carry, lp):
                h, aux = carry
                h, _, a = self._layer_apply(lp, h, positions)
                return (h, aux + a), None
            if cfg.remat == "layer":
                body = jax.checkpoint(body)
            (x, aux_total), _ = jax.lax.scan(
                body, (x, jnp.zeros((), jnp.float32)), params["layers"])

        x = blocks.apply_norm(cfg, params.get("final_norm"), x)
        logits = jnp.einsum("btd,dv->btv", x, params["unembed"])
        logits = constrain(logits, ("batch", "seq", "vocab"))
        return logits, aux_total

    def loss(self, params, batch) -> jnp.ndarray:
        logits, aux = self.forward(params, batch)
        return cross_entropy_loss(logits, batch["labels"]) + AUX_COEF * aux

    # ------------------------------------------------------------- serving
    def decode_cache_init(self, batch: int, max_len: int) -> Pytree:
        cfg = self.cfg
        dt = jnp.dtype(cfg.kv_cache_dtype)
        if cfg.family == "ssm":
            st = blocks.rwkv_state_init(cfg, batch, dt)
            return jax.tree.map(
                lambda z: jnp.broadcast_to(z[None], (cfg.n_layers, *z.shape))
                .copy(), st)
        if cfg.family == "hybrid":
            pat = len(cfg.block_pattern)
            n_super, n_rem = divmod(cfg.n_layers, pat)
            sb = {}
            for i, kind in enumerate(cfg.block_pattern):
                if kind == "rec":
                    sb[f"t{i}"] = blocks.rglru_state_init(cfg, batch, dt)
                else:
                    sb[f"t{i}"] = blocks.attn_cache_init(
                        cfg, batch, min(max_len, cfg.window), dt)
            stacked = jax.tree.map(
                lambda z: jnp.broadcast_to(z[None], (n_super, *z.shape))
                .copy(), sb)
            rem = [blocks.rglru_state_init(cfg, batch, dt)
                   for _ in range(n_rem)]
            return {"super": stacked, "rem": rem}
        if cfg.kv_lora:
            c = blocks.mla_cache_init(cfg, batch, max_len, dt,
                                      absorbed=self.mla_absorbed)
        else:
            c = blocks.attn_cache_init(cfg, batch, max_len, dt)
        return jax.tree.map(
            lambda z: jnp.broadcast_to(z[None], (cfg.n_layers, *z.shape))
            .copy(), c)

    def decode_step(self, params, batch, cache, pos):
        """One-token decode. batch: {"tokens": [B,1]} (or embeds).
        Returns (logits [B,1,V], new_cache)."""
        cfg = self.cfg
        params = self._compute_cast(params)
        x = self._embed(params, batch)
        positions = self._positions(batch, 1, offset=pos)
        if cfg.rope != "mrope" and not isinstance(positions, int):
            positions = jnp.arange(1) + pos

        if cfg.family == "hybrid":
            def body(h, pc):
                lp, lc = pc
                h, nc = self._superblock_apply(lp, h, positions,
                                               cache=lc, pos=pos)
                return h, nc
            x, new_super = jax.lax.scan(body, x,
                                        (params["layers"], cache["super"]))
            new_rem = []
            for rp, rc in zip(params["rem"], cache["rem"]):
                x, st = blocks.rglru_apply(rp["t"], x, cfg=cfg, state=rc,
                                           scan_impl="xla")
                x = blocks.ffn_apply(rp["mlp"], x, cfg=cfg, act="gelu")
                new_rem.append(st)
            new_cache = {"super": new_super, "rem": new_rem}
        else:
            def body(h, pc):
                lp, lc = pc
                h, nc, _ = self._layer_apply(lp, h, positions,
                                             cache=lc, pos=pos)
                return h, nc
            x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))

        x = blocks.apply_norm(cfg, params.get("final_norm"), x)
        logits = jnp.einsum("btd,dv->btv", x, params["unembed"])
        return constrain(logits, ("batch", None, "vocab")), new_cache

    def prefill(self, params, batch, max_len: Optional[int] = None):
        """Prompt processing; returns (logits, decode-ready cache).
        ``max_len`` sizes the kv cache (default: prompt length)."""
        cfg = self.cfg
        params_c = self._compute_cast(params)
        x = self._embed(params_c, batch)
        B, T = x.shape[0], x.shape[1]
        max_len = max_len or T
        dt = jnp.dtype(cfg.kv_cache_dtype)
        positions = self._positions(batch, T)

        if cfg.family == "ssm":
            st0 = jax.tree.map(
                lambda z: jnp.broadcast_to(
                    z[None], (cfg.n_layers, *z.shape)).copy(),
                blocks.rwkv_state_init(cfg, B, dt))

            def body(h, pc):
                lp, lst = pc
                hh, st = blocks.rwkv_apply(lp["rwkv"], h, cfg=cfg, state=lst,
                                           scan_impl=self.scan_impl)
                return hh, st

            x, states = jax.lax.scan(body, x, (params_c["layers"], st0))
            x = blocks.apply_norm(cfg, params_c.get("final_norm"), x)
            logits = jnp.einsum("btd,dv->btv", x, params_c["unembed"])
            return constrain(logits, ("batch", "seq", "vocab")), states

        if cfg.family == "hybrid":
            def body(h, lp):
                hh, caches = self._superblock_prefill(lp, h, positions,
                                                      max_len)
                return hh, caches

            x, super_caches = jax.lax.scan(body, x, params_c["layers"])
            rem = []
            for rp in params_c["rem"]:
                st0 = blocks.rglru_state_init(cfg, B, dt)
                x, st = blocks.rglru_apply(rp["t"], x, cfg=cfg, state=st0,
                                           scan_impl="xla")
                x = blocks.ffn_apply(rp["mlp"], x, cfg=cfg, act="gelu")
                rem.append(st)
            x = blocks.apply_norm(cfg, params_c.get("final_norm"), x)
            logits = jnp.einsum("btd,dv->btv", x, params_c["unembed"])
            return (constrain(logits, ("batch", "seq", "vocab")),
                    {"super": super_caches, "rem": rem})

        # attention families: scan layers, emitting per-layer packed kv
        def body(h, lp):
            if cfg.kv_lora:
                hh, _ = blocks.mla_apply(lp["attn"], h, cfg=cfg,
                                         positions=positions, cache=None,
                                         attn_impl=self.attn_impl)
                c = blocks.mla_prefill_cache(lp["attn"], h, cfg=cfg,
                                             positions=positions,
                                             max_len=max_len, dtype=dt,
                                             absorbed=self.mla_absorbed)
            else:
                kv = blocks.attn_prefill_kv(lp["attn"], h, cfg=cfg,
                                            positions=positions)
                c = blocks.pack_prefill_cache(cfg, kv, max_len, dt)
                hh, _ = blocks.attn_apply(lp["attn"], h, cfg=cfg,
                                          positions=positions,
                                          attn_impl=self.attn_impl)
            if cfg.n_experts:
                hh, _ = blocks.moe_apply(lp["moe"], hh, cfg=cfg)
            else:
                hh = blocks.ffn_apply(lp["ffn"], hh, cfg=cfg)
            return hh, c

        x_out, cache = jax.lax.scan(body, x, params_c["layers"])
        x_out = blocks.apply_norm(cfg, params_c.get("final_norm"), x_out)
        logits = jnp.einsum("btd,dv->btv", x_out, params_c["unembed"])
        return constrain(logits, ("batch", "seq", "vocab")), cache

    def _superblock_prefill(self, p, x, positions, max_len):
        cfg = self.cfg
        dt = jnp.dtype(cfg.kv_cache_dtype)
        caches = {}
        for i, kind in enumerate(cfg.block_pattern):
            if kind == "rec":
                st0 = blocks.rglru_state_init(cfg, x.shape[0], dt)
                x, st = blocks.rglru_apply(p[f"t{i}"], x, cfg=cfg, state=st0,
                                           scan_impl="xla")
                caches[f"t{i}"] = st
            else:
                kv = blocks.attn_prefill_kv(p[f"t{i}"], x, cfg=cfg,
                                            positions=positions)
                caches[f"t{i}"] = blocks.pack_prefill_cache(cfg, kv, max_len,
                                                            dt)
                x, _ = blocks.attn_apply(p[f"t{i}"], x, cfg=cfg,
                                         positions=positions,
                                         attn_impl=self.attn_impl)
            x = blocks.ffn_apply(p[f"mlp{i}"], x, cfg=cfg, act="gelu")
        return x, caches
