"""Model registry + input specs for every (architecture × shape) cell.

``input_specs`` returns ShapeDtypeStructs only (the dry-run contract: weak-
type-correct, shardable, no device allocation).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeConfig
from .encdec import EncDecLM
from .lm import LM

Pytree = Any


def build_model(cfg: ArchConfig, **kw):
    if cfg.family == "encdec":
        kw.pop("scan_impl", None)
        kw.pop("mla_absorbed", None)
        return EncDecLM(cfg, **kw)
    return LM(cfg, **kw)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def train_batch_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, T = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        return {"src_embeds": _sds((B, T, cfg.d_model), cfg.compute_dtype),
                "tokens": _sds((B, T), "int32"),
                "labels": _sds((B, T), "int32")}
    batch: Dict[str, Any] = {"labels": _sds((B, T), "int32")}
    if cfg.embed_inputs:
        batch["embeds"] = _sds((B, T, cfg.d_model), cfg.compute_dtype)
    else:
        batch["tokens"] = _sds((B, T), "int32")
    if cfg.rope == "mrope":
        batch["positions"] = _sds((B, 3, T), "int32")
    return batch


def prefill_batch_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, T = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        # encoder consumes the 32k frames; decoder starts from a short prompt
        return {"src_embeds": _sds((B, T, cfg.d_model), cfg.compute_dtype),
                "tokens": _sds((B, 128), "int32")}
    batch: Dict[str, Any] = {}
    if cfg.embed_inputs:
        batch["embeds"] = _sds((B, T, cfg.d_model), cfg.compute_dtype)
    else:
        batch["tokens"] = _sds((B, T), "int32")
    if cfg.rope == "mrope":
        batch["positions"] = _sds((B, 3, T), "int32")
    return batch


def decode_batch_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B = shape.global_batch
    batch: Dict[str, Any] = {"tokens": _sds((B, 1), "int32")}
    if cfg.rope == "mrope":
        batch["positions"] = _sds((B, 3, 1), "int32")
    return batch


def decode_cache_specs(model, cfg: ArchConfig, shape: ShapeConfig) -> Pytree:
    """Abstract cache for a decode step with a ``seq_len``-token context."""
    B, T = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        fn = lambda: model.decode_cache_init(B, T, memory=None)
        cache = jax.eval_shape(fn)
        # cross kv sized to the encoder memory (= seq_len frames)
        hd = cfg.resolved_head_dim
        L = cfg.n_layers
        cache = dict(cache)
        cache["cross_k"] = _sds((L, B, cfg.kv_heads, T, hd),
                                cfg.kv_cache_dtype)
        cache["cross_v"] = _sds((L, B, cfg.kv_heads, T, hd),
                                cfg.kv_cache_dtype)
        return cache
    return jax.eval_shape(lambda: model.decode_cache_init(B, T))


def input_specs(cfg: ArchConfig, shape: ShapeConfig,
                model=None) -> Dict[str, Any]:
    """All inputs for the step function this shape lowers."""
    if shape.kind == "train":
        return {"batch": train_batch_specs(cfg, shape)}
    if shape.kind == "prefill":
        return {"batch": prefill_batch_specs(cfg, shape)}
    if shape.kind == "decode":
        model = model or build_model(cfg)
        return {"batch": decode_batch_specs(cfg, shape),
                "cache": decode_cache_specs(model, cfg, shape),
                "pos": _sds((), "int32")}
    raise ValueError(shape.kind)


def count_params(cfg: ArchConfig) -> int:
    import math
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    return sum(math.prod(x.shape) for x in jax.tree.leaves(shapes))


def active_params(cfg: ArchConfig) -> int:
    """Active params per token (MoE: shared + top_k routed experts)."""
    total = count_params(cfg)
    if not cfg.n_experts:
        return total
    per_expert = 3 * cfg.d_model * cfg.d_expert
    inactive = (cfg.n_experts - cfg.top_k) * per_expert * cfg.n_layers
    return total - inactive
