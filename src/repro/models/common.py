"""Shared model building blocks: norms, RoPE variants, init helpers, and the
logical-axis annotation scheme used to derive PartitionSpecs.

Params are plain nested dicts of jnp arrays. Every init function returns
``(params, axes)`` where ``axes`` mirrors ``params`` with a tuple of logical
axis names per array dim (or None). ``launch/mesh.py`` maps logical names to
mesh axes (the sharding rules table).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]
Axes = Dict[str, Any]

# Logical axis vocabulary (mapped to mesh axes in launch/mesh.py):
#   "embed"   — d_model dim of weights (FSDP/ZeRO shard axis)
#   "heads"   — attention-head dim (tensor-parallel)
#   "kv"      — kv-head dim (tensor-parallel when divisible)
#   "mlp"     — ffn hidden dim (tensor-parallel)
#   "vocab"   — vocabulary dim (tensor-parallel)
#   "experts" — MoE expert dim (expert-parallel)
#   "layers"  — stacked-layer (scan) dim, never sharded
#   None      — replicated


def dense_init(key, in_dim: int, out_dims, in_axis: Optional[str],
               out_axes, dtype=jnp.float32, scale: Optional[float] = None):
    """He/Glorot-ish init for a [in_dim, *out_dims] weight."""
    if isinstance(out_dims, int):
        out_dims = (out_dims,)
        out_axes = (out_axes,)
    if scale is None:
        scale = 1.0 / math.sqrt(in_dim)
    w = jax.random.normal(key, (in_dim, *out_dims), dtype) * scale
    return w, (in_axis, *out_axes)


def rms_norm(x: jnp.ndarray, weight: Optional[jnp.ndarray],
             eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    if weight is not None:
        x = x * weight.astype(jnp.float32)
    return x.astype(dt)


def layer_norm(x: jnp.ndarray, weight: Optional[jnp.ndarray] = None,
               bias: Optional[jnp.ndarray] = None,
               eps: float = 1e-5) -> jnp.ndarray:
    """Non-parametric when weight/bias are None (OLMo §3: non-parametric LN)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    if weight is not None:
        x = x * weight.astype(jnp.float32)
    if bias is not None:
        x = x + bias.astype(jnp.float32)
    return x.astype(dt)


# ---------------------------------------------------------------------------
# RoPE (standard + M-RoPE)
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10000.0) -> jnp.ndarray:
    """x: [..., T, H, D]; positions: broadcastable to [..., T]."""
    D = x.shape[-1]
    freqs = rope_freqs(D, theta)                       # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., T, D/2]
    cos = jnp.cos(angles)[..., None, :]                # [..., T, 1, D/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions: jnp.ndarray,
                sections: Tuple[int, int, int] = None,
                theta: float = 10000.0) -> jnp.ndarray:
    """Qwen2-VL M-RoPE: positions [..., 3, T] (t/h/w); rope dims split into 3
    sections, each rotated by its own coordinate."""
    D = x.shape[-1]
    if sections is None:
        d6 = D // 2 // 3
        sections = (D // 2 - 2 * d6, d6, d6)
    freqs = rope_freqs(D, theta)                       # [D/2]
    # per-frequency section id; gather that section's coordinate per frequency
    sec = jnp.concatenate([jnp.full((s,), i) for i, s in enumerate(sections)])
    # positions [..., 3, T] -> per-freq positions [..., T, D/2]
    coords = jnp.moveaxis(positions.astype(jnp.float32), -2, 0)  # [3, ..., T]
    per_freq = coords[sec.astype(jnp.int32)]           # [D/2, ..., T]
    per_freq = jnp.moveaxis(per_freq, 0, -1)           # [..., T, D/2]
    angles = per_freq * freqs
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def cross_entropy_loss(logits: jnp.ndarray, labels: jnp.ndarray,
                       ignore_index: int = -100) -> jnp.ndarray:
    """Mean CE over valid positions. logits [..., V] f32-upcast."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(
        lf, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    valid = labels != ignore_index
    nll = (lse - ll) * valid
    return nll.sum() / jnp.maximum(valid.sum(), 1)
