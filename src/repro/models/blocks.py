"""Per-layer blocks for all assigned architecture families.

Every ``*_init`` builds ONE layer's params and returns ``(params, axes)``;
the LM stacks layers by vmapping init over per-layer keys (scan-friendly).
Every ``*_apply`` handles both full-sequence ("train"/"prefill") and
single-token decode (``cache`` + ``pos``) modes.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..kernels.flash_attention.ops import flash_attention
from ..kernels.flash_attention.ref import attention_ref
from ..kernels.linear_scan.ops import diag_scan, gla_scan
from ..sharding import constrain, constrain_seq
from .common import apply_mrope, apply_rope, dense_init, layer_norm, rms_norm

Pytree = Any


def _norm_init(cfg: ArchConfig, d: int):
    if cfg.norm == "nonparam_ln":
        return None, None
    return jnp.ones((d,)), ("embed_vec",)


def apply_norm(cfg: ArchConfig, w, x):
    if cfg.norm == "rmsnorm":
        return rms_norm(x, w)
    if cfg.norm == "layernorm":
        return layer_norm(x, w)
    if cfg.norm == "nonparam_ln":
        return layer_norm(x, None)
    raise ValueError(cfg.norm)


def _split_heads(x, n, hd):
    return x.reshape(*x.shape[:-1], n, hd)


# ---------------------------------------------------------------------------
# GQA attention (dense / qwen3 qk_norm / mrope / sliding window)
# ---------------------------------------------------------------------------
def attn_init(key, cfg: ArchConfig) -> Tuple[Pytree, Pytree]:
    d, H, KH, hd = cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p, a = {}, {}
    p["wq"], a["wq"] = dense_init(ks[0], d, (H, hd), "embed", ("heads", None))
    p["wk"], a["wk"] = dense_init(ks[1], d, (KH, hd), "embed", ("kv", None))
    p["wv"], a["wv"] = dense_init(ks[2], d, (KH, hd), "embed", ("kv", None))
    wo = jax.random.normal(ks[3], (H, hd, d)) * (1.0 / math.sqrt(H * hd))
    p["wo"], a["wo"] = wo, ("heads", None, "embed")
    p["norm"], a["norm"] = _norm_init(cfg, d)
    if cfg.qk_norm:
        p["q_norm"], a["q_norm"] = jnp.ones((hd,)), (None,)
        p["k_norm"], a["k_norm"] = jnp.ones((hd,)), (None,)
    return p, a


def _rope_qk(cfg: ArchConfig, q, k, positions):
    if cfg.rope == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.rope == "mrope":
        q = apply_mrope(q, positions, theta=cfg.rope_theta)
        k = apply_mrope(k, positions, theta=cfg.rope_theta)
    return q, k


def attn_apply(p, x, *, cfg: ArchConfig, positions, causal: bool = True,
               cache: Optional[Dict] = None, pos=None,
               attn_impl: str = "xla",
               kv_memory: Optional[Tuple] = None):
    """x: [B, T, d]. Full mode when cache is None; decode otherwise.

    ``kv_memory``: precomputed (k, v) for cross-attention (enc-dec) — skips
    self kv projection and causal masking.
    """
    B, T, d = x.shape
    H, KH, hd = cfg.n_heads, cfg.kv_heads, cfg.resolved_head_dim
    x = constrain_seq(x)  # seq-parallel residual stream (fsdp_tp_sp only)
    h = apply_norm(cfg, p.get("norm"), x)
    q = jnp.einsum("btd,dhk->bthk", h, p["wq"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])

    if kv_memory is not None:
        # cross attention: kv precomputed, head-major [B, KH, S, hd]
        qh = q.swapaxes(1, 2)
        kh, vh = kv_memory
        o = attention_ref(qh, kh.astype(qh.dtype), vh.astype(qh.dtype),
                          causal=False)
        o = o.swapaxes(1, 2)
        y = jnp.einsum("bthk,hkd->btd", o, p["wo"])
        return x + y, None

    k = jnp.einsum("btd,dhk->bthk", h, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", h, p["wv"])
    if cfg.qk_norm:
        k = rms_norm(k, p["k_norm"])
    q, k = _rope_qk(cfg, q, k, positions)
    qh = q.swapaxes(1, 2)                       # [B, H, T, hd]
    kh = k.swapaxes(1, 2)                       # [B, KH, Tk, hd]
    vh = v.swapaxes(1, 2)
    new_cache = None
    if cache is not None:
        ck, cv = cache["k"], cache["v"]          # [B, KH, Tmax, hd]
        Tmax = ck.shape[2]
        if cfg.window is not None and Tmax == cfg.window:
            o, new_cache = _window_ring_decode(cfg, qh, kh, vh, ck, cv, pos)
        else:
            # decode: write new kv at pos, attend over the whole cache
            ck = jax.lax.dynamic_update_slice(ck, kh.astype(ck.dtype),
                                              (0, 0, pos, 0))
            cv = jax.lax.dynamic_update_slice(cv, vh.astype(cv.dtype),
                                              (0, 0, pos, 0))
            new_cache = {"k": ck, "v": cv}
            o = attention_ref(qh, ck.astype(qh.dtype), cv.astype(qh.dtype),
                              causal=True, window=cfg.window, q_offset=pos)
    else:
        o = flash_attention(qh, kh, vh, causal=causal, window=cfg.window,
                            impl=attn_impl, block_k=cfg.attn_block_k,
                            p_bf16=cfg.attn_p_bf16)
    o = o.swapaxes(1, 2)                        # [B, T, H, hd]
    y = jnp.einsum("bthk,hkd->btd", o, p["wo"])
    return x + y, new_cache


def _window_ring_decode(cfg: ArchConfig, qh, kh, vh, ck, cv, pos):
    """O(window) decode with a ring-buffer KV cache (T=1). Slot ``i`` holds
    absolute position ``pos - ((pos - i) mod W)``. GQA via grouped einsum
    (no repeat — keeps the cache sharding intact under SPMD)."""
    W = cfg.window
    slot = pos % W
    ck = jax.lax.dynamic_update_slice(ck, kh.astype(ck.dtype),
                                      (0, 0, slot, 0))
    cv = jax.lax.dynamic_update_slice(cv, vh.astype(cv.dtype),
                                      (0, 0, slot, 0))
    B, H, Tq, D = qh.shape
    KH = ck.shape[1]
    G = H // KH
    qg = qh.reshape(B, KH, G, Tq, D).astype(jnp.float32)
    scale = D ** -0.5
    s = jnp.einsum("bkgqd,bktd->bkgqt", qg,
                   ck.astype(jnp.float32)) * scale
    idx = jnp.arange(W)
    abs_pos = pos - jnp.mod(pos - idx, W)
    valid = abs_pos >= 0          # (> pos - W and <= pos hold by construction)
    s = jnp.where(valid[None, None, None, None, :], s, -1e30)
    p_ = jnp.exp(s - s.max(-1, keepdims=True))
    o = jnp.einsum("bkgqt,bktd->bkgqd", p_, cv.astype(jnp.float32))
    o = o / jnp.maximum(p_.sum(-1, keepdims=True), 1e-20)
    return o.reshape(B, H, Tq, D).astype(qh.dtype), {"k": ck, "v": cv}


def attn_cache_init(cfg: ArchConfig, batch: int, max_len: int, dtype):
    hd = cfg.resolved_head_dim
    shape = (batch, cfg.kv_heads, max_len, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attn_prefill_kv(p, x, *, cfg: ArchConfig, positions):
    """Compute this layer's k/v for a prompt (to seed the decode cache)."""
    h = apply_norm(cfg, p.get("norm"), x)
    k = jnp.einsum("btd,dhk->bthk", h, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", h, p["wv"])
    if cfg.qk_norm:
        k = rms_norm(k, p["k_norm"])
    if cfg.rope == "rope":
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.rope == "mrope":
        k = apply_mrope(k, positions, theta=cfg.rope_theta)
    return k.swapaxes(1, 2), v.swapaxes(1, 2)


def pack_prefill_cache(cfg: ArchConfig, kv, max_len: int, dtype):
    """Arrange prompt k/v [B, KH, T, hd] into a decode cache.

    Sliding-window archs get a ring buffer of size ``window`` when the prompt
    is at least that long (slot i holds abs position T-1-((T-1-i) mod W));
    otherwise a dense cache of ``min(max_len, window or inf)`` padded slots.
    """
    k, v = kv
    T = k.shape[2]
    W = cfg.window
    cache_len = min(max_len, W) if W else max_len
    if W and cache_len == W and T >= W:
        idx = jnp.arange(W)
        abs_idx = (T - 1) - jnp.mod((T - 1) - idx, W)
        return {"k": k[:, :, abs_idx].astype(dtype),
                "v": v[:, :, abs_idx].astype(dtype)}
    pad = cache_len - T
    if pad > 0:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    elif pad < 0:
        k, v = k[:, :, :cache_len], v[:, :, :cache_len]
    return {"k": k.astype(dtype), "v": v.astype(dtype)}


def mla_prefill_cache(p, x, *, cfg: ArchConfig, positions, max_len: int,
                      dtype, absorbed: bool = False):
    """Build the MLA decode cache from a prompt."""
    B, T, _ = x.shape
    h = apply_norm(cfg, p.get("norm"), x)
    c_kv = rms_norm(jnp.einsum("btd,dl->btl", h, p["w_dkv"]), p["kv_norm"])
    k_rope = apply_rope(jnp.einsum("btd,dr->btr", h, p["w_kr"])[:, :, None],
                        positions, cfg.rope_theta)[:, :, 0]
    pad = max_len - T
    if absorbed:
        cc = jnp.pad(c_kv, ((0, 0), (0, max(pad, 0)), (0, 0)))[:, :max_len]
        kr = jnp.pad(k_rope, ((0, 0), (0, max(pad, 0)), (0, 0)))[:, :max_len]
        return {"c_kv": cc.astype(dtype), "k_rope": kr.astype(dtype)}
    nope, rope_d = cfg.qk_nope_dim, cfg.qk_rope_dim
    k_nope = jnp.einsum("btl,lhn->bthn", c_kv, p["w_uk"])
    v = jnp.einsum("btl,lhv->bthv", c_kv, p["w_uv"])
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None],
                                  (B, T, cfg.n_heads, rope_d))], axis=-1)
    kh, vh = k.swapaxes(1, 2), v.swapaxes(1, 2)
    if pad > 0:
        kh = jnp.pad(kh, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vh = jnp.pad(vh, ((0, 0), (0, 0), (0, pad), (0, 0)))
    return {"k": kh[:, :, :max_len].astype(dtype),
            "v": vh[:, :, :max_len].astype(dtype)}


# ---------------------------------------------------------------------------
# MLA attention (deepseek-v2): low-rank compressed KV
# ---------------------------------------------------------------------------
def mla_init(key, cfg: ArchConfig) -> Tuple[Pytree, Pytree]:
    d, H = cfg.d_model, cfg.n_heads
    nope, rope_d, vd, lora = (cfg.qk_nope_dim, cfg.qk_rope_dim,
                              cfg.v_head_dim, cfg.kv_lora)
    ks = jax.random.split(key, 6)
    p, a = {}, {}
    p["wq"], a["wq"] = dense_init(ks[0], d, (H, nope + rope_d), "embed",
                                  ("heads", None))
    p["w_dkv"], a["w_dkv"] = dense_init(ks[1], d, lora, "embed", "lora")
    p["w_kr"], a["w_kr"] = dense_init(ks[2], d, rope_d, "embed", None)
    p["w_uk"], a["w_uk"] = dense_init(ks[3], lora, (H, nope), "lora",
                                      ("heads", None))
    p["w_uv"], a["w_uv"] = dense_init(ks[4], lora, (H, vd), "lora",
                                      ("heads", None))
    wo = jax.random.normal(ks[5], (H, vd, d)) * (1.0 / math.sqrt(H * vd))
    p["wo"], a["wo"] = wo, ("heads", None, "embed")
    p["norm"], a["norm"] = _norm_init(cfg, d)
    p["kv_norm"], a["kv_norm"] = jnp.ones((lora,)), (None,)
    return p, a


def mla_apply(p, x, *, cfg: ArchConfig, positions, cache: Optional[Dict] = None,
              pos=None, attn_impl: str = "xla", absorbed: bool = False):
    """MLA. Baseline decode caches EXPANDED per-head k/v (naive port);
    ``absorbed=True`` caches compressed c_kv/k_rope and absorbs the up-
    projections into the query/output (the §Perf-optimized path)."""
    B, T, d = x.shape
    H = cfg.n_heads
    nope, rope_d, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    x = constrain_seq(x)  # seq-parallel residual stream (fsdp_tp_sp only)
    h = apply_norm(cfg, p.get("norm"), x)
    q = jnp.einsum("btd,dhk->bthk", h, p["wq"])          # [B,T,H,nope+rope]
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    c_kv = rms_norm(jnp.einsum("btd,dl->btl", h, p["w_dkv"]), p["kv_norm"])
    k_rope = apply_rope(jnp.einsum("btd,dr->btr", h, p["w_kr"])[:, :, None],
                        positions, cfg.rope_theta)       # [B,T,1,rope]

    if absorbed and cache is not None:
        # --- absorbed decode: scores in latent space ---
        cc, ckr = cache["c_kv"], cache["k_rope"]         # [B,Tmax,l], [B,Tmax,r]
        cc = jax.lax.dynamic_update_slice(cc, c_kv.astype(cc.dtype),
                                          (0, pos, 0))
        ckr = jax.lax.dynamic_update_slice(
            ckr, k_rope[:, :, 0].astype(ckr.dtype), (0, pos, 0))
        new_cache = {"c_kv": cc, "k_rope": ckr}
        # absorb W_uk into q: q_lat [B,T,H,l]
        q_lat = jnp.einsum("bthn,lhn->bthl", q_nope, p["w_uk"])
        s = (jnp.einsum("bthl,bsl->bhts", q_lat.astype(jnp.float32),
                        cc.astype(jnp.float32))
             + jnp.einsum("bthr,bsr->bhts", q_rope.astype(jnp.float32),
                          ckr.astype(jnp.float32)))
        s *= (nope + rope_d) ** -0.5
        Tmax = cc.shape[1]
        mask = jnp.arange(Tmax)[None, None, None, :] <= (
            pos + jnp.arange(T)[None, None, :, None])
        s = jnp.where(mask, s, -1e30)
        pr = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bhts,bsl->bthl", pr, cc.astype(jnp.float32))
        o = jnp.einsum("bthl,lhv->bthv", o_lat, p["w_uv"].astype(jnp.float32))
        y = jnp.einsum("bthv,hvd->btd", o.astype(x.dtype), p["wo"])
        return x + y, new_cache

    # expand per-head keys/values
    k_nope = jnp.einsum("btl,lhn->bthn", c_kv, p["w_uk"])
    v = jnp.einsum("btl,lhv->bthv", c_kv, p["w_uv"])
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, T, H, rope_d))], axis=-1)
    qq = jnp.concatenate([q_nope, q_rope], axis=-1)
    qh, kh, vh = (t.swapaxes(1, 2) for t in (qq, k, v))
    new_cache = None
    if cache is not None:                                # naive decode
        ck, cv = cache["k"], cache["v"]
        ck = jax.lax.dynamic_update_slice(ck, kh.astype(ck.dtype),
                                          (0, 0, pos, 0))
        cv = jax.lax.dynamic_update_slice(cv, vh.astype(cv.dtype),
                                          (0, 0, pos, 0))
        new_cache = {"k": ck, "v": cv}
        o = attention_ref(qh, ck.astype(qh.dtype), cv.astype(qh.dtype),
                          causal=True, q_offset=pos)
    else:
        o = flash_attention(qh, kh, vh, causal=True, impl=attn_impl,
                            block_k=cfg.attn_block_k)
    o = o.swapaxes(1, 2)
    y = jnp.einsum("bthv,hvd->btd", o[..., :vd], p["wo"])
    return x + y, new_cache


def mla_cache_init(cfg: ArchConfig, batch: int, max_len: int, dtype,
                   absorbed: bool = False):
    if absorbed:
        return {"c_kv": jnp.zeros((batch, max_len, cfg.kv_lora), dtype),
                "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype)}
    hd = cfg.qk_nope_dim + cfg.qk_rope_dim
    return {"k": jnp.zeros((batch, cfg.n_heads, max_len, hd), dtype),
            "v": jnp.zeros((batch, cfg.n_heads, max_len, cfg.v_head_dim),
                           dtype)}


# ---------------------------------------------------------------------------
# FFN (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------
def ffn_init(key, cfg: ArchConfig, d_ff: Optional[int] = None
             ) -> Tuple[Pytree, Pytree]:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p, a = {}, {}
    p["w1"], a["w1"] = dense_init(ks[0], d, f, "embed", "mlp")
    p["w3"], a["w3"] = dense_init(ks[1], d, f, "embed", "mlp")
    p["w2"], a["w2"] = dense_init(ks[2], f, d, "mlp", "embed")
    p["norm"], a["norm"] = _norm_init(cfg, d)
    return p, a


def ffn_apply(p, x, *, cfg: ArchConfig, act: str = "silu"):
    x = constrain_seq(x)  # seq-parallel residual stream (fsdp_tp_sp only)
    h = apply_norm(cfg, p.get("norm"), x)
    # serve_2d preset: gather activations over "data" here so the 2D-sharded
    # weights stay put (weight-stationary decode); identity otherwise
    h = constrain(h, ("ffn_batch", None, None))
    g = jnp.einsum("btd,df->btf", h, p["w1"])
    u = jnp.einsum("btd,df->btf", h, p["w3"])
    g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
    y = jnp.einsum("btf,fd->btd", g * u, p["w2"])
    y = constrain(y, ("batch", None, None))
    return x + y


# ---------------------------------------------------------------------------
# MoE (grok: expert-TP; deepseek: expert-parallel + shared experts)
# ---------------------------------------------------------------------------
def moe_init(key, cfg: ArchConfig) -> Tuple[Pytree, Pytree]:
    d, E, f = cfg.d_model, cfg.n_experts, cfg.d_expert
    ks = jax.random.split(key, 5)
    p, a = {}, {}
    p["w_router"], a["w_router"] = dense_init(ks[0], d, E, "embed", None)
    escale = 1.0 / math.sqrt(d)
    # expert_parallel: experts dim on "model" (all-to-all EP), ffn local;
    # expert_tp: experts replicated, each expert's ffn sharded on "model".
    if cfg.moe_strategy == "expert_parallel":
        ep, fp = "experts", None
    else:
        ep, fp = None, "mlp"
    p["w1"] = jax.random.normal(ks[1], (E, d, f)) * escale
    a["w1"] = (ep, "embed", fp)
    p["w3"] = jax.random.normal(ks[2], (E, d, f)) * escale
    a["w3"] = (ep, "embed", fp)
    p["w2"] = jax.random.normal(ks[3], (E, f, d)) * (1.0 / math.sqrt(f))
    a["w2"] = (ep, fp, "embed")
    p["norm"], a["norm"] = _norm_init(cfg, d)
    if cfg.n_shared_experts:
        sh, sa = ffn_init(ks[4], cfg, d_ff=cfg.n_shared_experts * f)
        sh.pop("norm"), sa.pop("norm")   # share the block norm
        p["shared"], a["shared"] = sh, sa
    return p, a


def _capacity(cfg: ArchConfig, T: int) -> int:
    c = int(math.ceil(T * cfg.top_k * cfg.capacity_factor / cfg.n_experts))
    return max(4, -(-c // 4) * 4)


def moe_apply(p, x, *, cfg: ArchConfig):
    """Einsum (dispatch-mask) MoE — the device-side shuffle service.

    Per-batch-row capacity bounds the mask to [B, T, E, C]. Returns
    (y, aux_loss).
    """
    B, T, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = _capacity(cfg, T)
    x = constrain_seq(x)  # seq-parallel residual stream (fsdp_tp_sp only)
    h = apply_norm(cfg, p.get("norm"), x)
    h = constrain(h, ("ffn_batch", None, None))  # serve_2d: gather over data
    logits = jnp.einsum("btd,de->bte", h, p["w_router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eid = jax.lax.top_k(probs, K)                  # [B,T,K]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # slots: position of each (t,k) within its expert, per batch row
    onehot = jax.nn.one_hot(eid, E, dtype=jnp.int32)      # [B,T,K,E]
    flat = onehot.reshape(B, T * K, E)
    pos = jnp.cumsum(flat, axis=1) - flat                 # exclusive
    slot = (pos * flat).sum(-1).reshape(B, T, K)          # [B,T,K]
    keep = slot < C
    # dispatch mask [B,T,E,C]
    slot_oh = jax.nn.one_hot(jnp.where(keep, slot, C), C + 1,
                             dtype=h.dtype)[..., :C]      # [B,T,K,C]
    mask = jnp.einsum("btke,btkc->btec", onehot.astype(h.dtype), slot_oh)
    gmask = jnp.einsum("btke,btkc,btk->btec", onehot.astype(h.dtype),
                       slot_oh, gates.astype(h.dtype))

    disp = jnp.einsum("btec,btd->becd", mask, h)
    disp = constrain(disp, ("ffn_batch", "experts", None, None))
    g1 = jnp.einsum("becd,edf->becf", disp, p["w1"])
    u1 = jnp.einsum("becd,edf->becf", disp, p["w3"])
    eo = jnp.einsum("becf,efd->becd", jax.nn.silu(g1) * u1, p["w2"])
    eo = constrain(eo, ("ffn_batch", "experts", None, None))
    y = jnp.einsum("btec,becd->btd", gmask, eo)

    if cfg.n_shared_experts:
        sp = dict(p["shared"])
        sp["norm"] = None
        g = jnp.einsum("btd,df->btf", h, sp["w1"])
        u = jnp.einsum("btd,df->btf", h, sp["w3"])
        y = y + jnp.einsum("btf,fd->btd", jax.nn.silu(g) * u, sp["w2"])

    # switch-style load-balance aux loss
    density = mask.sum(axis=(1, 3)) / T                   # [B,E] tokens frac
    router_prob = probs.mean(axis=1)                      # [B,E]
    aux = (density * router_prob).sum(-1).mean() * E
    y = constrain(y, ("batch", None, None))
    return x + y, aux


# ---------------------------------------------------------------------------
# RWKV6 (Finch) — time mix (wkv) + channel mix
# ---------------------------------------------------------------------------
def rwkv_init(key, cfg: ArchConfig) -> Tuple[Pytree, Pytree]:
    d = cfg.d_model
    ff = cfg.d_ff
    lora = 64
    ks = jax.random.split(key, 12)
    p, a = {}, {}
    for i, nm in enumerate(("mu_r", "mu_k", "mu_v", "mu_w", "mu_g")):
        p[nm] = jax.random.uniform(ks[0], (d,), minval=0.0, maxval=1.0)
        a[nm] = (None,)
    p["w0"] = jnp.full((d,), -2.0) + jax.random.normal(ks[1], (d,)) * 0.1
    a["w0"] = (None,)
    p["wA"], a["wA"] = dense_init(ks[2], d, lora, "embed", None)
    p["wB"], a["wB"] = dense_init(ks[3], lora, d, None, "embed")
    for i, nm in enumerate(("w_r", "w_k", "w_v", "w_g")):
        p[nm], a[nm] = dense_init(ks[4 + i], d, d, "embed", "heads_embed")
    p["u"] = jax.random.normal(ks[8], (d,)) * 0.1
    a["u"] = (None,)
    p["ln_x"] = jnp.ones((d,))
    a["ln_x"] = (None,)
    p["w_o"], a["w_o"] = dense_init(ks[9], d, d, "heads_embed", "embed")
    p["norm1"], a["norm1"] = _norm_init(cfg, d)
    # channel mix
    p["cmu_k"] = jax.random.uniform(ks[10], (d,), minval=0.0, maxval=1.0)
    a["cmu_k"] = (None,)
    p["cmu_r"] = jax.random.uniform(ks[10], (d,), minval=0.0, maxval=1.0)
    a["cmu_r"] = (None,)
    p["cw_k"], a["cw_k"] = dense_init(ks[11], d, ff, "embed", "mlp")
    p["cw_v"], a["cw_v"] = dense_init(ks[11], ff, d, "mlp", "embed")
    p["cw_r"], a["cw_r"] = dense_init(ks[11], d, d, "embed", "embed_out")
    p["norm2"], a["norm2"] = _norm_init(cfg, d)
    return p, a


def _token_shift(x, prev):
    """[B,T,d] -> previous token's activations ([B,1,d] prev for t=0)."""
    if x.shape[1] == 1:
        return prev[:, None] if prev.ndim == 2 else prev
    shifted = jnp.concatenate([x[:, :1] * 0, x[:, :-1]], axis=1)
    if prev is not None:
        shifted = shifted.at[:, 0].set(prev if prev.ndim == 2 else prev[:, 0])
    return shifted


def rwkv_apply(p, x, *, cfg: ArchConfig, state: Optional[Dict] = None,
               scan_impl: str = "xla_chunked"):
    """Returns (y, new_state). state: {"tm_x","cm_x": [B,d], "S": [B,H,dk,dv]}."""
    B, T, d = x.shape
    hd = cfg.rwkv_head_dim
    H = d // hd
    decode = state is not None and T == 1

    # ---- time mix ----
    h = apply_norm(cfg, p.get("norm1"), x)
    prev = state["tm_x"] if state is not None else None
    hs = _token_shift(h, prev)
    def mix(mu):
        return h + (hs - h) * mu
    xr, xk, xv, xw, xg = (mix(p[m]) for m in
                          ("mu_r", "mu_k", "mu_v", "mu_w", "mu_g"))
    w_log = -jnp.exp(p["w0"] + jnp.tanh(
        jnp.einsum("btd,dl->btl", xw, p["wA"])) @ p["wB"])  # [B,T,d] <= 0
    r = jnp.einsum("btd,de->bte", xr, p["w_r"])
    k = jnp.einsum("btd,de->bte", xk, p["w_k"])
    v = jnp.einsum("btd,de->bte", xv, p["w_v"])
    g = jnp.einsum("btd,de->bte", xg, p["w_g"])

    def heads(t):  # [B,T,d] -> [B*H, T, hd]
        return (t.reshape(B, T, H, hd).swapaxes(1, 2)
                .reshape(B * H, T, hd))
    rh, kh, vh, wh = heads(r), heads(k), heads(v), heads(w_log)
    u = jnp.broadcast_to(p["u"].reshape(H, hd)[None], (B, H, hd)
                         ).reshape(B * H, hd)
    if decode:
        S = state["S"].reshape(B * H, hd, hd)
        kv = kh[:, 0, :, None] * vh[:, 0, None, :]
        o = jnp.einsum("bk,bkv->bv", rh[:, 0],
                       S + u[:, :, None] * kv)[:, None]
        S = jnp.exp(wh[:, 0])[:, :, None] * S + kv
        new_S = S.reshape(B, H, hd, hd)
    else:
        o, Sf = gla_scan(rh, kh, vh, wh, u, impl=scan_impl)
        new_S = Sf.reshape(B, H, hd, hd)
    o = (o.reshape(B, H, T, hd).swapaxes(1, 2).reshape(B, T, d))
    # per-head group norm
    og = o.reshape(B, T, H, hd)
    og = rms_norm(og, None) * p["ln_x"].reshape(H, hd)
    o = og.reshape(B, T, d).astype(x.dtype)
    o = o * jax.nn.silu(g)
    x = x + jnp.einsum("btd,de->bte", o, p["w_o"])

    # ---- channel mix ----
    h2 = apply_norm(cfg, p.get("norm2"), x)
    prev2 = state["cm_x"] if state is not None else None
    hs2 = _token_shift(h2, prev2)
    ck = h2 + (hs2 - h2) * p["cmu_k"]
    cr = h2 + (hs2 - h2) * p["cmu_r"]
    kk = jnp.einsum("btd,df->btf", ck, p["cw_k"])
    kk = jnp.maximum(kk, 0.0) ** 2
    out = jax.nn.sigmoid(jnp.einsum("btd,de->bte", cr, p["cw_r"])) * \
        jnp.einsum("btf,fd->btd", kk, p["cw_v"])
    x = x + out

    new_state = None
    if state is not None:
        new_state = {"tm_x": h[:, -1], "cm_x": h2[:, -1], "S": new_S}
    return x, new_state


def rwkv_state_init(cfg: ArchConfig, batch: int, dtype):
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    H = d // hd
    return {"tm_x": jnp.zeros((batch, d), dtype),
            "cm_x": jnp.zeros((batch, d), dtype),
            "S": jnp.zeros((batch, H, hd, hd), jnp.float32)}


# ---------------------------------------------------------------------------
# RG-LRU recurrent block (recurrentgemma)
# ---------------------------------------------------------------------------
CONV_W = 4
LRU_C = 8.0


def rglru_init(key, cfg: ArchConfig) -> Tuple[Pytree, Pytree]:
    d = cfg.d_model
    w = d  # lru_width = d_model
    ks = jax.random.split(key, 7)
    p, a = {}, {}
    p["w_gate"], a["w_gate"] = dense_init(ks[0], d, w, "embed", "mlp")
    p["w_x"], a["w_x"] = dense_init(ks[1], d, w, "embed", "mlp")
    p["conv_w"] = jax.random.normal(ks[2], (CONV_W, w)) * 0.1
    a["conv_w"] = (None, "mlp")
    p["conv_b"] = jnp.zeros((w,))
    a["conv_b"] = ("mlp",)
    # [w_in, w_out]: FSDP on the input dim, TP on the output dim (the
    # recurrence state h stays sharded on "model" end to end)
    p["w_a"], a["w_a"] = dense_init(ks[3], w, w, "embed", "mlp_out")
    p["b_a"] = jnp.zeros((w,)); a["b_a"] = ("mlp_out",)
    p["w_i"], a["w_i"] = dense_init(ks[4], w, w, "embed", "mlp_out")
    p["b_i"] = jnp.zeros((w,)); a["b_i"] = ("mlp_out",)
    p["lam"] = jax.random.uniform(ks[5], (w,), minval=0.5, maxval=2.0)
    a["lam"] = ("mlp_out",)
    p["w_out"], a["w_out"] = dense_init(ks[6], w, d, "mlp_out", "embed")
    p["norm"], a["norm"] = _norm_init(cfg, d)
    return p, a


def rglru_apply(p, x, *, cfg: ArchConfig, state: Optional[Dict] = None,
                scan_impl: str = "xla"):
    """Returns (y, new_state); state: {"conv": [B,CONV_W-1,w], "h": [B,w]}."""
    B, T, d = x.shape
    h0 = apply_norm(cfg, p.get("norm"), x)
    gate = jax.nn.gelu(jnp.einsum("btd,dw->btw", h0, p["w_gate"]))
    xx = jnp.einsum("btd,dw->btw", h0, p["w_x"])
    # causal depthwise conv, window CONV_W
    prev_conv = (state["conv"] if state is not None
                 else jnp.zeros((B, CONV_W - 1, xx.shape[-1]), xx.dtype))
    xcat = jnp.concatenate([prev_conv, xx], axis=1)
    conv = sum(xcat[:, i:i + T] * p["conv_w"][i] for i in range(CONV_W))
    conv = conv + p["conv_b"]
    r = jax.nn.sigmoid(jnp.einsum("btw,wv->btv", conv, p["w_a"]) + p["b_a"])
    i = jax.nn.sigmoid(jnp.einsum("btw,wv->btv", conv, p["w_i"]) + p["b_i"])
    log_a = -LRU_C * jax.nn.softplus(p["lam"]) * r
    aa = jnp.exp(log_a)
    bb = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * conv)
    hprev = state["h"] if state is not None else None
    hs, hT = diag_scan(aa, bb, hprev, impl=scan_impl)
    y = jnp.einsum("btw,wd->btd", hs * gate, p["w_out"])
    new_state = None
    if state is not None:
        new_state = {"conv": xcat[:, -(CONV_W - 1):], "h": hT}
    return x + y, new_state


def rglru_state_init(cfg: ArchConfig, batch: int, dtype):
    w = cfg.d_model
    return {"conv": jnp.zeros((batch, CONV_W - 1, w), dtype),
            "h": jnp.zeros((batch, w), dtype)}
