"""Logical-axis sharding rules.

Models annotate weights and activations with *logical* axis names; a rules
table maps logical names to mesh axes. Inside a ``use_rules(...)`` context
(set up by the launcher), ``constrain(x, axes)`` applies
``with_sharding_constraint``; outside, it is a no-op so models run untouched
on a single CPU device.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

# default logical -> mesh-axis rules (single-pod); launcher may override.
# None = replicated. A tuple means the dim is sharded over several mesh axes.
DEFAULT_RULES: Dict[str, Union[None, str, Tuple[str, ...]]] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": "data",        # FSDP/ZeRO shard axis for weights
    "heads": "model",
    "kv": "model",
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "layers": None,
    "expert_batch": None,
    "state": None,
    "conv": None,
    "lora": None,
    "pages": None,
    "kv_seq": None,
}


def _mesh_axes(mesh: Mesh):
    return set(mesh.axis_names)


def spec_for(axes: Sequence[Optional[str]],
             rules: Optional[Dict] = None,
             mesh: Optional[Mesh] = None) -> P:
    """Logical axes tuple -> PartitionSpec under the active rules/mesh."""
    rules = rules if rules is not None else get_rules()
    mesh = mesh if mesh is not None else get_mesh()
    names = _mesh_axes(mesh) if mesh is not None else set()
    out = []
    used = set()
    for ax in axes:
        m = rules.get(ax) if ax is not None else None
        if m is None:
            out.append(None)
            continue
        if isinstance(m, str):
            m = (m,)
        # a mesh axis may appear at most once per spec; first dim wins
        m = tuple(a for a in m if a in names and a not in used)
        used.update(m)
        out.append(m if len(m) > 1 else (m[0] if m else None))
    # trim trailing Nones (canonical form)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


@contextlib.contextmanager
def use_rules(rules: Dict, mesh: Optional[Mesh] = None):
    prev = (getattr(_state, "rules", None), getattr(_state, "mesh", None))
    _state.rules = rules
    _state.mesh = mesh
    try:
        yield
    finally:
        _state.rules, _state.mesh = prev


def get_rules() -> Optional[Dict]:
    return getattr(_state, "rules", None)


def get_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def constrain(x, axes: Sequence[Optional[str]]):
    """Apply a logical sharding constraint if rules+mesh are active."""
    rules = get_rules()
    mesh = get_mesh()
    if rules is None or mesh is None:
        return x
    spec = spec_for(axes, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_seq(x):
    """Sequence-parallel residual-stream constraint: only emitted when the
    active rules actually shard "seq" (the fsdp_tp_sp preset) so the default
    presets lower exactly as without it."""
    rules = get_rules()
    if rules is None or rules.get("seq") is None:
        return x
    return constrain(x, ("batch", "seq", None))
