"""Scheduler-driven distributed equi-join — paper §9.2.2's flagship workload.

The monolithic-storage payoff in one operator: because the storage layer's
statistics database knows every replica's partitioning, the scheduler
(``ClusterScheduler.plan_join``) can prove which sides of a join do NOT need
to move:

* **co-partitioned** — both sides (or registered replicas of them) are
  partitioned on the join key onto the same layout: no shuffle at all, every
  node joins its own shard pair, ``net_bytes == 0``;
* **one side shuffled** — one side anchors the join in place; the other is
  routed by the *anchor's own storage scheme* (not the generic shuffle hash),
  so matching keys land exactly where the anchor's shards already sit;
* **both sides shuffled** — neither side is partitioned on the key; both
  repartition to a common hash layout and reducer placement follows the
  combined byte statistics with the usual memory-pressure discount.

Execution rides the existing machinery end to end: the moving side goes
through ``ClusterShuffle`` (map-side virtual shuffle buffers, straggler
re-execution from replica holders, dead owners read through CRC-verified
replicas), and the shuffled partitions stream partition-by-partition through
``ShuffleService.iter_partition`` directly into the single-node
``JoinService`` hash tables (``core/services.py``) — no reducer-set staging.
Build-side batches are reserve-charged against the executing node's
``MemoryManager``, so an over-capacity build spills through the pool's
eviction policy instead of OOM-ing, and probes fault the spilled build pages
back in transparently.

Results are canonical-sorted (``canonical_join_sort``), which makes every
execution mode byte-identical to the single-pool ``join_records`` reference.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, Iterable, Iterator, List, Optional, Tuple

import numpy as np

from ..core.columnar import iter_column_blocks
from ..core.services import JoinService, canonical_join_sort, is_columnar
from .scheduler import ClusterScheduler, JoinPlan
from .watchdog import StepTimer


def scheme_slot_of_keys(keys: np.ndarray, scheme) -> np.ndarray:
    """The scheme slot (index into a set's ``node_ids``) each join key routes
    to — lets a shuffled side be routed by the *other* side's partitioner
    even when its key field has a different name."""
    return scheme.slot_of_keys(keys)


@dataclass
class JoinReport:
    """What one distributed join did: the scheduler's plan plus the movement
    and pressure its execution actually caused."""

    plan: JoinPlan
    net_bytes: int = 0              # bytes this join moved across nodes
    shuffled_bytes: Dict[str, int] = field(default_factory=dict)  # per side
    build_rows: int = 0
    probe_rows: int = 0
    output_rows: int = 0
    stragglers_redone: List[Tuple[int, int]] = field(default_factory=list)
    # reducer -> (refused_node, placed_node): partitions whose byte-locality
    # node refused admission past the deadline and were re-routed (PR 5)
    diversions: Dict[int, Tuple[int, int]] = field(default_factory=dict)
    seconds: float = 0.0

    @property
    def shuffle_free(self) -> bool:
        return self.plan.shuffle_free


def _batches(records: np.ndarray, batch: int = 65536) -> Iterator[np.ndarray]:
    for i in range(0, len(records), batch):
        yield records[i:i + batch]


class ClusterJoin:
    """Execute one equi-join over two sharded sets, as planned by the
    cluster scheduler. ``build``'s rows feed the hash tables, ``probe``'s
    rows stream through them; both dtypes must carry ``key_field``. The
    scheduler decides only *placement and movement* — roles never swap, so
    the output layout (and byte-identity with the single-pool reference) is
    independent of which plan executes."""

    def __init__(self, cluster, build, probe, key_field: str,
                 scheduler: Optional[ClusterScheduler] = None,
                 page_size: int = 1 << 16,
                 num_reducers: Optional[int] = None,
                 step_timer: Optional[StepTimer] = None,
                 batch: int = 65536):
        self.cluster = cluster
        self.build = build
        self.probe = probe
        self.key_field = key_field
        self.scheduler = scheduler or cluster.scheduler
        self.page_size = page_size
        self.num_reducers = num_reducers
        self.step_timer = step_timer
        self.batch = batch
        self._name = f"{build.name}-join-{probe.name}"

    # -- shared executor -------------------------------------------------------
    def _run_join(self, node, tag: str, build_dtype, probe_dtype,
                  build_chunks: Iterable, probe_chunks: Iterable) -> np.ndarray:
        """One node-local hash join: build chunks reserve-charged into pool
        pages (spillable), probe chunks streamed through the table. Chunks
        are polymorphic over the storage scheme (PR 7): a record array goes
        through ``build_batch``/``probe_batch``, a ``(columns, n)`` block
        tuple through the columnar twins — probe blocks run the searchsorted
        match on the raw key column and gather output per column, with no
        probe-side row materialization."""
        js = JoinService(node.pool, f"{self._name}/tbl{tag}", build_dtype,
                         probe_dtype, self.key_field, self.key_field,
                         page_size=self.page_size)
        for chunk in build_chunks:
            if isinstance(chunk, tuple):
                cols, n = chunk
                with node.memory.reserve(n * js.build_dtype.itemsize):
                    js.build_columns(cols, n)
            else:
                with node.memory.reserve(chunk.nbytes):
                    js.build_batch(chunk)
        js.finish_build()
        outs = []
        for chunk in probe_chunks:
            if isinstance(chunk, tuple):
                cols, n = chunk
                with node.memory.reserve(n * js.probe_dtype.itemsize):
                    out = js.probe_columns(cols, n)
            else:
                with node.memory.reserve(chunk.nbytes):
                    out = js.probe_batch(chunk)
            if len(out):
                outs.append(out)
        empty = np.empty(0, js.out_dtype)
        js.close()
        return np.concatenate(outs) if outs else empty

    def _map_moving_side(self, sh, sset, report: JoinReport) -> None:
        """The aggregation path's map side, verbatim: each shard maps on the
        node holding its bytes (replica holders for dead owners), per-shard
        times feed the straggler detector, and flagged mappers re-execute
        from replica holders before byte statistics are published. On a
        columnar shuffle, ``key_field`` routes each shard's blocks through
        the fused partition+CRC pass without materializing rows."""
        for n in sorted(sset.shards):
            t0 = time.perf_counter()
            worker = sh.map_shard(sset, n,
                                  key_fn=lambda r: r[self.key_field],
                                  key_field=self.key_field)
            if self.step_timer is not None:
                self.step_timer.record(worker, time.perf_counter() - t0)
        if self.step_timer is not None:
            report.stragglers_redone.extend(sh.reexecute_stragglers(
                self.step_timer.stragglers(min_samples=1)))

    def _columnar_shard_blocks(self, t, n: int):
        """``(holder, block_iterator)`` when shard ``n``'s alive primary is
        stored columnar (the zero-materialization feed), else None — dead
        owners and row shards take the record read path."""
        info = t.shards[n]
        node = self.cluster.nodes[info.node_id]
        if (node.alive and node.pool is not None
                and info.set_name in node.pool.paging.sets):
            ls = node.pool.get_set(info.set_name)
            if is_columnar(ls):
                return info.node_id, iter_column_blocks(node.pool, ls,
                                                        t.dtype)
        return None

    # -- the three plans -------------------------------------------------------
    def _co_partitioned(self, bt, pt, report: JoinReport) -> List[np.ndarray]:
        """Both sides aligned on the key: node-local shard-pair joins, zero
        network bytes (replica fallback for a dead owner is the only thing
        that can move data, and it is counted when it does). Columnar shard
        pairs stream block-by-block straight into the join tables — the
        probe side never materializes rows at all."""
        outs = []
        for n in sorted(bt.shards):
            bfast = self._columnar_shard_blocks(bt, n)
            pfast = self._columnar_shard_blocks(pt, n)
            if (bfast is not None and pfast is not None
                    and bfast[0] == pfast[0]):
                node = self.cluster.node(bfast[0])
                outs.append(self._run_join(node, f"co{n}", bt.dtype,
                                           pt.dtype, bfast[1], pfast[1]))
                continue
            bholder, brecs = self.cluster.read_shard_from(bt, n)
            pholder, precs = self.cluster.read_shard_from(pt, n)
            if pholder != bholder:
                # dead-owner fallback put the two shards on different
                # holders; the probe shard crosses to the build holder
                self.cluster.add_net_bytes(precs.nbytes)
            node = self.cluster.node(bholder)
            outs.append(self._run_join(node, f"co{n}", bt.dtype, pt.dtype,
                                       _batches(brecs, self.batch),
                                       _batches(precs, self.batch)))
        return outs

    def _one_side(self, bt, pt, plan: JoinPlan,
                  report: JoinReport) -> List[np.ndarray]:
        """Anchor side stays put; the moving side shuffles routed by the
        anchor's scheme, then streams partition-by-partition into join
        tables built from the anchor's local shards."""
        from .cluster import (ClusterShuffle,  # local: cluster imports scheduler
                              sharded_set_is_columnar)
        anchor_t, moving_t = (bt, pt) if plan.anchor == "build" else (pt, bt)
        moving_side = plan.shuffle_sides[0]
        sh = ClusterShuffle(
            self.cluster, f"{self._name}.sh", len(anchor_t.node_ids),
            moving_t.dtype, page_size=self.page_size,
            scheduler=self.scheduler,
            partition_fn=lambda keys: scheme_slot_of_keys(
                keys, anchor_t.scheme),
            columnar=sharded_set_is_columnar(moving_t))
        self._map_moving_side(sh, moving_t, report)
        sh.finish_maps()
        report.shuffled_bytes[moving_side] = \
            self.cluster.stats.total_shuffle_bytes(sh.name)
        outs = []
        for r, nid in enumerate(anchor_t.node_ids):
            afast = self._columnar_shard_blocks(anchor_t, nid)
            if afast is not None:
                aholder = afast[0]
                anchor_chunks: Iterable = afast[1]
            else:
                aholder, arecs = self.cluster.read_shard_from(anchor_t, nid)
                anchor_chunks = _batches(arecs, self.batch)
            node = self.cluster.node(aholder)
            moving_chunks = sh.stream_partition(r, dst_node=aholder)
            if plan.anchor == "build":
                out = self._run_join(node, f"r{r}", bt.dtype, pt.dtype,
                                     anchor_chunks, moving_chunks)
            else:
                out = self._run_join(node, f"r{r}", bt.dtype, pt.dtype,
                                     moving_chunks, anchor_chunks)
            sh.release_partition(r)
            outs.append(out)
        self.cluster.stats.clear_shuffle(sh.name)
        return outs

    def _both_sides(self, bt, pt, report: JoinReport) -> List[np.ndarray]:
        """Neither side is partitioned on the key: repartition both to a
        common hash layout; reducer placement follows the combined build +
        probe byte statistics with the pressure discount."""
        from .cluster import ClusterShuffle, sharded_set_is_columnar
        R = self.num_reducers or len(self.cluster.alive_node_ids())
        shb = ClusterShuffle(self.cluster, f"{self._name}.b", R, bt.dtype,
                             page_size=self.page_size,
                             scheduler=self.scheduler,
                             columnar=sharded_set_is_columnar(bt))
        shp = ClusterShuffle(self.cluster, f"{self._name}.p", R, pt.dtype,
                             page_size=self.page_size,
                             scheduler=self.scheduler,
                             columnar=sharded_set_is_columnar(pt))
        self._map_moving_side(shb, bt, report)
        self._map_moving_side(shp, pt, report)
        shb.finish_maps()
        shp.finish_maps()
        report.shuffled_bytes["build"] = \
            self.cluster.stats.total_shuffle_bytes(shb.name)
        report.shuffled_bytes["probe"] = \
            self.cluster.stats.total_shuffle_bytes(shp.name)
        if self.cluster.admission:
            pplan = self.scheduler.place_join_reducers_admitted(
                shb.name, shp.name, R,
                deadline_s=self.cluster.admission_deadline_s)
            placement = pplan.placement
            report.diversions = dict(pplan.diversions)
        else:
            placement = self.scheduler.place_join_reducers(shb.name,
                                                           shp.name, R)
        shb.assign_placement(placement)
        shp.assign_placement(placement)
        outs = []
        for r in range(R):
            dst = placement[r]
            node = self.cluster.node(dst)
            out = self._run_join(node, f"r{r}", bt.dtype, pt.dtype,
                                 shb.stream_partition(r, dst_node=dst),
                                 shp.stream_partition(r, dst_node=dst))
            shb.release_partition(r)
            shp.release_partition(r)
            outs.append(out)
        self.cluster.stats.clear_shuffle(shb.name)
        self.cluster.stats.clear_shuffle(shp.name)
        return outs

    # -- entry point -----------------------------------------------------------
    def execute(self) -> Tuple[np.ndarray, JoinReport]:
        """Plan, execute, and canonical-sort the join. Returns the joined
        records (``join_output_dtype`` layout) and the execution report."""
        t0 = time.perf_counter()
        plan = self.scheduler.plan_join(self.build, self.probe,
                                        self.key_field)
        report = JoinReport(plan=plan)
        bt = self.cluster.catalog.get(plan.build_name, self.build)
        pt = self.cluster.catalog.get(plan.probe_name, self.probe)
        report.build_rows = sum(i.num_records for i in bt.shards.values())
        report.probe_rows = sum(i.num_records for i in pt.shards.values())
        base_net = self.cluster.net_bytes
        if plan.shuffle_free:
            outs = self._co_partitioned(bt, pt, report)
        elif len(plan.shuffle_sides) == 1:
            outs = self._one_side(bt, pt, plan, report)
        else:
            outs = self._both_sides(bt, pt, report)
        outs = [o for o in outs if len(o)]
        if outs:
            out = canonical_join_sort(np.concatenate(outs))
        else:
            from ..core.services import join_output_dtype
            out = np.empty(0, join_output_dtype(bt.dtype, pt.dtype,
                                                self.key_field,
                                                self.key_field))
        report.output_rows = len(out)
        report.net_bytes = self.cluster.net_bytes - base_net
        report.seconds = time.perf_counter() - t0
        return out, report


def cluster_join(cluster, build, probe, key_field: str,
                 **kw) -> Tuple[np.ndarray, JoinReport]:
    """One-call form over existing sharded sets (``data/pipeline.py``'s
    ``cluster_join`` stages records first and then calls this)."""
    return ClusterJoin(cluster, build, probe, key_field, **kw).execute()
