"""Async node-to-node transfer engine — the cluster's "network stack".

PR 1 moved every byte synchronously: ``Cluster.transfer_records`` streamed
pages inline, so reducer pulls serialized behind map finalization and behind
each other. This module extracts the two halves:

* ``copy_set`` — the mechanics: stream one locality set between two buffer
  pools page by page (paged reads on the source, sequential writes on the
  destination). ``Cluster.transfer_records`` is now one client of it.
* ``TransferEngine`` — the asynchrony: a small producer/consumer thread pool
  (BatchLoader-style) whose jobs may declare dependencies (``after=``), so a
  reducer pull can be submitted before the map side has finalized and the
  engine orders them. Workers exit after an idle timeout and are respawned on
  the next submit, so short-lived clusters in tests don't accumulate threads.

Since PR 5 the engine also enforces **per-destination in-flight byte caps**
(the wire half of admission control): jobs may declare the node their bytes
land on (``dest=``) and how many (``nbytes=``), and the engine holds a job
back while that destination already has a cap's worth of transfer bytes in
flight — so overlapped reducer pulls can't stampede one reducer node even
before its MemoryManager starts refusing staging. ``dest``/``nbytes`` may be
callables, resolved once the job's dependencies finish (a pull submitted
before placement doesn't know its reducer node yet). A destination with
nothing in flight always admits one job, so an oversized transfer can't
starve.

The buffer pool is internally locked (pin/unpin/new_page take the pool's
RLock), which is what makes concurrent pulls through shared source pools safe.
"""
from __future__ import annotations

import itertools
import queue
import threading
from typing import Callable, List, Optional, Sequence

import numpy as np

from ..core.attributes import AttributeSet, StorageScheme
from ..core.sanitizer import note_blocking, tracked_condition, tracked_lock
from ..core.services import _HEADER, PageIterator, SequentialWriter


def copy_set(src_pool, src_set_name: str, dst_pool, dst_set_name: str,
             dtype: np.dtype, page_size: int,
             attrs: Optional[AttributeSet] = None) -> int:
    """Stream one locality set between pools page by page; returns bytes
    moved. This is the wire: a paged read on the source feeding a sequential
    write on the destination. Each in-flight chunk is charged to the
    destination's MemoryManager (``reserve``) so replica creation and
    recovery copies show up in the same pressure accounting as shuffle pulls
    and remesh streams.

    Row sets decode records per page (the destination re-packs them);
    columnar sets (and sources whose pages already are column blocks) take
    :func:`copy_set_raw` instead — page images move as raw buffers with no
    per-record decode/encode at either end."""
    src_ls = src_pool.get_set(src_set_name)
    if (src_ls.attrs.storage is StorageScheme.COLUMNAR
            or (attrs is not None
                and attrs.storage is StorageScheme.COLUMNAR)):
        return copy_set_raw(src_pool, src_set_name, dst_pool, dst_set_name,
                            np.dtype(dtype), attrs)
    dtype = np.dtype(dtype)
    ls_dst = dst_pool.create_set(dst_set_name, page_size, attrs)
    writer = SequentialWriter(dst_pool, ls_dst, dtype)
    memory = getattr(dst_pool, "memory", None)
    moved = 0
    for recs in PageIterator(src_pool, src_ls, dtype, sorted(src_ls.pages)):
        reservation = memory.reserve(recs.nbytes) if memory is not None else None
        try:
            writer.append_batch(recs)
        finally:
            if reservation is not None:
                reservation.release()
        moved += recs.nbytes
    writer.close()
    return moved


def iter_page_images(pool, ls):
    """Pin each page of a set in page-id order and yield ``(size, view)``.
    The view is a uint8 window over the pool's backing store, valid only
    until the next iteration — callers copy it out (into a destination page,
    a shm arena frame, or a socket buffer) before advancing.  This is the
    producer half of every raw page-image move: same-pool replica copies
    (:func:`copy_set_raw`) and the multi-process backend's shm exports share
    it, so neither path ever touches per-record decode or pickle."""
    for pid in sorted(ls.pages):
        page = ls.pages[pid]
        view = pool.pin(page)
        try:
            yield page.size, view
        finally:
            pool.unpin(page)


def land_page_image(pool, ls, image, memory=None) -> None:
    """The consumer half: allocate a destination page of the image's exact
    size and memcpy the image in (charged to ``memory`` while in flight).
    Valid for any self-describing page — row small-page blocks and columnar
    blocks alike carry their own count headers."""
    image = np.frombuffer(image, dtype=np.uint8)
    reservation = memory.reserve(image.nbytes) if memory is not None else None
    try:
        dst_page = pool.new_page(ls, size=image.nbytes)
        pool.view(dst_page)[:] = image
        pool.unpin(dst_page, dirty=True)
    finally:
        if reservation is not None:
            reservation.release()


def copy_set_raw(src_pool, src_set_name: str, dst_pool, dst_set_name: str,
                 dtype: np.dtype, attrs: Optional[AttributeSet] = None) -> int:
    """Move a set between pools as raw page images: pin source page, alloc an
    equally sized destination page, one memcpy, unpin dirty. No per-record
    pickling or decode — the columnar fast wire (a column block is
    position-dependent inside its page, so the image must move whole; the
    destination set inherits the source's page size for the same reason).
    Returns the *logical* record bytes moved (each block's ``count`` header
    times the record width) so net-byte accounting stays comparable with the
    row path."""
    dtype = np.dtype(dtype)
    ls_src = src_pool.get_set(src_set_name)
    ls_dst = dst_pool.create_set(dst_set_name, ls_src.page_size, attrs)
    memory = getattr(dst_pool, "memory", None)
    moved = 0
    for size, src_view in iter_page_images(src_pool, ls_src):
        land_page_image(dst_pool, ls_dst, src_view, memory=memory)
        n = int(src_view[:_HEADER].view(np.int64)[0])
        moved += n * dtype.itemsize
    return moved


class TransferError(RuntimeError):
    """A transfer job failed because one of its dependencies failed."""


class TransferFuture:
    """Result handle for a submitted transfer job."""

    def __init__(self, job_id: int, label: str = ""):
        self.job_id = job_id
        self.label = label
        self._done = threading.Event()
        self._result = None
        self._exc: Optional[BaseException] = None

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: Optional[float] = None):
        if timeout is None or timeout > 0:
            # a future wait is a real block (a 0-timeout call is a poll)
            note_blocking("transfer.result")
        if not self._done.wait(timeout):
            raise TimeoutError(f"transfer job {self.label or self.job_id} "
                               f"did not finish within {timeout}s")
        if self._exc is not None:
            raise self._exc
        return self._result

    def exception(self, timeout: Optional[float] = None):
        self._done.wait(timeout)
        return self._exc

    def _finish(self, result=None, exc: Optional[BaseException] = None):
        self._result = result
        self._exc = exc
        self._done.set()


class _Job:
    __slots__ = ("fn", "args", "kwargs", "future", "deps",
                 "dest", "nbytes", "charged", "held")

    def __init__(self, fn, args, kwargs, future, deps,
                 dest=None, nbytes=0):
        self.fn = fn
        self.args = args
        self.kwargs = kwargs
        self.future = future
        self.deps: List[TransferFuture] = deps
        self.dest = dest        # destination key (or callable resolving one)
        self.nbytes = nbytes    # landing bytes (or callable resolving them)
        self.charged = 0        # bytes charged against dest while in flight
        self.held = False       # already counted in dest_holds

    def resolve(self) -> None:
        """Late-bind dest/nbytes (callables become values once deps are
        done — e.g. a reducer pull learns its node from the placement
        job)."""
        if callable(self.dest):
            self.dest = self.dest()
        if callable(self.nbytes):
            self.nbytes = int(self.nbytes())


class TransferEngine:
    """Producer/consumer job pool with dependency ordering.

    ``submit(fn, *args, after=[futs])`` enqueues a job that runs only once
    every future in ``after`` has completed; a failed dependency fails the
    dependent with ``TransferError`` instead of running it. Jobs with no
    pending dependencies go straight to the ready queue that worker threads
    drain. Dependency resolution happens on completion callbacks, never by a
    worker blocking, so the pool cannot deadlock on its own ordering.
    """

    IDLE_EXIT_S = 5.0  # workers exit after this much idleness; respawned lazily

    def __init__(self, num_workers: int = 4, name: str = "transfer",
                 dest_inflight_cap: Optional[int] = None):
        self.num_workers = num_workers
        self.name = name
        self.dest_inflight_cap = dest_inflight_cap
        self._ready: "queue.Queue[Optional[_Job]]" = queue.Queue()
        self._lock = tracked_lock("transfer.engine")
        self._pending: List[_Job] = []      # waiting on deps or dest headroom
        self._inflight = 0                  # submitted but not finished
        self._dest_bytes: dict = {}         # dest -> bytes currently in flight
        self.dest_holds = 0                 # jobs held back for dest headroom
        self._workers: List[threading.Thread] = []
        self._idle = tracked_condition("transfer.idle", self._lock)
        self._closed = False
        self._ids = itertools.count()

    # -- worker management ----------------------------------------------------
    def _ensure_workers(self) -> None:
        self._workers = [t for t in self._workers if t.is_alive()]
        while len(self._workers) < self.num_workers:
            t = threading.Thread(target=self._worker_loop, daemon=True,
                                 name=f"{self.name}-{len(self._workers)}")
            t.start()
            self._workers.append(t)

    def _worker_loop(self) -> None:
        me = threading.current_thread()
        while True:
            try:
                job = self._ready.get(timeout=self.IDLE_EXIT_S)
            except queue.Empty:
                # idle exit — but deregister under the submit lock and
                # re-check the queue there, so a submit that raced the
                # timeout either finds us still listed (we loop again) or
                # sees us gone and spawns a replacement; a job can never
                # strand between an exiting worker and _ensure_workers
                with self._lock:
                    if not self._ready.empty():
                        continue
                    if me in self._workers:
                        self._workers.remove(me)
                    return
            if job is None:  # shutdown sentinel
                return
            self._run(job)

    def _run(self, job: _Job) -> None:
        failed = next((d for d in job.deps if d.exception() is not None), None)
        try:
            if failed is not None:
                raise TransferError(
                    f"dependency {failed.label or failed.job_id} failed: "
                    f"{failed.exception()!r}")
            result = job.fn(*job.args, **job.kwargs)
        except BaseException as exc:  # noqa: BLE001 - delivered via future
            job.future._finish(exc=exc)
        else:
            job.future._finish(result=result)
        self._on_done(job)

    def _dest_admits(self, job: _Job) -> bool:
        """Per-destination in-flight cap (lock held, deps already done): a
        destination with nothing in flight always admits, otherwise the
        job's bytes must fit under the cap on top of what is in flight."""
        if self.dest_inflight_cap is None:
            return True
        job.resolve()
        if job.dest is None or job.nbytes <= 0:
            return True
        inflight = self._dest_bytes.get(job.dest, 0)
        return inflight == 0 or inflight + job.nbytes <= self.dest_inflight_cap

    def _charge(self, job: _Job) -> None:
        if self.dest_inflight_cap is not None and job.dest is not None \
                and not callable(job.dest) and job.nbytes:
            job.charged = job.nbytes
            self._dest_bytes[job.dest] = \
                self._dest_bytes.get(job.dest, 0) + job.charged

    def _try_admit(self, job: _Job) -> Optional[bool]:
        """Admission check with exception isolation (lock held): True =
        admit, False = hold for headroom, None = the job's user-supplied
        dest/nbytes callable raised — its future is failed and the job is
        terminally done (a raising callable must not kill a worker thread
        or hang ``drain`` on a leaked inflight count)."""
        try:
            return self._dest_admits(job)
        except BaseException as exc:  # noqa: BLE001 - delivered via future
            job.future._finish(exc=exc)
            return None

    def _promote_ready(self) -> None:
        """Move every pending job whose deps are done AND whose destination
        has headroom onto the ready queue (lock held). Charges destination
        bytes as jobs are admitted, so one scan can't over-admit. Admission
        per destination is FIFO: once a job for destination D is held, later
        jobs for D stay held too — otherwise a stream of small jobs could
        starve a large held one by forever eating D's headroom."""
        still_pending: List[_Job] = []
        blocked_dests = set()
        for j in self._pending:
            if not all(d.done() for d in j.deps):
                still_pending.append(j)
                continue
            admit = self._try_admit(j)
            if admit is None:
                self._inflight -= 1      # failed without running
                continue
            if admit and j.dest is not None and j.dest in blocked_dests:
                admit = False            # FIFO: an earlier job for this
                                         # dest is already held
            if not admit:
                if not j.held:           # count each held job once
                    j.held = True
                    self.dest_holds += 1
                if j.dest is not None and not callable(j.dest):
                    blocked_dests.add(j.dest)
                still_pending.append(j)
            else:
                self._charge(j)
                self._ready.put(j)
        self._pending = still_pending

    def _on_done(self, job: _Job) -> None:
        with self._lock:
            self._inflight -= 1
            if job.charged:
                left = self._dest_bytes.get(job.dest, 0) - job.charged
                if left > 0:
                    self._dest_bytes[job.dest] = left
                else:
                    self._dest_bytes.pop(job.dest, None)
            self._promote_ready()
            self._idle.notify_all()

    # -- public API ------------------------------------------------------------
    def submit(self, fn: Callable, *args,
               after: Sequence[TransferFuture] = (),
               label: str = "", dest=None, nbytes=0,
               **kwargs) -> TransferFuture:
        """Enqueue a job. ``dest``/``nbytes`` (values or callables resolved
        once deps finish) declare where the job's bytes land and how many,
        for the per-destination in-flight cap; jobs without them are
        unmetered."""
        if self._closed:
            raise RuntimeError("engine is shut down")
        future = TransferFuture(next(self._ids), label or getattr(fn, "__name__", ""))
        job = _Job(fn, args, kwargs, future, list(after),
                   dest=dest, nbytes=nbytes)
        with self._lock:
            self._inflight += 1
            self._ensure_workers()
            # one admission path for every job: append in submission order
            # and let the scan admit — it enforces deps, dest headroom, and
            # per-destination FIFO in one place (a fast path here would let
            # a newcomer slip past an earlier job the scan hasn't marked
            # held yet)
            self._pending.append(job)
            self._promote_ready()
            self._idle.notify_all()
        return future

    def map(self, fn: Callable, items: Sequence,
            after: Sequence[TransferFuture] = ()) -> List[TransferFuture]:
        return [self.submit(fn, item, after=after) for item in items]

    def drain(self, timeout: Optional[float] = None) -> None:
        """Block until every submitted job has finished."""
        with self._idle:
            if not self._idle.wait_for(lambda: self._inflight == 0,
                                       timeout=timeout):
                raise TimeoutError(
                    f"{self._inflight} transfer jobs still in flight")

    def shutdown(self) -> None:
        """Finish outstanding work, then stop the workers."""
        self.drain()
        self._closed = True
        for _ in self._workers:
            self._ready.put(None)

    def __enter__(self) -> "TransferEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()
