"""Async node-to-node transfer engine — the cluster's "network stack".

PR 1 moved every byte synchronously: ``Cluster.transfer_records`` streamed
pages inline, so reducer pulls serialized behind map finalization and behind
each other. This module extracts the two halves:

* ``copy_set`` — the mechanics: stream one locality set between two buffer
  pools page by page (paged reads on the source, sequential writes on the
  destination). ``Cluster.transfer_records`` is now one client of it.
* ``TransferEngine`` — the asynchrony: a small producer/consumer thread pool
  (BatchLoader-style) whose jobs may declare dependencies (``after=``), so a
  reducer pull can be submitted before the map side has finalized and the
  engine orders them. Workers exit after an idle timeout and are respawned on
  the next submit, so short-lived clusters in tests don't accumulate threads.

The buffer pool is internally locked (pin/unpin/new_page take the pool's
RLock), which is what makes concurrent pulls through shared source pools safe.
"""
from __future__ import annotations

import itertools
import queue
import threading
from typing import Callable, List, Optional, Sequence

import numpy as np

from ..core.attributes import AttributeSet
from ..core.services import PageIterator, SequentialWriter


def copy_set(src_pool, src_set_name: str, dst_pool, dst_set_name: str,
             dtype: np.dtype, page_size: int,
             attrs: Optional[AttributeSet] = None) -> int:
    """Stream one locality set between pools page by page; returns bytes
    moved. This is the wire: a paged read on the source feeding a sequential
    write on the destination. Each in-flight chunk is charged to the
    destination's MemoryManager (``reserve``) so replica creation and
    recovery copies show up in the same pressure accounting as shuffle pulls
    and remesh streams."""
    dtype = np.dtype(dtype)
    ls_src = src_pool.get_set(src_set_name)
    ls_dst = dst_pool.create_set(dst_set_name, page_size, attrs)
    writer = SequentialWriter(dst_pool, ls_dst, dtype)
    memory = getattr(dst_pool, "memory", None)
    moved = 0
    for recs in PageIterator(src_pool, ls_src, dtype, sorted(ls_src.pages)):
        reservation = memory.reserve(recs.nbytes) if memory is not None else None
        try:
            writer.append_batch(recs)
        finally:
            if reservation is not None:
                reservation.release()
        moved += recs.nbytes
    writer.close()
    return moved


class TransferError(RuntimeError):
    """A transfer job failed because one of its dependencies failed."""


class TransferFuture:
    """Result handle for a submitted transfer job."""

    def __init__(self, job_id: int, label: str = ""):
        self.job_id = job_id
        self.label = label
        self._done = threading.Event()
        self._result = None
        self._exc: Optional[BaseException] = None

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: Optional[float] = None):
        if not self._done.wait(timeout):
            raise TimeoutError(f"transfer job {self.label or self.job_id} "
                               f"did not finish within {timeout}s")
        if self._exc is not None:
            raise self._exc
        return self._result

    def exception(self, timeout: Optional[float] = None):
        self._done.wait(timeout)
        return self._exc

    def _finish(self, result=None, exc: Optional[BaseException] = None):
        self._result = result
        self._exc = exc
        self._done.set()


class _Job:
    __slots__ = ("fn", "args", "kwargs", "future", "deps")

    def __init__(self, fn, args, kwargs, future, deps):
        self.fn = fn
        self.args = args
        self.kwargs = kwargs
        self.future = future
        self.deps: List[TransferFuture] = deps


class TransferEngine:
    """Producer/consumer job pool with dependency ordering.

    ``submit(fn, *args, after=[futs])`` enqueues a job that runs only once
    every future in ``after`` has completed; a failed dependency fails the
    dependent with ``TransferError`` instead of running it. Jobs with no
    pending dependencies go straight to the ready queue that worker threads
    drain. Dependency resolution happens on completion callbacks, never by a
    worker blocking, so the pool cannot deadlock on its own ordering.
    """

    IDLE_EXIT_S = 5.0  # workers exit after this much idleness; respawned lazily

    def __init__(self, num_workers: int = 4, name: str = "transfer"):
        self.num_workers = num_workers
        self.name = name
        self._ready: "queue.Queue[Optional[_Job]]" = queue.Queue()
        self._lock = threading.Lock()
        self._pending: List[_Job] = []      # jobs waiting on dependencies
        self._inflight = 0                  # submitted but not finished
        self._workers: List[threading.Thread] = []
        self._idle = threading.Condition(self._lock)
        self._closed = False
        self._ids = itertools.count()

    # -- worker management ----------------------------------------------------
    def _ensure_workers(self) -> None:
        self._workers = [t for t in self._workers if t.is_alive()]
        while len(self._workers) < self.num_workers:
            t = threading.Thread(target=self._worker_loop, daemon=True,
                                 name=f"{self.name}-{len(self._workers)}")
            t.start()
            self._workers.append(t)

    def _worker_loop(self) -> None:
        me = threading.current_thread()
        while True:
            try:
                job = self._ready.get(timeout=self.IDLE_EXIT_S)
            except queue.Empty:
                # idle exit — but deregister under the submit lock and
                # re-check the queue there, so a submit that raced the
                # timeout either finds us still listed (we loop again) or
                # sees us gone and spawns a replacement; a job can never
                # strand between an exiting worker and _ensure_workers
                with self._lock:
                    if not self._ready.empty():
                        continue
                    if me in self._workers:
                        self._workers.remove(me)
                    return
            if job is None:  # shutdown sentinel
                return
            self._run(job)

    def _run(self, job: _Job) -> None:
        failed = next((d for d in job.deps if d.exception() is not None), None)
        try:
            if failed is not None:
                raise TransferError(
                    f"dependency {failed.label or failed.job_id} failed: "
                    f"{failed.exception()!r}")
            result = job.fn(*job.args, **job.kwargs)
        except BaseException as exc:  # noqa: BLE001 - delivered via future
            job.future._finish(exc=exc)
        else:
            job.future._finish(result=result)
        self._on_done()

    def _on_done(self) -> None:
        with self._lock:
            self._inflight -= 1
            newly_ready = [j for j in self._pending
                           if all(d.done() for d in j.deps)]
            for j in newly_ready:
                self._pending.remove(j)
                self._ready.put(j)
            self._idle.notify_all()

    # -- public API ------------------------------------------------------------
    def submit(self, fn: Callable, *args,
               after: Sequence[TransferFuture] = (),
               label: str = "", **kwargs) -> TransferFuture:
        if self._closed:
            raise RuntimeError("engine is shut down")
        future = TransferFuture(next(self._ids), label or getattr(fn, "__name__", ""))
        job = _Job(fn, args, kwargs, future, list(after))
        with self._lock:
            self._inflight += 1
            self._ensure_workers()
            if all(d.done() for d in job.deps):
                self._ready.put(job)
            else:
                self._pending.append(job)
        return future

    def map(self, fn: Callable, items: Sequence,
            after: Sequence[TransferFuture] = ()) -> List[TransferFuture]:
        return [self.submit(fn, item, after=after) for item in items]

    def drain(self, timeout: Optional[float] = None) -> None:
        """Block until every submitted job has finished."""
        with self._idle:
            if not self._idle.wait_for(lambda: self._inflight == 0,
                                       timeout=timeout):
                raise TimeoutError(
                    f"{self._inflight} transfer jobs still in flight")

    def shutdown(self) -> None:
        """Finish outstanding work, then stop the workers."""
        self.drain()
        self._closed = True
        for _ in self._workers:
            self._ready.put(None)

    def __enter__(self) -> "TransferEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()
