"""Elastic scaling: after failures, pick the largest viable mesh from the
survivors and restart from checkpoint (restore is mesh-agnostic — shards are
reassembled then resharded to the new mesh's PartitionSpecs)."""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple


def surviving_node_ids(total_hosts: int,
                       dead_hosts: Sequence[int]) -> List[int]:
    """The shrunk placement domain after unrecoverable losses: the alive node
    ids in order. Sharded sets are re-partitioned over exactly this list by
    the cluster's remesh-degrade path."""
    dead = set(dead_hosts)
    return [h for h in range(total_hosts) if h not in dead]


def remesh_partition_plan(old_num_partitions: int, old_domain_size: int,
                          survivors: Sequence[int]) -> Tuple[int, int]:
    """How a sharded set re-partitions onto the shrunk membership: keep the
    per-node partition density of the old layout, scaled to the survivor
    count. Returns ``(partitions_per_node, new_num_partitions)``."""
    per_node = max(1, old_num_partitions // max(1, old_domain_size))
    return per_node, per_node * len(survivors)


def surviving_mesh_shape(n_alive: int,
                         prefer_model: int = 16) -> Tuple[int, int]:
    """Largest (data, model) grid with model | prefer_model using <= n_alive
    chips. Keeps the model axis a power-of-two divisor of the preferred TP
    degree so checkpoint layouts stay divisible."""
    model = prefer_model
    while model > 1:
        data = n_alive // model
        if data >= 1:
            return (data, model)
        model //= 2
    return (max(n_alive, 1), 1)


def plan_remesh(total_hosts: int, dead_hosts: Sequence[int],
                chips_per_host: int = 4,
                prefer_model: int = 16) -> dict:
    """Failure-response plan: new mesh + which checkpoint layout to restore
    from + which dataset shards must be re-dispatched (the paper's recovery,
    at the training-runtime level)."""
    alive = total_hosts - len(set(dead_hosts))
    chips = alive * chips_per_host
    data, model = surviving_mesh_shape(chips, prefer_model)
    return {
        "alive_hosts": alive,
        "mesh_shape": (data, model),
        "utilized_chips": data * model,
        "idle_chips": chips - data * model,
        "restore_layout": "row" if data >= model else "col",
        "redispatch_shards": sorted(set(dead_hosts)),
    }
