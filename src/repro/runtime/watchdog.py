"""Fault-tolerance runtime: host heartbeats, straggler detection, collective
watchdog. On a real cluster the heartbeat transport is the coordinator
(jax.distributed); here hosts are simulated processes/threads — the policy
logic (what to do when) is what this module owns and what the tests cover.
"""
from __future__ import annotations

import contextlib
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set

from ..core.sanitizer import tracked_lock


class HostMonitor:
    """Heartbeat table. A host missing ``timeout`` seconds is declared dead;
    registered callbacks receive the failure set (runtime drives elastic
    remesh + replica recovery from there)."""

    def __init__(self, hosts: List[int], timeout: float = 10.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout
        self.clock = clock
        self._last: Dict[int, float] = {h: clock() for h in hosts}
        self._dead: Set[int] = set()
        self._callbacks: List[Callable[[Set[int]], None]] = []
        self._lock = tracked_lock("watchdog")

    def heartbeat(self, host: int) -> None:
        with self._lock:
            if host not in self._dead:
                self._last[host] = self.clock()

    def on_failure(self, cb: Callable[[Set[int]], None]) -> None:
        self._callbacks.append(cb)

    def check(self) -> Set[int]:
        """Returns newly dead hosts (and fires callbacks)."""
        now = self.clock()
        newly: Set[int] = set()
        with self._lock:
            for h, t in self._last.items():
                if h not in self._dead and now - t > self.timeout:
                    newly.add(h)
            self._dead |= newly
        if newly:
            for cb in self._callbacks:
                cb(set(newly))
        return newly

    @property
    def alive(self) -> List[int]:
        return sorted(h for h in self._last if h not in self._dead)

    @property
    def dead(self) -> Set[int]:
        return set(self._dead)


class StepTimer:
    """Per-host step-time EWMA; hosts slower than mean + k·std are
    stragglers. The data pipeline re-dispatches a straggler's pending pages
    to its backup (paper-style backup tasks, at page granularity)."""

    def __init__(self, hosts: List[int], alpha: float = 0.2, k: float = 3.0,
                 min_samples: int = 5):
        self.alpha = alpha
        self.k = k
        self.min_samples = min_samples
        self.ewma: Dict[int, float] = {h: 0.0 for h in hosts}
        self.count: Dict[int, int] = {h: 0 for h in hosts}

    def record(self, host: int, step_time: float) -> None:
        c = self.count.get(host, 0)
        self.ewma[host] = (step_time if c == 0
                           else (1 - self.alpha) * self.ewma[host]
                           + self.alpha * step_time)
        self.count[host] = c + 1

    @contextlib.contextmanager
    def time(self, host: int,
             clock: Callable[[], float] = time.perf_counter):
        """Time a block of work on ``host`` and record it as one step — how
        the cluster map phase feeds the straggler detector."""
        t0 = clock()
        try:
            yield
        finally:
            self.record(host, clock() - t0)

    def stragglers(self, min_samples: Optional[int] = None) -> List[int]:
        """Robust detection: median + k * 1.4826 * MAD (a lone extreme host
        can't inflate the threshold the way it inflates a stddev), with a
        20%-of-median floor so benign jitter never triggers. ``min_samples``
        overrides the instance default — one-shot phases (a single map pass
        per host) pass 1, long-running pipelines keep the warmup guard."""
        need = self.min_samples if min_samples is None else min_samples
        ready = [h for h, c in self.count.items() if c >= need]
        if len(ready) < 2:
            return []
        vals = sorted(self.ewma[h] for h in ready)
        med = vals[len(vals) // 2]
        mad = sorted(abs(v - med) for v in vals)[len(vals) // 2]
        thr = med + max(self.k * 1.4826 * mad, 0.2 * med) + 1e-12
        return [h for h in ready if self.ewma[h] > thr]


class CollectiveWatchdog:
    """Context manager that bounds how long a collective may take; on
    timeout it invokes ``on_timeout`` (abort + checkpoint-restart on a real
    cluster). Used around blocking cross-host operations."""

    def __init__(self, timeout: float, on_timeout: Callable[[], None]):
        self.timeout = timeout
        self.on_timeout = on_timeout
        self._timer: Optional[threading.Timer] = None
        self.fired = False

    def __enter__(self):
        def fire():
            self.fired = True
            self.on_timeout()
        self._timer = threading.Timer(self.timeout, fire)
        self._timer.daemon = True
        self._timer.start()
        return self

    def __exit__(self, *exc):
        if self._timer:
            self._timer.cancel()
        return False
