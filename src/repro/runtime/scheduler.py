"""Cluster scheduler — placement policy extracted from the cluster mechanics.

The paper's monolithic-storage argument (§1, §9.2.2) is that because one
storage layer sees everything — replica partitionings in the statistics
database, map-output locality, liveness — it can make the placement decisions
that layered stacks (Spark over Alluxio over HDFS) each make blindly. This
module owns those decisions; ``runtime/cluster.py`` owns the mechanics and
asks the scheduler where to put things:

* **Reducer placement** (``place_reducers``) — reducer ``r`` lands on the
  node already holding the most map-output bytes for partition ``r``
  (``StatisticsDB.shuffle_partition_bytes``), instead of the naive ``r % N``;
  a node's bytes are discounted by its published memory pressure, so a node
  that is already spilling deliberately trades network bytes for not paging.
  Absent pressure, ties prefer the baseline node so placement is never worse
  than round-robin.
* **Shuffle elision** (``plan_aggregation``) — when the input sharded set is
  already partitioned on the aggregation key (``stats.best_replica`` finds a
  co-partitioned replica), the shuffle is skipped outright: every node
  aggregates its own shard and the merge is disjoint. net_bytes == 0.
* **Join planning** (``plan_join``) — the §9.2.2 flagship: an equi-join
  shuffles *only the non-co-partitioned side* (or neither, when a
  co-partitioned replica pair is registered), routing the moving side by the
  stationary side's own storage scheme; when both sides must move, reducer
  placement follows the combined byte statistics with the same pressure
  discount as aggregation.
* **Admission-checked placement** (``place_reducers_admitted`` /
  ``place_join_reducers_admitted``, PR 5) — before a reducer is pinned, the
  chosen node's ``MemoryManager`` must *admit* the partition's landing bytes
  (``AdmissionController.admit_placement``); a node that refuses past the
  deadline loses the partition to the next-best byte-locality candidate, and
  the diversion is recorded in the returned ``PlacementPlan``. Placement
  also reads pressure through ``node_pressure_current`` — the recorded
  snapshot while fresh, the node's live score once any topology/job event
  has made the snapshot stale.
* **Read-source selection** (``read_sources``) — reads of a dead owner's
  shard are routed to a surviving CRC-verified replica holder rather than
  failing.
* **Straggler re-execution** (``backup_source``) — a mapper flagged by the
  ``watchdog.StepTimer`` gets its partitions re-executed on a node holding a
  replica of its shard (backup tasks from replica holders, paper §7 applied
  to execution; ``ClusterShuffle.reexecute_stragglers`` drives it).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.statistics import ReplicaInfo


# preference order among sources whose costs tie: a direct local copy, then
# local disk, then a network copy, then a full re-partition
_KIND_RANK = {"primary": 0, "pagelog": 1, "replica": 2, "rebuild": 3}


@dataclass
class RecoverySource:
    """One costed way to re-materialize a shard (scheduler recovery plan).

    ``kind`` is ``"primary"``/``"replica"`` for a direct page-for-page copy
    from a surviving set, ``"pagelog"`` for replaying the revived owner's
    own durable page log (PR 6 — zero network bytes, only local disk reads),
    or ``"rebuild"`` for re-running the partitioner over a heterogeneously
    partitioned replica of the same logical data
    (``core/replication.recover_target_shard``). ``cost_bytes`` is the bytes
    that must cross the network to execute it; ``disk_bytes`` the bytes that
    must come off the target's local disk (discounted by the scheduler's
    ``disk_byte_cost`` — disk is cheaper than the wire but not free);
    ``pressure`` is the source node's memory-pressure score (tie-breaker:
    don't read a shard off a node that is busy spilling)."""

    kind: str
    holder: Optional[int]
    set_name: Optional[str]
    cost_bytes: int
    pressure: float = 0.0
    replica_of: Optional[str] = None   # rebuild: the sharded set to read
    disk_bytes: int = 0                # pagelog: bytes replayed off local disk

    def effective_cost(self, disk_byte_cost: float) -> int:
        return self.cost_bytes + int(disk_byte_cost * self.disk_bytes)

    @property
    def sort_key(self) -> Tuple:
        return (self.cost_bytes, self.pressure, _KIND_RANK[self.kind],
                -1 if self.holder is None else self.holder)


@dataclass
class PlacementPlan:
    """An admission-checked reducer placement (PR 5): the final assignment
    plus every diversion the admission loop made — ``diversions[r]`` is
    ``(refused_node, placed_node)`` for a reducer whose byte-locality choice
    refused admission past the deadline and was re-placed on the next-best
    candidate. ``refusals`` counts every candidate that refused along the
    way (a reducer may be refused by several nodes before landing)."""

    placement: Dict[int, int]
    diversions: Dict[int, Tuple[int, int]]
    refusals: int = 0

    @property
    def diverted(self) -> int:
        return len(self.diversions)


@dataclass
class AggregationPlan:
    """How an aggregation over a sharded set should execute."""

    co_partitioned: bool
    replica: Optional[ReplicaInfo] = None
    target_name: Optional[str] = None   # the sharded set to actually read

    @property
    def shuffle_free(self) -> bool:
        return self.co_partitioned


@dataclass
class JoinPlan:
    """How a two-sided equi-join should execute (paper §9.2.2).

    ``build_name``/``probe_name`` are the sharded sets to actually read —
    possibly co-partitioned replicas of the handles the query came in with
    (``stats.best_replica`` routing, same as ``plan_aggregation``).
    ``shuffle_sides`` lists which sides must move: empty when both sides are
    co-partitioned *and aligned* (same partition count, same placement
    domain), one side when the other can anchor the join in place, both only
    when neither side is partitioned on the key. ``anchor`` names the
    stationary side (``"build"``/``"probe"``) for the one-side case — the
    shuffled side is routed by the anchor's own storage scheme, so matching
    keys land exactly where the anchor's shards already sit."""

    key_field: str
    build_name: str
    probe_name: str
    shuffle_sides: Tuple[str, ...]      # () | 1 side | ("build", "probe")
    anchor: Optional[str] = None        # stationary side for one-side shuffles
    build_bytes: int = 0
    probe_bytes: int = 0

    @property
    def shuffle_free(self) -> bool:
        return not self.shuffle_sides


class ClusterScheduler:
    """Placement decisions over a ``Cluster`` (duck-typed: anything with
    ``nodes``, ``alive_node_ids()`` and ``stats``)."""

    #: relative price of a byte read from the recovery target's local disk
    #: versus a byte pulled over the network (recovery costing, PR 6). At the
    #: default a warm log replay beats any remote copy of the same bytes but
    #: still loses to a copy already sitting in the target's pool, and a
    #: sufficiently small replica pull can out-cost a huge disk replay.
    disk_byte_cost: float = 0.25

    def __init__(self, cluster):
        self.cluster = cluster

    # -- reducer placement -----------------------------------------------------
    def baseline_placement(self, num_reducers: int) -> Dict[int, int]:
        """The PR-1 policy: round-robin over the alive membership."""
        alive = self.cluster.alive_node_ids()
        return {r: alive[r % len(alive)] for r in range(num_reducers)}

    def node_pressure_current(self, node_id: int) -> float:
        """The pressure score placement should trust *now*: the recorded
        snapshot while it is fresh, else the node's live
        ``MemoryManager.pressure_score()`` (PR-5 bugfix — pressure is
        published at shuffle finalization, so back-to-back jobs used to plan
        against the previous job's snapshot; any topology/job event since
        the recording invalidates it)."""
        fresh = self.cluster.stats.node_pressure_fresh(node_id)
        if fresh is not None:
            return fresh
        return self.node_pressure_live(node_id)

    def _rank_candidates(self, shuffle_names: Sequence[str], r: int,
                         base: int) -> Tuple[List[int], int]:
        """Alive candidate nodes for reducer ``r``, best byte-locality first
        (pressure-discounted), plus the partition's total map-output bytes.
        Falls back to ``[base]`` when no byte statistics exist."""
        stats = self.cluster.stats
        by_node: Dict[int, int] = {}
        for name in shuffle_names:
            for n, b in stats.shuffle_partition_bytes(name, r).items():
                if self.cluster.nodes[n].alive:
                    by_node[n] = by_node.get(n, 0) + b
        total = sum(by_node.values())
        if not by_node:
            return [base], total
        score = {n: b * (1.0 - self.node_pressure_current(n))
                 for n, b in by_node.items()}
        ranked = sorted(score, key=lambda n: (score[n], n == base, -n),
                        reverse=True)
        return ranked, total

    def _place_by_bytes(self, shuffle_names: Sequence[str],
                        num_reducers: int) -> Dict[int, int]:
        """The placement core shared by aggregation and join shuffles:
        reducer ``r`` goes to the alive node holding the most map-output
        bytes for partition ``r``, summed over every named shuffle,
        pressure-discounted; ties fall back to the baseline node."""
        placement = self.baseline_placement(num_reducers)
        for r in range(num_reducers):
            ranked, _total = self._rank_candidates(shuffle_names, r,
                                                   placement[r])
            placement[r] = ranked[0]
        return placement

    def _place_admitted(self, shuffle_names: Sequence[str],
                        num_reducers: int,
                        deadline_s: float) -> PlacementPlan:
        """Admission-checked placement (the PR-5 control loop's re-route
        step): walk each reducer's byte-locality ranking and pin it to the
        first candidate whose MemoryManager admits the partition's landing
        bytes within ``deadline_s``. A refusal past the deadline diverts the
        partition to the next-best candidate and is recorded in the plan;
        when every candidate refuses, the byte-heaviest keeps the reducer
        (someone must run it — the pool spills rather than fails).

        Candidates beyond the byte holders count too: a node holding zero
        map output but with admission headroom is a better home than a full
        byte-local node — it pays the partition's bytes on the wire once
        instead of spilling them through a saturated pool — so the ranking
        is extended with the remaining alive nodes, least-pressured first."""
        placement = self.baseline_placement(num_reducers)
        plan = PlacementPlan(placement=placement, diversions={})
        # a node that already refused during THIS planning pass gets only a
        # non-blocking probe for later reducers — without the memo, one
        # persistently pressured byte-heavy node would cost the full
        # deadline serially for every reducer planned onto it
        refused_once: set = set()
        # bytes this pass has already planned onto each node: admission is
        # probed against live occupancy, so without this a node with
        # headroom for ONE partition would be granted all of them and the
        # pulls would spill exactly the way always-grant does
        planned: Dict[int, int] = {}
        for r in range(num_reducers):
            ranked, total = self._rank_candidates(shuffle_names, r,
                                                  placement[r])
            ranked = ranked + sorted(
                (n for n in self.cluster.alive_node_ids()
                 if n not in ranked),
                key=lambda n: (self.node_pressure_live(n), n))
            chosen = ranked[0]
            for candidate in ranked:
                node = self.cluster.nodes[candidate]
                memory = node.memory if node.alive else None
                first_probe = candidate not in refused_once
                ask = total + planned.get(candidate, 0)
                if memory is None or memory.admission.admit_placement(
                        ask, deadline_s=deadline_s if first_probe else 0.0,
                        count=first_probe):
                    chosen = candidate
                    break
                refused_once.add(candidate)
                plan.refusals += 1
            placement[r] = chosen
            planned[chosen] = planned.get(chosen, 0) + total
            if chosen != ranked[0]:
                plan.diversions[r] = (ranked[0], chosen)
        return plan

    # -- serving-sequence placement (PR 9) -------------------------------------
    def place_sequences(self, asks: Dict[int, Tuple[int, int]],
                        deadline_s: float = 0.0) -> PlacementPlan:
        """Admission-checked placement for serving sequences: ``asks`` maps
        ``seq_id -> (affinity_node, kv_bytes)``. Session affinity makes the
        hashed home node the top candidate (its pool may already hold the
        session's KV pages); the ranking extends with the remaining alive
        nodes, least live pressure first, exactly like the reducer re-route
        loop. A refusal past ``deadline_s`` diverts the prefill to the next
        admitting node (``plan.diversions[seq] = (affinity, chosen)``); when
        every candidate refuses, the affinity node keeps the sequence — the
        serving pool degrades to spill, it does not drop a session."""
        plan = PlacementPlan(placement={}, diversions={})
        refused_once: set = set()
        planned: Dict[int, int] = {}
        for seq_id, (affinity, nbytes) in asks.items():
            ranked = ([affinity] if self.cluster.nodes[affinity].alive
                      else [])
            ranked = ranked + sorted(
                (n for n in self.cluster.alive_node_ids()
                 if n not in ranked),
                key=lambda n: (self.node_pressure_live(n), n))
            if not ranked:
                raise ValueError("no alive nodes to place sequences on")
            chosen = ranked[0]
            for candidate in ranked:
                node = self.cluster.nodes[candidate]
                memory = node.memory if node.alive else None
                first_probe = candidate not in refused_once
                ask = nbytes + planned.get(candidate, 0)
                if memory is None or memory.admission.admit_placement(
                        ask, deadline_s=deadline_s if first_probe else 0.0,
                        count=first_probe):
                    chosen = candidate
                    break
                refused_once.add(candidate)
                plan.refusals += 1
            plan.placement[seq_id] = chosen
            planned[chosen] = planned.get(chosen, 0) + nbytes
            if chosen != ranked[0]:
                plan.diversions[seq_id] = (ranked[0], chosen)
        return plan

    def place_reducers(self, shuffle_name: str,
                       num_reducers: int) -> Dict[int, int]:
        """Locality-aware placement: reducer ``r`` goes to the alive node
        holding the most map-output bytes for partition ``r``. Per-reducer
        cross-node traffic is ``total_bytes(r) - bytes_on(chosen)``, so the
        byte-heaviest choice minimizes it; ties fall back to the baseline
        node, which (absent pressure) makes the plan never worse than
        round-robin.

        Bytes are discounted by the node's published memory-pressure score
        (``StatisticsDB.node_pressure``, fed from each node's MemoryManager
        at map finalization): a node already spilling its pool would pay for
        reducer input with page faults, so locality there is worth less —
        at score 1.0 it is worth nothing and the reducer lands elsewhere.
        That is a deliberate trade of network bytes for fault avoidance, so
        under pressure the plan may ship more bytes than round-robin
        would."""
        return self._place_by_bytes([shuffle_name], num_reducers)

    def place_reducers_admitted(self, shuffle_name: str, num_reducers: int,
                                deadline_s: float = 0.05) -> PlacementPlan:
        """``place_reducers`` plus admission: each reducer's chosen node must
        admit the partition's landing bytes (``AdmissionController
        .admit_placement``) within ``deadline_s``, else the partition is
        diverted to the next-best byte-locality candidate and the diversion
        recorded in the returned plan."""
        return self._place_admitted([shuffle_name], num_reducers, deadline_s)

    def placement_net_bytes(self, shuffle_name: str,
                            placement: Dict[int, int]) -> int:
        """Predicted cross-node bytes for a reducer placement (what the
        benchmark reports next to the measured figure)."""
        stats = self.cluster.stats
        total = 0
        for r, node in placement.items():
            by_node = stats.shuffle_partition_bytes(shuffle_name, r)
            total += sum(b for n, b in by_node.items() if n != node)
        return total

    # -- shuffle elision -------------------------------------------------------
    def plan_aggregation(self, sset, key_field: str) -> AggregationPlan:
        """Co-partitioned input aggregates shard-locally with zero network
        bytes; otherwise shuffle, with reducer placement decided after the
        map phase (it needs the byte statistics maps produce).

        ``stats.best_replica`` is consulted for the *logical* dataset, so a
        heterogeneously partitioned replica set (same records, partitioned on
        ``key_field``, registered via ``Cluster.register_replica_set``) makes
        the query shuffle-free even when the set handed in is not — the
        paper's "select a Pangea replica that is the best for the query"."""
        target, co, replica = self._resolve_side(sset, key_field)
        return AggregationPlan(co_partitioned=co, replica=replica,
                               target_name=target.name)

    # -- join planning (paper §9.2.2: shuffle only the non-co side) ------------
    def _resolve_side(self, sset, key_field: str):
        """Route one query input through the replica catalog: prefer a
        co-partitioned replica of the same logical dataset (the paper's
        "select a Pangea replica that is the best for the query"). Shared by
        aggregation and join planning; returns ``(target_set, co,
        replica_info)``."""
        replica = self.cluster.stats.best_replica(sset.name, key_field)
        target = sset
        if (replica is not None and replica.partition_key == key_field
                and replica.set_name != sset.name):
            alt = self.cluster.catalog.get(replica.set_name)
            if alt is not None and alt.partition_key == key_field:
                target = alt
        co = (replica is not None and replica.partition_key == key_field
              and target.partition_key == key_field)
        return target, co, replica

    def set_bytes(self, sset) -> int:
        """Catalog-metadata size of a sharded set (what join planning costs
        sides by — no data is read to make the plan)."""
        return sum(info.num_records for info in sset.shards.values()) \
            * sset.dtype.itemsize

    @staticmethod
    def _aligned(a, b) -> bool:
        """Two sets partitioned on the same key route every key to the same
        node iff they share the partition count and the placement domain (the
        hash is deterministic, so that is the whole condition)."""
        return (a.scheme.num_partitions == b.scheme.num_partitions
                and list(a.node_ids) == list(b.node_ids))

    def plan_join(self, build_sset, probe_sset, key_field: str) -> JoinPlan:
        """Decide placement and movement for an equi-join on ``key_field``:

        * both sides co-partitioned and aligned → shuffle *neither*; every
          node joins its own build/probe shard pair (net_bytes == 0);
        * exactly one side co-partitioned → it anchors the join; only the
          non-co side is shuffled, routed by the anchor's storage scheme;
        * both co-partitioned but misaligned (different partition counts or
          placement domains) → the byte-heavier side anchors and only the
          *smaller* side moves;
        * neither co-partitioned → both sides shuffle to a common hash
          layout; reducer placement then follows the combined byte statistics
          with the usual memory-pressure discount
          (``place_join_reducers``)."""
        bt, bco, _ = self._resolve_side(build_sset, key_field)
        pt, pco, _ = self._resolve_side(probe_sset, key_field)
        bb, pb = self.set_bytes(bt), self.set_bytes(pt)
        plan = JoinPlan(key_field=key_field, build_name=bt.name,
                        probe_name=pt.name, shuffle_sides=(),
                        build_bytes=bb, probe_bytes=pb)
        if bco and pco and self._aligned(bt, pt):
            return plan
        if bco and pco:
            # both partitioned on the key, but not onto the same layout:
            # anchor the heavier side, move only the smaller one
            anchor = "build" if bb >= pb else "probe"
        elif bco:
            anchor = "build"
        elif pco:
            anchor = "probe"
        else:
            plan.shuffle_sides = ("build", "probe")
            return plan
        plan.anchor = anchor
        plan.shuffle_sides = ("probe",) if anchor == "build" else ("build",)
        return plan

    def place_join_reducers(self, build_shuffle: str, probe_shuffle: str,
                            num_reducers: int) -> Dict[int, int]:
        """Reducer placement for a both-sides-shuffled join: reducer ``r``
        lands on the alive node holding the most *combined* build+probe
        map-output bytes for partition ``r``, discounted by published memory
        pressure — ``place_reducers`` over two byte maps that must
        co-locate."""
        return self._place_by_bytes([build_shuffle, probe_shuffle],
                                    num_reducers)

    def place_join_reducers_admitted(self, build_shuffle: str,
                                     probe_shuffle: str, num_reducers: int,
                                     deadline_s: float = 0.05
                                     ) -> PlacementPlan:
        """``place_join_reducers`` with the same admission check and
        re-routing as ``place_reducers_admitted`` (the landing ask is the
        combined build+probe partition bytes)."""
        return self._place_admitted([build_shuffle, probe_shuffle],
                                    num_reducers, deadline_s)

    # -- read-source selection -------------------------------------------------
    def _holds(self, node_id: int, set_name: str) -> bool:
        """An alive node physically holding the set (a freshly revived node
        mid-recovery is alive but empty — it must not serve reads yet)."""
        node = self.cluster.nodes[node_id]
        return (node.alive and node.pool is not None
                and set_name in node.pool.paging.sets)

    def read_sources(self, sset, node_id: int) -> List[Tuple[int, str]]:
        """Candidate locations for shard ``node_id`` of ``sset``, best first:
        the primary when its owner is alive and holds it, then every alive
        replica holder. The cluster walks these in order, CRC-verifying
        replica reads."""
        info = sset.shards[node_id]
        sources: List[Tuple[int, str]] = []
        if self._holds(node_id, info.set_name):
            sources.append((node_id, info.set_name))
        sources.extend((holder, rep_name)
                       for holder, rep_name in info.replicas
                       if self._holds(holder, rep_name))
        return sources

    # -- recovery source costing (ROADMAP "Recovery source costing") -----------
    def node_pressure_live(self, node_id: int) -> float:
        """Current MemoryManager pressure score of an alive node (0 for dead
        nodes — they have no pool to pressure)."""
        node = self.cluster.nodes.get(node_id)
        memory = node.memory if node is not None and node.alive else None
        if memory is None:
            return 0.0
        return memory.pressure_score()

    def _shard_bytes(self, sset, info) -> int:
        return info.num_records * sset.dtype.itemsize

    def recovery_plan(self, sset, shard_id: int,
                      target_node: int) -> List[RecoverySource]:
        """Every way to re-materialize ``sset``'s shard ``shard_id`` onto
        ``target_node``, cheapest first. Candidates:

        * the alive primary / each alive replica holder — a page-for-page
          copy; costs the shard's bytes when the holder is remote, zero when
          the bytes are already on the target;
        * the target's own durable page log (PR 6) — when the target IS the
          shard's owner and its replayed log still indexes the set at a
          non-stale epoch, the shard can be adopted from local disk; zero
          network bytes, the replay bytes priced at ``disk_byte_cost`` each;
        * a heterogeneously partitioned replica of the same logical dataset
          (``Cluster.register_replica_set``) — rebuild by re-running the
          partitioner over its readable shards
          (``core/replication.recover_target_shard``); costs every remote
          byte of that replica set, since each shard must be scanned. An
          alt shard unreadable *because it sat on the failed node itself* is
          still viable when a conflicting-object guard covers it.

        Ties break toward the source node with the lowest live memory
        pressure: reading a shard off a node that is busy spilling faults
        its pool on every page."""
        info = sset.shards[shard_id]
        shard_bytes = self._shard_bytes(sset, info)
        plan: List[RecoverySource] = []
        if self._holds(shard_id, info.set_name):
            plan.append(RecoverySource(
                kind="primary", holder=shard_id, set_name=info.set_name,
                cost_bytes=0 if shard_id == target_node else shard_bytes,
                pressure=self.node_pressure_live(shard_id)))
        log_bytes = self._pagelog_bytes(sset, info, shard_id, target_node)
        if log_bytes is not None:
            plan.append(RecoverySource(
                kind="pagelog", holder=target_node, set_name=info.set_name,
                cost_bytes=0, disk_bytes=log_bytes,
                pressure=self.node_pressure_live(target_node)))
        for holder, rep_name in info.replicas:
            if not self._holds(holder, rep_name):
                continue
            plan.append(RecoverySource(
                kind="replica", holder=holder, set_name=rep_name,
                cost_bytes=0 if holder == target_node else shard_bytes,
                pressure=self.node_pressure_live(holder)))
        guard_fn = getattr(self.cluster, "conflict_guard", None)
        for rinfo in self.cluster.stats.replicas_of(sset.name):
            alt = self.cluster.catalog.get(rinfo.set_name)
            if alt is None or alt is sset or alt.name == sset.name:
                continue
            cost = 0
            readable = True
            pressures = [0.0]
            for n, ainfo in alt.shards.items():
                sources = self.read_sources(alt, n)
                if not sources:
                    # paper-§7 conflicting objects: the alt's shard on the
                    # failed node is the one shard the rebuild can substitute
                    # — the guard copy holds exactly the records both
                    # partitionings routed there, which are exactly the ones
                    # this target shard needs from it
                    guard = (guard_fn(sset.name, alt.name, n)
                             if guard_fn is not None else None)
                    if guard is not None and n == shard_id:
                        if guard.holder != target_node:
                            cost += guard.num_records * sset.dtype.itemsize
                        pressures.append(
                            self.node_pressure_live(guard.holder))
                        continue
                    readable = False
                    break
                holder = sources[0][0]
                if holder != target_node:
                    cost += self._shard_bytes(alt, ainfo)
                pressures.append(self.node_pressure_live(holder))
            if readable:
                plan.append(RecoverySource(
                    kind="rebuild", holder=None, set_name=None,
                    cost_bytes=cost, pressure=max(pressures),
                    replica_of=alt.name))
        plan.sort(key=lambda s: (s.effective_cost(self.disk_byte_cost),
                                 s.pressure, _KIND_RANK[s.kind],
                                 -1 if s.holder is None else s.holder))
        return plan

    def _pagelog_bytes(self, sset, info, shard_id: int,
                       target_node: int) -> Optional[int]:
        """Bytes the recovery target could replay from its local page log
        for this shard, or None when the log has nothing usable. The target
        must BE the shard's owner (logs are per-node — no other node's log
        ever held these pages), alive with the durable tier configured, and
        the replayed entries must carry an epoch at least the cataloged
        shard's (the revival fence: log state from before a drop/re-shard
        must not resurrect)."""
        if target_node != shard_id:
            return None
        node = self.cluster.nodes.get(target_node)
        memory = node.memory if node is not None and node.alive else None
        if memory is None:
            return None
        log = memory.pagelog
        if log is None or not log.entries_for(info.set_name):
            return None
        if log.set_epoch(info.set_name) < getattr(info, "epoch", 0):
            return None
        return log.set_bytes(info.set_name)

    def remesh_read_source(self, sset, shard_id: int,
                           survivors: Sequence[int]) -> List[Tuple[int, str]]:
        """Source ordering for the streaming remesh's per-shard scan: the
        usual ``read_sources`` candidates, re-ranked so that a holder inside
        the surviving domain (its slice of the re-partition stays local) and
        under the least memory pressure streams the shard."""
        surv = set(survivors)
        ranked = sorted(
            self.read_sources(sset, shard_id),
            key=lambda hs: (hs[0] not in surv,
                            self.node_pressure_live(hs[0]),
                            hs[0] != shard_id, hs[0]))
        return ranked

    # -- straggler re-execution ------------------------------------------------
    def backup_source(self, sset, shard_id: int,
                      exclude: int) -> Optional[Tuple[int, str]]:
        """Where a straggler's map work for ``shard_id`` should re-execute:
        the first surviving copy *not* on the straggler (the alive primary
        when the straggler was only a backup, else a replica holder). None
        when no such copy exists — the slow output must stand."""
        for holder, set_name in self.read_sources(sset, shard_id):
            if holder != exclude:
                return holder, set_name
        return None

    def backup_source_admitted(self, sset, shard_id: int, exclude: int,
                               deadline_s: float = 0.05
                               ) -> Tuple[Optional[Tuple[int, str]],
                                          Optional[Tuple[int, int]]]:
        """``backup_source`` with the admission check the PR-5 loop missed
        (carried bugfix): re-executing a straggler's map work lands the
        shard's scan plus its map output on the chosen holder, so that
        holder's MemoryManager must admit the bytes exactly like reducer
        placement admits a partition's landing bytes. A holder that refuses
        past the deadline loses the backup task to the next surviving copy;
        when every candidate refuses, the first keeps it (someone must run
        it — the pool spills rather than fails, same terminal rule as
        ``_place_admitted``). Returns ``(source, diversion)`` where
        ``diversion`` is ``(refused_holder, placed_holder)`` or None."""
        candidates = [(h, s) for h, s in self.read_sources(sset, shard_id)
                      if h != exclude]
        if not candidates:
            return None, None
        ask = self._shard_bytes(sset, sset.shards[shard_id])
        for holder, set_name in candidates:
            memory = self.cluster.nodes[holder].memory
            if memory is None or memory.admission.admit_placement(
                    ask, deadline_s=deadline_s):
                diversion = (None if holder == candidates[0][0]
                             else (candidates[0][0], holder))
                return (holder, set_name), diversion
        return candidates[0], None
