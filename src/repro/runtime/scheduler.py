"""Cluster scheduler — placement policy extracted from the cluster mechanics.

The paper's monolithic-storage argument (§1, §9.2.2) is that because one
storage layer sees everything — replica partitionings in the statistics
database, map-output locality, liveness — it can make the placement decisions
that layered stacks (Spark over Alluxio over HDFS) each make blindly. This
module owns those decisions; ``runtime/cluster.py`` owns the mechanics and
asks the scheduler where to put things:

* **Reducer placement** (``place_reducers``) — reducer ``r`` lands on the
  node already holding the most map-output bytes for partition ``r``
  (``StatisticsDB.shuffle_partition_bytes``), instead of the naive ``r % N``.
  Ties prefer the baseline node so placement is never worse than round-robin.
* **Shuffle elision** (``plan_aggregation``) — when the input sharded set is
  already partitioned on the aggregation key (``stats.best_replica`` finds a
  co-partitioned replica), the shuffle is skipped outright: every node
  aggregates its own shard and the merge is disjoint. net_bytes == 0.
* **Read-source selection** (``read_sources``) — reads of a dead owner's
  shard are routed to a surviving CRC-verified replica holder rather than
  failing.
* **Straggler re-execution** (``backup_source``) — a mapper flagged by the
  ``watchdog.StepTimer`` gets its partitions re-executed on a node holding a
  replica of its shard (backup tasks from replica holders, paper §7 applied
  to execution; ``ClusterShuffle.reexecute_stragglers`` drives it).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from ..core.statistics import ReplicaInfo


@dataclass
class AggregationPlan:
    """How an aggregation over a sharded set should execute."""

    co_partitioned: bool
    replica: Optional[ReplicaInfo] = None
    target_name: Optional[str] = None   # the sharded set to actually read

    @property
    def shuffle_free(self) -> bool:
        return self.co_partitioned


class ClusterScheduler:
    """Placement decisions over a ``Cluster`` (duck-typed: anything with
    ``nodes``, ``alive_node_ids()`` and ``stats``)."""

    def __init__(self, cluster):
        self.cluster = cluster

    # -- reducer placement -----------------------------------------------------
    def baseline_placement(self, num_reducers: int) -> Dict[int, int]:
        """The PR-1 policy: round-robin over the alive membership."""
        alive = self.cluster.alive_node_ids()
        return {r: alive[r % len(alive)] for r in range(num_reducers)}

    def place_reducers(self, shuffle_name: str,
                       num_reducers: int) -> Dict[int, int]:
        """Locality-aware placement: reducer ``r`` goes to the alive node
        holding the most map-output bytes for partition ``r``. Per-reducer
        cross-node traffic is ``total_bytes(r) - bytes_on(chosen)``, so the
        byte-heaviest choice minimizes it; ties fall back to the baseline
        node, which makes the plan never worse than round-robin."""
        stats = self.cluster.stats
        placement = self.baseline_placement(num_reducers)
        for r in range(num_reducers):
            base = placement[r]
            by_node = {n: b for n, b
                       in stats.shuffle_partition_bytes(shuffle_name, r).items()
                       if self.cluster.nodes[n].alive}
            if not by_node:
                continue
            placement[r] = max(
                by_node,
                key=lambda n: (by_node[n], n == base, -n))
        return placement

    def placement_net_bytes(self, shuffle_name: str,
                            placement: Dict[int, int]) -> int:
        """Predicted cross-node bytes for a reducer placement (what the
        benchmark reports next to the measured figure)."""
        stats = self.cluster.stats
        total = 0
        for r, node in placement.items():
            by_node = stats.shuffle_partition_bytes(shuffle_name, r)
            total += sum(b for n, b in by_node.items() if n != node)
        return total

    # -- shuffle elision -------------------------------------------------------
    def plan_aggregation(self, sset, key_field: str) -> AggregationPlan:
        """Co-partitioned input aggregates shard-locally with zero network
        bytes; otherwise shuffle, with reducer placement decided after the
        map phase (it needs the byte statistics maps produce).

        ``stats.best_replica`` is consulted for the *logical* dataset, so a
        heterogeneously partitioned replica set (same records, partitioned on
        ``key_field``, registered via ``Cluster.register_replica_set``) makes
        the query shuffle-free even when the set handed in is not — the
        paper's "select a Pangea replica that is the best for the query"."""
        replica = self.cluster.stats.best_replica(sset.name, key_field)
        target = sset
        if (replica is not None and replica.partition_key == key_field
                and replica.set_name != sset.name):
            alt = self.cluster.catalog.get(replica.set_name)
            if alt is not None and alt.partition_key == key_field:
                target = alt
        co = (replica is not None and replica.partition_key == key_field
              and target.partition_key == key_field)
        return AggregationPlan(co_partitioned=co, replica=replica,
                               target_name=target.name)

    # -- read-source selection -------------------------------------------------
    def read_sources(self, sset, node_id: int) -> List[Tuple[int, str]]:
        """Candidate locations for shard ``node_id`` of ``sset``, best first:
        the primary when its owner is alive, then every alive replica holder.
        The cluster walks these in order, CRC-verifying replica reads."""
        info = sset.shards[node_id]
        sources: List[Tuple[int, str]] = []
        if self.cluster.nodes[node_id].alive:
            sources.append((node_id, info.set_name))
        sources.extend((holder, rep_name)
                       for holder, rep_name in info.replicas
                       if self.cluster.nodes[holder].alive)
        return sources

    # -- straggler re-execution ------------------------------------------------
    def backup_source(self, sset, shard_id: int,
                      exclude: int) -> Optional[Tuple[int, str]]:
        """Where a straggler's map work for ``shard_id`` should re-execute:
        the first surviving copy *not* on the straggler (the alive primary
        when the straggler was only a backup, else a replica holder). None
        when no such copy exists — the slow output must stand."""
        for holder, set_name in self.read_sources(sset, shard_id):
            if holder != exclude:
                return holder, set_name
        return None
