"""Length-prefixed socket control plane for the multi-process cluster
backend (``runtime/node_proc.py``).

Every message is one frame::

    [u32 meta_len][u32 raw_len][meta: UTF-8 JSON][raw bytes]

``meta`` is the request/response envelope (op name, set names, offsets,
checksums, shm frame descriptors); ``raw`` is an optional small byte payload
for callers without arena room.  Page payloads normally bypass this socket
entirely through ``core/shm_arena.py`` — the envelope only carries frame
descriptors.

Pickle is NOT part of the wire format.  A non-JSON-able value in an envelope
falls back to a counted pickle escape hatch (``pickle_fallbacks()``), so the
zero-pickle property of the hot path is an observable invariant the tests
assert (delta == 0 across a whole shuffle), not an assumption.
"""
from __future__ import annotations

import base64
import json
import pickle  # the counted escape hatch: R1 exempts exactly this module
import socket
import struct
import traceback
from typing import Any, Callable, Dict, Optional, Tuple

from ..core.sanitizer import blocking_region, tracked_lock

_FRAME = struct.Struct("<II")
_MAX_META = 64 << 20  # sanity bound against desynced streams

_counter_lock = tracked_lock("rpc.counters")
_counters = {"messages": 0, "raw_bytes": 0, "pickle_fallbacks": 0}


def pickle_fallbacks() -> int:
    """How many envelope values have ever needed the pickle escape hatch in
    this process (the zero-pickle fast-path counter)."""
    with _counter_lock:
        return _counters["pickle_fallbacks"]


def wire_counters() -> Dict[str, int]:
    with _counter_lock:
        return dict(_counters)


def reset_counters() -> None:
    """Zero the process-global wire counters.  Tests assert *deltas* across
    one operation; without this hook every assertion depends on what ran
    before it in the process (order-dependent flakes)."""
    with _counter_lock:
        for k in _counters:
            _counters[k] = 0


class ConnectionClosed(ConnectionError):
    """Peer hung up (EOF mid-frame) — for a node process, it died."""


class RemoteError(RuntimeError):
    """The remote handler raised; carries its traceback text."""

    def __init__(self, message: str, remote_traceback: str = ""):
        super().__init__(message)
        self.remote_traceback = remote_traceback


def _json_default(obj: Any) -> Any:
    # numpy scalars are routine in envelopes (byte counts, epochs)
    item = getattr(obj, "item", None)
    if item is not None:
        try:
            return item()
        except Exception:
            pass
    with _counter_lock:
        _counters["pickle_fallbacks"] += 1
    return {"__pickle__": base64.b64encode(pickle.dumps(obj)).decode("ascii")}


def _json_object_hook(d: Dict[str, Any]) -> Any:
    blob = d.get("__pickle__")
    if blob is not None and len(d) == 1:
        return pickle.loads(base64.b64decode(blob))
    return d


def _recvall(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise ConnectionClosed("peer closed the connection")
        buf += chunk
    return bytes(buf)


def send_msg(sock: socket.socket, meta: Dict[str, Any],
             raw: bytes = b"") -> None:
    body = json.dumps(meta, default=_json_default,
                      separators=(",", ":")).encode("utf-8")
    with blocking_region("rpc.send", allow=("rpc.conn",)):
        sock.sendall(_FRAME.pack(len(body), len(raw)) + body + raw)
    with _counter_lock:
        _counters["messages"] += 1
        _counters["raw_bytes"] += len(raw)


def recv_msg(sock: socket.socket) -> Tuple[Dict[str, Any], bytes]:
    with blocking_region("rpc.recv", allow=("rpc.conn",)):
        return _recv_msg(sock)


def _recv_msg(sock: socket.socket) -> Tuple[Dict[str, Any], bytes]:
    meta_len, raw_len = _FRAME.unpack(_recvall(sock, _FRAME.size))
    if meta_len > _MAX_META:
        raise ConnectionError(f"oversized envelope ({meta_len} bytes)")
    meta = json.loads(_recvall(sock, meta_len).decode("utf-8"),
                      object_hook=_json_object_hook)
    raw = _recvall(sock, raw_len) if raw_len else b""
    return meta, raw


class RpcConnection:
    """Driver-side request/response endpoint.  One in-flight call per
    connection (per-connection lock); concurrency across *nodes* comes from
    issuing calls on different connections from TransferEngine workers."""

    def __init__(self, sock: socket.socket, timeout_s: float = 60.0):
        self.sock = sock
        self.sock.settimeout(timeout_s)
        self._lock = tracked_lock("rpc.conn")
        self.calls = 0

    def call(self, op: str, raw: bytes = b"",
             **fields: Any) -> Tuple[Dict[str, Any], bytes]:
        meta = {"op": op, **fields}
        # Holding rpc.conn across the round trip is the design: one
        # in-flight call per connection.  blocking_region() at the socket
        # layer allows exactly this lock and no other.
        with self._lock:
            # pangea: allow(R3): rpc.conn exists to serialize this round trip
            send_msg(self.sock, meta, raw)
            # pangea: allow(R3): reply is read on the same serialized round trip
            reply, reply_raw = recv_msg(self.sock)
            self.calls += 1
        if not reply.get("ok", False):
            raise RemoteError(reply.get("error", "remote handler failed"),
                              reply.get("traceback", ""))
        return reply, reply_raw

    def close(self) -> None:
        try:
            self.sock.close()
        except OSError:  # pragma: no cover
            pass


def serve_connection(sock: socket.socket,
                     handlers: Dict[str, Callable[[Dict[str, Any], bytes],
                                                  Optional[Tuple[Dict[str, Any],
                                                                 bytes]]]],
                     on_request: Optional[Callable[[Dict[str, Any]], None]]
                     = None) -> None:
    """Node-process main loop: dispatch envelopes to ``handlers[op]`` until
    the peer hangs up or a handler for ``close`` runs.  Handler errors are
    reported to the caller, never fatal to the loop."""
    while True:
        try:
            meta, raw = recv_msg(sock)
        except (ConnectionClosed, OSError):
            return
        op = meta.get("op", "")
        reply: Dict[str, Any]
        reply_raw = b""
        try:
            if on_request is not None:
                on_request(meta)
            handler = handlers.get(op)
            if handler is None:
                raise KeyError(f"unknown rpc op {op!r}")
            out = handler(meta, raw)
            if out is None:
                reply = {}
            elif isinstance(out, tuple):
                reply, reply_raw = out
            else:
                reply = out
            reply.setdefault("ok", True)
        except Exception as exc:  # noqa: BLE001 - report to caller
            reply = {"ok": False,
                     "error": f"{type(exc).__name__}: {exc}",
                     "traceback": traceback.format_exc()}
            reply_raw = b""
        try:
            send_msg(sock, reply, reply_raw)
        except OSError:
            return
        if op == "close":
            return
