from .watchdog import CollectiveWatchdog, HostMonitor, StepTimer
from .elastic import plan_remesh, surviving_mesh_shape, surviving_node_ids
from .scheduler import AggregationPlan, ClusterScheduler, JoinPlan
from .transfer import TransferEngine, TransferError, TransferFuture, copy_set
from .cluster import (Cluster, ClusterShuffle, DeadNodeError, RecoveryReport,
                      RemeshReport, ShardInfo, ShardedSet, StorageNode,
                      cluster_hash_aggregate, dispatch_plan)
from .join import ClusterJoin, JoinReport, scheme_slot_of_keys
from .serving import (KVShard, ServingTier, Session, TieredSlabStore,
                      expected_page_slab, token_value)

__all__ = ["CollectiveWatchdog", "HostMonitor", "StepTimer", "plan_remesh",
           "surviving_mesh_shape", "surviving_node_ids", "AggregationPlan",
           "ClusterScheduler", "JoinPlan", "TransferEngine", "TransferError",
           "TransferFuture", "copy_set", "Cluster", "ClusterShuffle",
           "DeadNodeError", "RecoveryReport", "RemeshReport", "ShardInfo",
           "ShardedSet", "StorageNode", "cluster_hash_aggregate",
           "dispatch_plan", "ClusterJoin", "JoinReport",
           "scheme_slot_of_keys", "KVShard", "ServingTier", "Session",
           "TieredSlabStore", "expected_page_slab", "token_value"]
