from .watchdog import CollectiveWatchdog, HostMonitor, StepTimer
from .elastic import plan_remesh, surviving_mesh_shape
from .cluster import (Cluster, ClusterShuffle, DeadNodeError, RecoveryReport,
                      ShardInfo, ShardedSet, StorageNode,
                      cluster_hash_aggregate, dispatch_plan)

__all__ = ["CollectiveWatchdog", "HostMonitor", "StepTimer", "plan_remesh",
           "surviving_mesh_shape", "Cluster", "ClusterShuffle",
           "DeadNodeError", "RecoveryReport", "ShardInfo", "ShardedSet",
           "StorageNode", "cluster_hash_aggregate", "dispatch_plan"]
