from .watchdog import CollectiveWatchdog, HostMonitor, StepTimer
from .elastic import plan_remesh, surviving_mesh_shape

__all__ = ["CollectiveWatchdog", "HostMonitor", "StepTimer", "plan_remesh",
           "surviving_mesh_shape"]
