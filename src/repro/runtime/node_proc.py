"""Multi-process data plane: one OS process per storage node.

``Cluster(backend="proc")`` re-platforms the in-process ``StorageNode``
loop onto real node processes.  The split follows the paper's monolithic
storage-process design:

* **control plane** — a length-prefixed JSON socket per node
  (``runtime/rpc.py``): shuffle map/pull orchestration, catalog ops,
  pressure/admission probes, kill/revive;
* **data plane** — page payloads (row small-page blocks and columnar blocks
  alike) move through ``core/shm_arena.py`` shared-memory frames and bypass
  the sockets entirely: a page image is copied once into a frame by its
  producer and once out by its consumer, with zero pickling (the
  ``rpc.pickle_fallbacks`` counter is the testable invariant).

Every segment is *created* by the driver — a SIGKILLed node process never
owned one, so it can never leak one — while each node process *allocates*
from its own outbox.  Sibling processes map each other's outboxes read-only,
so shuffle partition pages travel node-to-node without ever landing in the
driver.

On this design the driver stays a thin orchestrator: map work, admission
waits, spill fsyncs, and page-log writes all happen inside the node
processes, so their blocking time overlaps across nodes instead of
serializing through the driver loop the way the in-process backend's
``map_sharded`` does.
"""
from __future__ import annotations

import multiprocessing as mp
import os
import shutil
import signal
import socket
import threading
import time
import types
import zlib
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.columnar import (ColumnarWriter, columns_to_records,
                             iter_column_blocks, records_to_columns,
                             route_partition_ids, set_column_crcs)
from ..core.memory_manager import MemoryManager, derive_staging_cap
from ..core.sanitizer import tracked_lock
from ..core.replication import (PartitionScheme, record_content_checksum,
                                replica_nodes, shard_checksum)
from ..core.services import (ColumnarShuffleService, SequentialWriter,
                             ShuffleService, columnar_job_data_attrs,
                             columnar_user_data_attrs, is_columnar,
                             iter_small_page_records, job_data_attrs,
                             user_data_attrs)
from ..core.shm_arena import (ArenaFullError, ShmArena, arena_name, gather,
                              segment_exists)
from ..core.statistics import StatisticsDB
from .cluster import (Cluster, DeadNodeError, RecoveryReport, ShardInfo,
                      ShardedSet, StorageNode, _iter_record_chunks,
                      _resolve_dispatch_plan, dispatch_plan, reducer_hash)
from .rpc import RpcConnection, serve_connection
from .scheduler import ClusterScheduler
from .transfer import TransferEngine

__all__ = ["ProcCluster", "ProcShuffle", "NodeDiedError", "CleanupReport"]


class NodeDiedError(DeadNodeError):
    """A node *process* died mid-call (EOF/reset on its control socket)."""


# -- attrs factories over the wire -------------------------------------------
# Callables cannot cross the process boundary; the proc backend ships attrs
# as one of these preset kind strings instead.
_KIND_TO_ATTRS: Dict[str, Optional[Callable]] = {
    "none": None,
    "user": user_data_attrs,
    "job": job_data_attrs,
    "columnar_user": columnar_user_data_attrs,
    "columnar_job": columnar_job_data_attrs,
}
_ATTRS_TO_KIND = {v: k for k, v in _KIND_TO_ATTRS.items()}


def _attrs_kind(factory: Optional[Callable]) -> str:
    try:
        return _ATTRS_TO_KIND[factory]
    except KeyError:
        raise ValueError(
            "the proc backend ships shard attributes by name; use one of the "
            "preset factories (user/job/columnar_user/columnar_job) or None"
        ) from None


def _attrs_from_kind(kind: str):
    factory = _KIND_TO_ATTRS[kind]
    return factory() if factory is not None else None


def _dtype_to_wire(dtype: np.dtype):
    dtype = np.dtype(dtype)
    return dtype.descr if dtype.names else dtype.str


def _dtype_from_wire(wire) -> np.dtype:
    if isinstance(wire, str):
        return np.dtype(wire)
    return np.dtype([tuple(f) for f in wire])


def _record_bytes(arr: np.ndarray) -> bytes:
    """A record chunk's exact bytes, detached from any pinned page."""
    return np.ascontiguousarray(arr).tobytes()


# ===========================================================================
# Child side: the node process
# ===========================================================================
class _NodeServer:
    """Hosts one real ``StorageNode`` inside its own OS process and serves
    the control-plane ops.  Single-threaded by design: one in-flight request
    per node (the driver's per-connection lock enforces it), concurrency
    comes from having many node processes."""

    def __init__(self, cfg: dict):
        self.cfg = cfg
        self.node_id = int(cfg["node_id"])
        self.epoch = int(cfg.get("epoch", 0))
        self.node = StorageNode(
            self.node_id, cfg["capacity"], cfg.get("spill_dir"),
            policy=cfg["policy"],
            pressure_watermark=cfg["pressure_watermark"],
            pagelog_dir=cfg.get("pagelog_dir"),
            epoch_fn=lambda: self.epoch,
            pagelog_fsync=cfg["pagelog_fsync"],
            pagelog_compact_threshold=cfg.get("pagelog_compact_threshold"))
        frame = int(cfg["frame_size"])
        self.inbox = ShmArena.attach(cfg["inbox"], frame,
                                     int(cfg["inbox_frames"]))
        self.outbox = ShmArena.attach(cfg["outbox"], frame,
                                      int(cfg["outbox_frames"]), owner=True)
        self.admission = bool(cfg["admission"])
        self.timeout_s = float(cfg["admission_timeout_s"])
        self._peers: Dict[str, ShmArena] = {}
        self._writers: Dict[str, dict] = {}
        self._cursors: Dict[int, dict] = {}
        self._next_cursor = 0
        self._reservations: Dict[int, object] = {}
        self._next_rid = 0
        self._shuffles: Dict[str, "_ChildShuffle"] = {}
        self.handlers = {
            "ping": self.op_ping,
            "close": self.op_close,
            "free": self.op_free,
            "write_set": self.op_write_set,
            "export_set": self.op_export_set,
            "drop_set": self.op_drop_set,
            "checksum_set": self.op_checksum_set,
            "pressure": self.op_pressure,
            "reserve": self.op_reserve,
            "try_reserve": self.op_try_reserve,
            "release_reservation": self.op_release_reservation,
            "admit": self.op_admit,
            "log_sets": self.op_log_sets,
            "log_info": self.op_log_info,
            "log_drop": self.op_log_drop,
            "log_report": self.op_log_report,
            "log_compact": self.op_log_compact,
            "warm_restore": self.op_warm_restore,
            "shuffle_begin": self.op_shuffle_begin,
            "map_set": self.op_map_set,
            "map_finish": self.op_map_finish,
            "export_part": self.op_export_part,
            "import_part": self.op_import_part,
            "local_attach": self.op_local_attach,
            "release_part": self.op_release_part,
            "reduce_read": self.op_reduce_read,
            "reduce_stats": self.op_reduce_stats,
            "reduce_release": self.op_reduce_release,
        }

    # every request piggybacks the driver's topology/job event counter, so
    # the node's page log stamps records with the same epochs the in-process
    # backend would (the revival fence depends on it)
    def note_epoch(self, meta: dict) -> None:
        e = meta.get("epoch")
        if e is not None and int(e) > self.epoch:
            self.epoch = int(e)

    # -- payload channels ---------------------------------------------------
    def _payload(self, meta: dict, raw: bytes) -> np.ndarray:
        """Resolve a request's payload: a sibling's outbox (``seg``), the
        driver's inbox (bare ``desc``), or the raw socket bytes."""
        desc = meta.get("desc")
        if desc is None:
            return gather(None, None, raw)
        seg = meta.get("seg")
        if seg is None:
            return self.inbox.read(desc)
        peer = self._peers.get(seg)
        if peer is None:
            peer = ShmArena.attach(seg, int(meta["frame_size"]),
                                   int(meta["num_frames"]))
            self._peers[seg] = peer
        return peer.read(desc)

    def _ship(self, buf: np.ndarray) -> Tuple[Optional[dict], bytes]:
        """Outbound payload: shm frames when the outbox has room, socket
        bytes otherwise (counted by the rpc wire counters, never pickled)."""
        if buf.nbytes == 0:
            return None, b""
        try:
            return self.outbox.put(buf), b""
        except ArenaFullError:
            return None, buf.tobytes()

    # -- basic ops ----------------------------------------------------------
    def op_ping(self, meta, raw):
        return {"pid": os.getpid(), "node_id": self.node_id}

    def op_close(self, meta, raw):
        return {}

    def op_free(self, meta, raw):
        self.outbox.free(meta["desc"])
        return {}

    # -- set creation / export ---------------------------------------------
    def op_write_set(self, meta, raw):
        """Chunked record ingest into a fresh locality set.  The final chunk
        (``done``) may carry ``expect_crc``: on mismatch the set is dropped
        and the error propagates, so a recovery copy verifies in-node without
        a second read pass."""
        name = meta["name"]
        st = self._writers.get(name)
        pool = self.node.pool
        if st is None:
            kind = meta.get("kind", "none")
            attrs = _attrs_from_kind(kind)
            dtype = _dtype_from_wire(meta["dtype"])
            ls = pool.create_set(name, int(meta["page_size"]), attrs)
            wcls = (ColumnarWriter if kind.startswith("columnar")
                    else SequentialWriter)
            st = {"writer": wcls(pool, ls, dtype), "ls": ls, "dtype": dtype,
                  "crc": 0, "n": 0}
            self._writers[name] = st
        buf = self._payload(meta, raw)
        if buf.nbytes:
            recs = buf.view(st["dtype"])
            st["writer"].append_batch(recs)
            st["crc"] = zlib.crc32(buf, st["crc"])
            st["n"] += len(recs)
        if not meta.get("done"):
            return {"num_records": st["n"]}
        self._writers.pop(name, None)
        st["writer"].close()
        crc = st["crc"] & 0xFFFFFFFF
        expect = meta.get("expect_crc")
        if expect is not None and crc != int(expect):
            pool.drop_set(st["ls"])
            raise ValueError(f"write_set {name!r}: crc mismatch "
                             f"({crc:#x} != {int(expect):#x})")
        return {"num_records": st["n"], "crc": crc}

    def op_export_set(self, meta, raw):
        """Cursor-style streaming read of a set's record bytes, cut at
        record-chunk boundaries, with a running CRC32 that equals the
        catalog's ``shard_checksum`` at ``done`` (the chain is order-exact)."""
        cur = meta.get("cursor")
        if cur is None:
            pool = self.node.pool
            ls = pool.get_set(meta["name"])
            dtype = _dtype_from_wire(meta["dtype"])
            cur = self._next_cursor
            self._next_cursor += 1
            self._cursors[cur] = {"gen": _iter_record_chunks(pool, ls, dtype),
                                  "crc": 0, "n": 0,
                                  "itemsize": dtype.itemsize}
        st = self._cursors[cur]
        max_bytes = int(meta.get("max_bytes", 1 << 20))
        parts: List[bytes] = []
        total = 0
        done = False
        while total < max_bytes:
            try:
                chunk = next(st["gen"])
            except StopIteration:
                done = True
                break
            b = _record_bytes(chunk)
            parts.append(b)
            total += len(b)
            st["n"] += len(chunk)
        buf = np.frombuffer(b"".join(parts), np.uint8)
        st["crc"] = zlib.crc32(buf, st["crc"])
        if done:
            self._cursors.pop(cur, None)
        desc, out_raw = self._ship(buf)
        return {"cursor": cur, "done": done, "nbytes": int(buf.nbytes),
                "crc": st["crc"] & 0xFFFFFFFF,
                "num_records": st["n"], "desc": desc}, out_raw

    def op_drop_set(self, meta, raw):
        pool = self.node.pool
        name = meta["name"]
        if name in pool.paging.sets:
            pool.drop_set(pool.get_set(name))
        return {}

    def op_checksum_set(self, meta, raw):
        pool = self.node.pool
        ls = pool.get_set(meta["name"])
        dtype = _dtype_from_wire(meta["dtype"])
        crc = 0
        content = 0
        n = 0
        for chunk in _iter_record_chunks(pool, ls, dtype):
            crc = zlib.crc32(_record_bytes(chunk), crc)
            content = (content + record_content_checksum(chunk)) % (1 << 64)
            n += len(chunk)
        return {"crc": crc & 0xFFFFFFFF, "content_crc": content,
                "num_records": n}

    # -- memory / admission -------------------------------------------------
    def op_pressure(self, meta, raw):
        memory = self.node.memory
        return {"score": float(memory.pressure_score()),
                "report": memory.pressure_report()}

    def op_reserve(self, meta, raw):
        res = self.node.memory.reserve(int(meta["nbytes"]))
        rid = self._next_rid
        self._next_rid += 1
        self._reservations[rid] = res
        return {"rid": rid}

    def op_try_reserve(self, meta, raw):
        res = self.node.memory.try_reserve(
            int(meta["nbytes"]), urgency=meta.get("urgency", "normal"),
            timeout=meta.get("timeout"))
        if res is None:
            return {"rid": None}
        rid = self._next_rid
        self._next_rid += 1
        self._reservations[rid] = res
        return {"rid": rid}

    def op_release_reservation(self, meta, raw):
        res = self._reservations.pop(int(meta["rid"]), None)
        if res is not None:
            res.release()
        return {}

    def op_admit(self, meta, raw):
        ok = self.node.memory.admission.admit_placement(
            int(meta["nbytes"]), deadline_s=float(meta["deadline_s"]),
            count=bool(meta.get("count", True)))
        return {"admitted": bool(ok)}

    # -- durable page log ---------------------------------------------------
    def _log(self):
        return self.node.memory.pagelog

    def op_log_sets(self, meta, raw):
        log = self._log()
        if log is None:
            return {"sets": {}}
        return {"sets": {name: int(log.set_epoch(name))
                         for name in log.set_names()}}

    def op_log_info(self, meta, raw):
        log = self._log()
        name = meta["name"]
        if log is None or not log.entries_for(name):
            return {"entries": 0, "epoch": 0, "bytes": 0}
        return {"entries": len(log.entries_for(name)),
                "epoch": int(log.set_epoch(name)),
                "bytes": int(log.set_bytes(name))}

    def op_log_drop(self, meta, raw):
        log = self._log()
        if log is not None:
            for name in meta["names"]:
                log.drop_set(name)
        return {}

    def op_log_report(self, meta, raw):
        log = self._log()
        if log is None:
            return {"configured": False}
        return {"configured": True, "generation": int(log.generation),
                "compactions": int(log.compactions),
                "live_bytes": int(log.live_bytes()),
                "file_bytes": int(log.file_bytes()),
                "amplification": float(log.amplification())}

    def op_log_compact(self, meta, raw):
        log = self._log()
        if log is None:
            return {"compacted": False}
        log.compact()
        return {"compacted": True, "generation": int(log.generation)}

    def op_warm_restore(self, meta, raw):
        """Adopt one set from the replayed local page log after a revival
        (same contract as ``Cluster._warm_restore_set``, in-node)."""
        pool = self.node.pool
        log = self._log()
        name = meta["name"]
        if log is None or not log.entries_for(name):
            return {"adopted": False}
        if name in pool.paging.sets:
            return {"adopted": True}
        kind = meta.get("kind", "none")
        dtype = _dtype_from_wire(meta["dtype"])
        if not Cluster._verify_log_crc(log, name, dtype,
                                       int(meta["expect_crc"]),
                                       columnar=kind.startswith("columnar")):
            return {"adopted": False}
        pool.adopt_durable_set(name, int(meta["page_size"]),
                               _attrs_from_kind(kind))
        return {"adopted": True}

    # -- shuffle data plane --------------------------------------------------
    def _shuffle(self, name: str) -> "_ChildShuffle":
        return self._shuffles[name]

    def op_shuffle_begin(self, meta, raw):
        name = meta["shuffle"]
        if name not in self._shuffles:
            self._shuffles[name] = _ChildShuffle(
                self, name, int(meta["num_reducers"]),
                _dtype_from_wire(meta["dtype"]), int(meta["page_size"]),
                bool(meta["columnar"]), bool(meta["admission"]))
        return {}

    def op_map_set(self, meta, raw):
        return self._shuffle(meta["shuffle"]).map_set(
            meta["set_name"], meta.get("key_field"),
            int(meta.get("batch", 65536)))

    def op_map_finish(self, meta, raw):
        return self._shuffle(meta["shuffle"]).finish()

    def op_export_part(self, meta, raw):
        return self._shuffle(meta["shuffle"]).export_part(
            int(meta["reducer"]), int(meta.get("max_bytes", 1 << 20)))

    def op_import_part(self, meta, raw):
        return self._shuffle(meta["shuffle"]).import_part(
            meta, self._payload(meta, raw))

    def op_local_attach(self, meta, raw):
        return self._shuffle(meta["shuffle"]).local_attach(
            int(meta["reducer"]))

    def op_release_part(self, meta, raw):
        self._shuffle(meta["shuffle"]).release_part(int(meta["reducer"]))
        return {}

    def op_reduce_read(self, meta, raw):
        return self._shuffle(meta["shuffle"]).reduce_read(
            int(meta["reducer"]), meta.get("cursor"),
            int(meta.get("max_bytes", 1 << 20)))

    def op_reduce_stats(self, meta, raw):
        return self._shuffle(meta["shuffle"]).reduce_stats(
            int(meta["reducer"]))

    def op_reduce_release(self, meta, raw):
        self._shuffle(meta["shuffle"]).reduce_release(int(meta["reducer"]))
        return {}

    # -- lifecycle ----------------------------------------------------------
    def teardown(self) -> None:
        try:
            memory = self.node.memory
            if memory is not None:
                if memory.pagelog is not None:
                    memory.pagelog.close()
                memory.close()  # graceful exit cleans the scratch spill dir
        except Exception:
            pass
        for arena in [self.inbox, self.outbox, *self._peers.values()]:
            try:
                arena.close()
            except Exception:
                pass


class _ChildShuffle:
    """Per-process shuffle state: the real ``ShuffleService`` (or columnar
    twin) plus export cursors, import landing sets, and the reduce-source
    registry.  Mirrors exactly what ``ClusterShuffle`` keeps per node, but
    the bytes never leave this process except as whole page images."""

    def __init__(self, server: _NodeServer, name: str, num_reducers: int,
                 dtype: np.dtype, page_size: int, columnar: bool,
                 admission: bool):
        self.server = server
        self.name = name
        self.num_reducers = num_reducers
        self.dtype = dtype
        self.page_size = page_size
        self.columnar = columnar
        self.admission = admission
        self.svc = None
        # reducer -> {"pages": [...], "crc": running} export cursor
        self._exports: Dict[int, dict] = {}
        # (reducer, src_node) -> {"ls", "crc"} import landing state
        self._imports: Dict[Tuple[int, int], dict] = {}
        # reducer -> {src_node: source entry} for the reduce read
        self.sources: Dict[int, Dict[int, dict]] = {}
        self._read_cursors: Dict[int, dict] = {}
        self._next_cursor = 0

    # -- map side -----------------------------------------------------------
    def _service(self):
        if self.svc is None:
            pool = self.server.node.pool
            if self.columnar:
                self.svc = ColumnarShuffleService(
                    pool, f"{self.name}/map{self.server.node_id}",
                    self.num_reducers, self.dtype, page_size=self.page_size,
                    attrs_factory=columnar_job_data_attrs)
            else:
                self.svc = ShuffleService(
                    pool, f"{self.name}/map{self.server.node_id}",
                    self.num_reducers, self.dtype, page_size=self.page_size,
                    attrs_factory=job_data_attrs)
        return self.svc

    def _paced(self, nbytes: int):
        memory = self.server.node.memory
        if not self.admission:
            return memory.reserve(nbytes)
        return (memory.try_reserve(nbytes, urgency="required",
                                   timeout=self.server.timeout_s)
                or memory.reserve(nbytes))

    def map_set(self, set_name: str, key_field: Optional[str],
                batch: int) -> dict:
        """Map one locally held set into this node's shuffle buffers.  This
        runs *inside* the node process: admission waits and spill I/O here
        overlap with every other node's, which is the wall-clock win the
        proc backend exists for."""
        pool = self.server.node.pool
        ls = pool.get_set(set_name)
        svc = self._service()
        worker = (self.server.node_id, 0)
        total = 0
        if self.columnar and is_columnar(ls):
            for cols, n in iter_column_blocks(pool, ls, self.dtype):
                keys = (cols[key_field] if key_field is not None
                        else columns_to_records(cols, self.dtype, n)
                        [self.dtype.names[0]])
                h = route_partition_ids(keys, self.num_reducers)
                parts = (h.astype(np.uint8) if self.num_reducers <= 256
                         else h.astype(np.int64))
                order, _counts, offsets = dispatch_plan(parts,
                                                        self.num_reducers)
                reservation = self._paced(n * self.dtype.itemsize)
                try:
                    svc.add_gathered(worker, cols, order, offsets)
                finally:
                    reservation.release()
                total += n
            return {"records": total}
        field_name = key_field or self.dtype.names[0]
        for chunk in _iter_record_chunks(pool, ls, self.dtype):
            for i in range(0, len(chunk), batch):
                recs = chunk[i:i + batch]
                parts = reducer_hash(recs[field_name], self.num_reducers)
                order, _counts, offsets = dispatch_plan(parts,
                                                        self.num_reducers)
                reservation = self._paced(recs.nbytes)
                try:
                    if self.columnar:
                        # row-stored input into a columnar shuffle: split
                        # once, then the fused gather path (same
                        # compatibility route as the in-process map_batch)
                        svc.add_gathered(worker, records_to_columns(recs),
                                         order, offsets)
                    else:
                        routed = recs[order]
                        for r in range(self.num_reducers):
                            sub = routed[offsets[r]:offsets[r + 1]]
                            if len(sub):
                                svc.get_buffer(worker, r).add_batch(sub)
                finally:
                    reservation.release()
                total += len(recs)
        return {"records": total}

    def finish(self) -> dict:
        svc = self._service()
        svc.finish_writes()
        memory = self.server.node.memory
        out = {"partition_bytes": [int(b) for b in svc.partition_bytes],
               "partition_records": [int(n) for n in svc.partition_records],
               "pressure": float(memory.pressure_score())}
        if self.columnar:
            out["crcs"] = [[int(c) for c in crcs]
                           for crcs in svc.partition_crcs]
        return out

    # -- partition export (whole page images out of the pool) ---------------
    def export_part(self, reducer: int, max_bytes: int):
        svc = self._service()
        st = self._exports.get(reducer)
        pool = self.server.node.pool
        if st is None:
            ls = svc.partition_sets[reducer]
            st = {"ls": ls, "pages": sorted(ls.pages), "crc": 0}
            self._exports[reducer] = st
        sizes: List[int] = []
        parts: List[np.ndarray] = []
        total = 0
        while st["pages"]:
            page = st["ls"].pages[st["pages"][0]]
            if sizes and total + page.size > max_bytes:
                break
            view = pool.pin(page)
            try:
                parts.append(np.array(view[:page.size], dtype=np.uint8))
            finally:
                pool.unpin(page)
            sizes.append(int(page.size))
            total += int(page.size)
            st["pages"].pop(0)
        buf = (np.concatenate(parts) if parts
               else np.empty(0, dtype=np.uint8))
        st["crc"] = zlib.crc32(buf, st["crc"])
        done = not st["pages"]
        out = {"sizes": sizes, "done": done, "nbytes": int(buf.nbytes),
               "crc": st["crc"] & 0xFFFFFFFF}
        if not self.columnar:
            out["small_page"] = int(svc.small_page_of(reducer))
        if done:
            self._exports.pop(reducer, None)
            if self.columnar:
                out["crcs"] = [int(c) for c in svc.partition_crcs[reducer]]
        desc, raw = self.server._ship(buf)
        out["desc"] = desc
        return out, raw

    # -- partition import (landing page images into the pool) ---------------
    def import_part(self, meta: dict, buf: np.ndarray) -> dict:
        reducer = int(meta["reducer"])
        src = int(meta["src_node"])
        key = (reducer, src)
        pool = self.server.node.pool
        st = self._imports.get(key)
        if st is None:
            attrs = (columnar_job_data_attrs() if self.columnar
                     else job_data_attrs())
            name = f"{self.name}/import/r{reducer}/n{src}"
            st = {"ls": pool.create_set(name, self.page_size, attrs),
                  "name": name, "crc": 0}
            self._imports[key] = st
        st["crc"] = zlib.crc32(buf, st["crc"])
        if (st["crc"] & 0xFFFFFFFF) != int(meta["crc"]):
            raise ValueError(
                f"import_part {self.name}/r{reducer} from node {src}: "
                f"page stream crc mismatch")
        if buf.nbytes:
            reservation = self._paced(buf.nbytes)
            try:
                off = 0
                for size in meta["sizes"]:
                    size = int(size)
                    page = pool.new_page(st["ls"], size=size)
                    pool.view(page)[:] = buf[off:off + size]
                    pool.unpin(page, dirty=True)
                    off += size
            finally:
                reservation.release()
        if meta.get("done"):
            if self.columnar:
                got = set_column_crcs(pool, st["ls"], self.dtype)
                want = [int(c) for c in meta.get("crcs", [])]
                if [int(c) for c in got] != want:
                    raise ValueError(
                        f"import_part {self.name}/r{reducer} from node "
                        f"{src}: column crc chain mismatch")
            entry = {"kind": "import", "name": st["name"]}
            if not self.columnar:
                entry["small_page"] = int(meta["small_page"])
            self.sources.setdefault(reducer, {})[src] = entry
            self._imports.pop(key, None)
        return {"nbytes": int(buf.nbytes)}

    def local_attach(self, reducer: int) -> dict:
        svc = self._service()
        self.sources.setdefault(reducer, {})[self.server.node_id] = {
            "kind": "own"}
        return {"nbytes": int(svc.partition_bytes[reducer])}

    def release_part(self, reducer: int) -> None:
        if self.svc is not None:
            self.svc.release_partition(reducer)

    # -- reduce side ----------------------------------------------------------
    def _reduce_chunks(self, reducer: int):
        """Record chunks of the landed reduce input, in source-node order
        (matching the in-process backend's sorted-service pull order)."""
        pool = self.server.node.pool
        for src in sorted(self.sources.get(reducer, {})):
            entry = self.sources[reducer][src]
            if entry["kind"] == "own":
                for chunk in self._service().iter_partition(reducer):
                    if self.columnar:
                        cols, n = chunk
                        yield columns_to_records(cols, self.dtype, n)
                    else:
                        yield chunk
                continue
            ls = pool.get_set(entry["name"])
            if self.columnar:
                for cols, n in iter_column_blocks(pool, ls, self.dtype):
                    yield columns_to_records(cols, self.dtype, n)
            else:
                yield from iter_small_page_records(
                    pool, ls, self.dtype, small_page=entry["small_page"])

    def reduce_read(self, reducer: int, cursor: Optional[int],
                    max_bytes: int):
        if cursor is None:
            cursor = self._next_cursor
            self._next_cursor += 1
            self._read_cursors[cursor] = {
                "gen": self._reduce_chunks(reducer), "n": 0}
        st = self._read_cursors[cursor]
        parts: List[bytes] = []
        total = 0
        done = False
        while total < max_bytes:
            try:
                chunk = next(st["gen"])
            except StopIteration:
                done = True
                break
            b = _record_bytes(chunk)
            parts.append(b)
            total += len(b)
            st["n"] += len(chunk)
        buf = np.frombuffer(b"".join(parts), np.uint8)
        if done:
            self._read_cursors.pop(cursor, None)
        desc, raw = self.server._ship(buf)
        return {"cursor": cursor, "done": done, "nbytes": int(buf.nbytes),
                "num_records": st["n"], "desc": desc}, raw

    def reduce_stats(self, reducer: int) -> dict:
        """Count + order-independent content checksum of the landed reduce
        input, computed here so checksum-only verification never ships the
        records anywhere (the benchmark's byte-identity certificate)."""
        n = 0
        content = 0
        for chunk in self._reduce_chunks(reducer):
            n += len(chunk)
            content = (content + record_content_checksum(chunk)) % (1 << 64)
        return {"num_records": n, "content_crc": content}

    def reduce_release(self, reducer: int) -> None:
        pool = self.server.node.pool
        for src, entry in self.sources.pop(reducer, {}).items():
            if entry["kind"] == "own":
                self.release_part(reducer)
            elif entry["name"] in pool.paging.sets:
                ls = pool.get_set(entry["name"])
                ls.end_lifetime(pool.clock)
                pool.drop_set(ls)


def _node_main(cfg: dict, sock: socket.socket,
               parent_sock: socket.socket,
               inherited: Sequence[socket.socket]) -> None:
    """Node-process entry point (fork start method — nothing is pickled).
    Inherited control sockets of *sibling* nodes are closed first, so a
    sibling's death reaches the driver as a clean EOF."""
    parent_sock.close()
    for s in inherited:
        try:
            s.close()
        except OSError:
            pass
    server = _NodeServer(cfg)
    try:
        serve_connection(sock, server.handlers, on_request=server.note_epoch)
    finally:
        try:
            server.teardown()
        finally:
            # skip inherited atexit/multiprocessing finalizers: the driver
            # owns every shared resource this process touched
            os._exit(0)


# ===========================================================================
# Driver side
# ===========================================================================
@dataclass
class CleanupReport:
    """What ``ProcCluster.close`` left behind (nothing, when healthy)."""

    orphan_processes: List[int] = field(default_factory=list)
    leaked_segments: List[str] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.orphan_processes and not self.leaked_segments


class _RemoteReservation:
    """Driver-side handle for a reservation held inside a node process."""

    def __init__(self, handle: "ProcNodeHandle", rid: int):
        self._handle = handle
        self.rid = rid

    def release(self) -> None:
        try:
            self._handle.call("release_reservation", rid=self.rid)
        except DeadNodeError:
            pass  # the node died; its reservations died with it


class _RemoteAdmission:
    def __init__(self, handle: "ProcNodeHandle"):
        self._handle = handle

    def admit_placement(self, nbytes: int, deadline_s: float = 0.05,
                        count: bool = True) -> bool:
        try:
            rep, _ = self._handle.call("admit", nbytes=int(nbytes),
                                       deadline_s=float(deadline_s),
                                       count=bool(count))
        except DeadNodeError:
            return False
        return bool(rep["admitted"])


class _RemotePageLog:
    """The scheduler's window onto a node process's page log (just the
    three probes ``recovery_plan`` costs with)."""

    def __init__(self, handle: "ProcNodeHandle"):
        self._handle = handle

    def _info(self, name: str) -> dict:
        rep, _ = self._handle.call("log_info", name=name)
        return rep

    def entries_for(self, name: str) -> int:
        return int(self._info(name)["entries"])

    def set_epoch(self, name: str) -> int:
        return int(self._info(name)["epoch"])

    def set_bytes(self, name: str) -> int:
        return int(self._info(name)["bytes"])


class RemoteMemory:
    """Duck-types the slice of ``MemoryManager`` the scheduler and shuffle
    admission paths touch, over RPC.  Same call sites, same semantics —
    the grant itself is taken inside the node process."""

    def __init__(self, handle: "ProcNodeHandle"):
        self._handle = handle
        self.admission = _RemoteAdmission(handle)

    def pressure_score(self) -> float:
        try:
            rep, _ = self._handle.call("pressure")
        except DeadNodeError:
            return 0.0
        return float(rep["score"])

    def pressure_report(self) -> dict:
        rep, _ = self._handle.call("pressure")
        return rep["report"]

    def reserve(self, nbytes: int) -> _RemoteReservation:
        rep, _ = self._handle.call("reserve", nbytes=int(nbytes))
        return _RemoteReservation(self._handle, int(rep["rid"]))

    def try_reserve(self, nbytes: int, *, urgency: str = "normal",
                    timeout: Optional[float] = None
                    ) -> Optional[_RemoteReservation]:
        rep, _ = self._handle.call("try_reserve", nbytes=int(nbytes),
                                   urgency=urgency, timeout=timeout)
        rid = rep.get("rid")
        if rid is None:
            return None
        return _RemoteReservation(self._handle, int(rid))

    @property
    def pagelog(self) -> Optional[_RemotePageLog]:
        if self._handle.cluster._pagelog_dir is None:
            return None
        return _RemotePageLog(self._handle)


class ProcNodeHandle:
    """Driver-side identity of one node process: its control connection,
    its two arenas (both *created* here, so a SIGKILL never leaks one), and
    the set-name mirror the scheduler's ``_holds`` reads without an RPC."""

    def __init__(self, cluster: "ProcCluster", node_id: int):
        self.cluster = cluster
        self.node_id = node_id
        self.generation = 0
        self.alive = False
        self.proc = None
        self.conn: Optional[RpcConnection] = None
        self.inbox: Optional[ShmArena] = None
        self.outbox: Optional[ShmArena] = None
        # set names this node's pool holds — kept in sync by every driver op
        # that creates/drops remote sets, so placement never pays an RPC
        self.set_mirror: set = set()
        self._memory = RemoteMemory(self)
        self._pool = types.SimpleNamespace(
            paging=types.SimpleNamespace(sets=self.set_mirror))
        self.spawn()

    @property
    def memory(self) -> Optional[RemoteMemory]:
        return self._memory if self.alive else None

    @property
    def pool(self):
        return self._pool if self.alive else None

    def spawn(self) -> None:
        self._unlink_arenas()
        c = self.cluster
        g = self.generation
        self.generation += 1
        self.inbox = ShmArena(arena_name(f"in{self.node_id}g{g}"),
                              c.arena_frame_bytes, c._inbox_frames,
                              create=True, owner=True)
        self.outbox = ShmArena(arena_name(f"out{self.node_id}g{g}"),
                               c.arena_frame_bytes, c._outbox_frames,
                               create=True, owner=False)
        c._segments.extend([self.inbox.name, self.outbox.name])
        parent_sock, child_sock = socket.socketpair()
        cfg = {
            "node_id": self.node_id,
            "capacity": c.node_capacity,
            "spill_dir": c._node_spill_dir(self.node_id),
            "policy": c.policy,
            "pressure_watermark": c.pressure_watermark,
            "pagelog_dir": c._node_pagelog_dir(self.node_id),
            "pagelog_fsync": c._pagelog_fsync,
            "pagelog_compact_threshold": c._pagelog_compact_threshold,
            "frame_size": c.arena_frame_bytes,
            "inbox": self.inbox.name,
            "inbox_frames": c._inbox_frames,
            "outbox": self.outbox.name,
            "outbox_frames": c._outbox_frames,
            "admission": c.admission,
            "admission_timeout_s": c.admission_timeout_s,
            "epoch": c.stats.event_seq,
        }
        inherited = [h.conn.sock for h in c.nodes.values()
                     if h is not self and h.conn is not None]
        self.proc = c._ctx.Process(
            target=_node_main, args=(cfg, child_sock, parent_sock, inherited),
            name=f"pangea-node{self.node_id}", daemon=True)
        self.proc.start()
        child_sock.close()
        self.conn = RpcConnection(parent_sock, timeout_s=c.rpc_timeout_s)
        self.set_mirror.clear()
        self.alive = True
        self.call("ping")

    def call(self, op: str, raw: bytes = b"", **fields):
        if not self.alive:
            raise DeadNodeError(f"node {self.node_id} is down")
        fields.setdefault("epoch", self.cluster.stats.event_seq)
        try:
            return self.conn.call(op, raw=raw, **fields)
        except OSError as exc:  # EOF/reset/timeout: the process is gone
            self.cluster._note_node_death(self.node_id)
            err = NodeDiedError(
                f"node {self.node_id} process died mid-call ({op!r})")
            err.node_id = self.node_id
            raise err from exc

    # -- payload helper (driver -> node) ------------------------------------
    def send_chunk(self, payload: bytes):
        """Stage an outbound payload in this node's inbox; falls back to the
        socket when the arena is full.  Returns ``(fields, raw, desc)`` —
        free ``desc`` after the call that consumed it returns."""
        try:
            desc = self.inbox.put(payload)
            return {"desc": desc}, b"", desc
        except ArenaFullError:
            return {"desc": None}, payload, None

    def fetch_reply(self, rep: dict, raw: bytes) -> np.ndarray:
        """Read an inbound payload (node -> driver) from the node's outbox
        (then free its frames) or from the raw socket bytes."""
        desc = rep.get("desc")
        buf = gather(self.outbox, desc, raw)
        if desc is not None:
            self.call("free", desc=desc)
        return buf

    def mark_dead(self) -> None:
        self.alive = False
        if self.conn is not None:
            self.conn.close()

    def sigkill(self) -> None:
        if self.proc is not None and self.proc.is_alive():
            try:
                os.kill(self.proc.pid, signal.SIGKILL)
            except ProcessLookupError:
                pass
            self.proc.join(10)

    def _unlink_arenas(self) -> None:
        for arena in (self.inbox, self.outbox):
            if arena is not None and arena.created:
                try:
                    arena.unlink()
                except Exception:
                    pass
        self.inbox = None
        self.outbox = None


class ProcCluster:
    """``Cluster(backend="proc")``: the same catalog/scheduler/statistics
    control plane as the in-process backend, with every ``StorageNode``
    hosted in its own OS process and page bytes moving through shared
    memory.  The scheduler is the *same* ``ClusterScheduler`` class — the
    handles duck-type ``alive``/``memory``/``pool.paging.sets`` — so
    placement, admission, and recovery costing are shared code, not a
    re-implementation."""

    backend = "proc"

    def __init__(self, num_nodes: int, node_capacity: int = 32 << 20,
                 page_size: int = 1 << 18, replication_factor: int = 1,
                 spill_dir: Optional[str] = None,
                 transfer_workers: int = 4, policy: str = "data-aware",
                 admission: bool = True,
                 admission_deadline_s: float = 0.05,
                 admission_timeout_s: float = 0.2,
                 pressure_watermark: float = 0.85,
                 pagelog_dir: Optional[str] = None,
                 pagelog_fsync: str = "none",
                 pagelog_compact_threshold: Optional[float] = None,
                 arena_bytes: int = 8 << 20,
                 arena_frame_bytes: int = 1 << 16,
                 rpc_chunk_bytes: int = 1 << 20,
                 rpc_timeout_s: float = 60.0):
        if num_nodes < 2:
            raise ValueError("a cluster needs at least 2 nodes")
        self.num_nodes = num_nodes
        self.node_capacity = node_capacity
        self.page_size = page_size
        self.replication_factor = replication_factor
        self.policy = policy
        self.admission = admission
        self.admission_deadline_s = admission_deadline_s
        self.admission_timeout_s = admission_timeout_s
        self.pressure_watermark = pressure_watermark
        self._spill_dir = spill_dir
        self._pagelog_dir = pagelog_dir
        self._pagelog_fsync = pagelog_fsync
        self._pagelog_compact_threshold = pagelog_compact_threshold
        self.arena_frame_bytes = int(arena_frame_bytes)
        self._inbox_frames = max(4, int(arena_bytes) // self.arena_frame_bytes)
        self._outbox_frames = self._inbox_frames
        self.rpc_chunk_bytes = int(rpc_chunk_bytes)
        self.rpc_timeout_s = float(rpc_timeout_s)
        self._ctx = mp.get_context("fork")  # spawn would re-import the world
        # Resolve the dispatch-plan kernel BEFORE the first fork: children
        # inherit the loaded module, instead of each paying the (possibly
        # jax-sized) import serially inside their first map call.
        _resolve_dispatch_plan()
        self.stats = StatisticsDB()
        self._segments: List[str] = []
        self.nodes: Dict[int, ProcNodeHandle] = {}
        for n in range(num_nodes):
            self.nodes[n] = ProcNodeHandle(self, n)
        self.driver_memory = MemoryManager(node_capacity, policy=policy)
        self.catalog: Dict[str, ShardedSet] = {}
        self.conflict_guards: Dict = {}
        self.durable_blobs: Dict[str, Tuple[int, int]] = {}
        self.scheduler = ClusterScheduler(self)
        self._transfer_workers = transfer_workers
        self._transfer: Optional[TransferEngine] = None
        self._acct_lock = tracked_lock("proc.acct")
        self.net_bytes = 0
        self.local_bytes = 0
        self._closed = False
        self._last_report: Optional[CleanupReport] = None

    # -- shared-with-Cluster plumbing -----------------------------------------
    def _node_spill_dir(self, node_id: int) -> Optional[str]:
        if self._spill_dir is None:
            return None
        return f"{self._spill_dir}/node{node_id}"

    def _node_pagelog_dir(self, node_id: int) -> Optional[str]:
        if self._pagelog_dir is None:
            return None
        return f"{self._pagelog_dir}/node{node_id}"

    def node(self, node_id: int) -> ProcNodeHandle:
        handle = self.nodes[node_id]
        if not handle.alive:
            raise DeadNodeError(f"node {node_id} is down")
        return handle

    def alive_node_ids(self) -> List[int]:
        return [n for n, h in self.nodes.items() if h.alive]

    def dead_node_ids(self) -> List[int]:
        return [n for n, h in self.nodes.items() if not h.alive]

    def conflict_guard(self, name_a: str, name_b: str, node: int):
        return None  # heterogeneous replica registration is inproc-only

    def add_net_bytes(self, n: int) -> None:
        with self._acct_lock:
            self.net_bytes += n

    def add_local_bytes(self, n: int) -> None:
        with self._acct_lock:
            self.local_bytes += n

    @property
    def transfer(self) -> TransferEngine:
        if self._transfer is None:
            cap = (derive_staging_cap(self.node_capacity,
                                      self.pressure_watermark)
                   if self.admission else None)
            self._transfer = TransferEngine(self._transfer_workers,
                                            name="transfer",
                                            dest_inflight_cap=cap)
        return self._transfer

    # -- membership -----------------------------------------------------------
    def _note_node_death(self, node_id: int) -> None:
        handle = self.nodes[node_id]
        if not handle.alive:
            return
        handle.mark_dead()
        handle.set_mirror.clear()
        self.stats.note_event()  # topology event: pressure snapshots stale

    def kill_node(self, node_id: int) -> None:
        """SIGKILL the node process — for this backend that IS the machine
        loss.  Scratch spill dies with the machine; the durable page log
        (a separate disk in the model) survives for warm recovery."""
        handle = self.nodes[node_id]
        handle.sigkill()
        self._note_node_death(node_id)
        sd = self._node_spill_dir(node_id)
        if sd is not None and os.path.isdir(sd):
            shutil.rmtree(sd, ignore_errors=True)
        handle._unlink_arenas()

    def revive_node(self, node_id: int,
                    warm: Optional[bool] = None) -> List[str]:
        handle = self.nodes[node_id]
        if handle.alive:
            raise ValueError(f"node {node_id} is alive; nothing to revive")
        if warm is None:
            warm = self._pagelog_dir is not None
        log_dir = self._node_pagelog_dir(node_id)
        if not warm and log_dir is not None and os.path.isdir(log_dir):
            shutil.rmtree(log_dir, ignore_errors=True)
        handle.spawn()  # the child's PageLog construction replays the index
        self.stats.note_event()
        return self._fence_pagelog(node_id)

    def _fence_pagelog(self, node_id: int) -> List[str]:
        """Same fence as ``Cluster._fence_pagelog`` with the log accessed
        over RPC: purge replayed sets the catalog no longer names on this
        node, or whose cataloged epoch outruns the log's."""
        handle = self.nodes[node_id]
        rep, _ = handle.call("log_sets")
        log_sets: Dict[str, int] = {name: int(e)
                                    for name, e in rep["sets"].items()}
        if not log_sets:
            return []
        valid: Dict[str, int] = {}
        for sset in self.catalog.values():
            info = sset.shards.get(node_id)
            if info is not None:
                valid[info.set_name] = info.epoch
            for oinfo in sset.shards.values():
                for holder, rep_name in oinfo.replicas:
                    if holder == node_id:
                        valid[rep_name] = oinfo.epoch
        for name, (nid, epoch) in self.durable_blobs.items():
            if nid == node_id:
                valid[name] = epoch
        fenced = [name for name, epoch in log_sets.items()
                  if name not in valid or epoch < valid[name]]
        if fenced:
            handle.call("log_drop", names=sorted(fenced))
        return sorted(fenced)

    # -- record movement ------------------------------------------------------
    def _send_records(self, node_id: int, set_name: str,
                      records: np.ndarray, dtype: np.dtype, page_size: int,
                      kind: str, expect_crc: Optional[int] = None) -> int:
        """Chunked driver -> node record write (inbox frames, socket
        fallback).  Returns the record bytes shipped."""
        handle = self.node(node_id)
        payload = records.tobytes()
        chunk = self.rpc_chunk_bytes
        offsets = list(range(0, len(payload), chunk)) or [0]
        for i, off in enumerate(offsets):
            piece = payload[off:off + chunk]
            done = i == len(offsets) - 1
            fields, raw, desc = handle.send_chunk(piece)
            fields.update(name=set_name, dtype=_dtype_to_wire(dtype),
                          page_size=page_size, kind=kind, done=done)
            if done and expect_crc is not None:
                fields["expect_crc"] = int(expect_crc)
            try:
                handle.call("write_set", raw=raw, **fields)
            finally:
                if desc is not None:
                    handle.inbox.free(desc)
        handle.set_mirror.add(set_name)
        return len(payload)

    def _fetch_set(self, node_id: int, set_name: str,
                   dtype: np.dtype) -> Tuple[np.ndarray, int]:
        """Stream a whole set driver-side; returns ``(records, crc)``."""
        handle = self.node(node_id)
        parts: List[np.ndarray] = []
        cursor = None
        while True:
            fields = {"name": set_name, "dtype": _dtype_to_wire(dtype),
                      "max_bytes": self.rpc_chunk_bytes}
            if cursor is not None:
                fields["cursor"] = cursor
            rep, raw = handle.call("export_set", **fields)
            parts.append(handle.fetch_reply(rep, raw))
            if rep["done"]:
                whole = (np.concatenate(parts) if parts
                         else np.empty(0, np.uint8))
                return whole.view(dtype), int(rep["crc"])
            cursor = rep["cursor"]

    def _copy_set(self, src_id: int, src_set: str, dst_id: int,
                  dst_set: str, dtype: np.dtype, page_size: int, kind: str,
                  expect_crc: Optional[int] = None) -> int:
        """Node-to-node set copy: the source exports record chunks into its
        outbox, the destination reads them straight out of that sibling
        segment — the bytes never visit the driver.  The destination
        verifies ``expect_crc`` in-node on the final chunk."""
        src = self.node(src_id)
        dst = self.node(dst_id)
        moved = 0
        cursor = None
        while True:
            fields = {"name": src_set, "dtype": _dtype_to_wire(dtype),
                      "max_bytes": self.rpc_chunk_bytes}
            if cursor is not None:
                fields["cursor"] = cursor
            rep, raw = src.call("export_set", **fields)
            desc = rep.get("desc")
            wfields = {"name": dst_set, "dtype": _dtype_to_wire(dtype),
                       "page_size": page_size, "kind": kind,
                       "done": bool(rep["done"]), "desc": desc}
            if desc is not None:
                wfields.update(seg=src.outbox.name,
                               frame_size=src.outbox.frame_size,
                               num_frames=src.outbox.num_frames)
            if rep["done"] and expect_crc is not None:
                wfields["expect_crc"] = int(expect_crc)
            try:
                dst.call("write_set", raw=raw, **wfields)
            finally:
                if desc is not None:
                    src.call("free", desc=desc)
            moved += int(rep["nbytes"])
            if rep["done"]:
                break
            cursor = rep["cursor"]
        dst.set_mirror.add(dst_set)
        if src_id == dst_id:
            self.add_local_bytes(moved)
        else:
            self.add_net_bytes(moved)
        return moved

    # -- raw byte blobs (serving KV slabs and other unsharded payloads) -------
    def store_bytes(self, node_id: int, name: str, data: bytes) -> int:
        """Blob write over RPC: the bytes land in the node *process*'s pool
        (drop-before-rewrite), so a serving replica slab physically outlives
        a SIGKILL of the sequence's primary node."""
        handle = self.node(node_id)
        handle.call("drop_set", name=name)
        recs = np.frombuffer(bytes(data), dtype=np.uint8)
        return self._send_records(node_id, name, recs, np.dtype(np.uint8),
                                  self.page_size, "none")

    def load_bytes(self, node_id: int, name: str) -> bytes:
        handle = self.node(node_id)
        if name not in handle.set_mirror:
            raise KeyError(name)
        recs, _crc = self._fetch_set(node_id, name, np.dtype(np.uint8))
        return recs.tobytes()

    def drop_bytes(self, node_id: int, name: str) -> None:
        handle = self.nodes[node_id]
        if handle.alive and name in handle.set_mirror:
            try:
                handle.call("drop_set", name=name)
            except DeadNodeError:
                pass  # died under us: its blobs are gone anyway
            handle.set_mirror.discard(name)

    def has_bytes(self, node_id: int, name: str) -> bool:
        handle = self.nodes[node_id]
        return bool(handle.alive and name in handle.set_mirror)

    # -- sharded sets ---------------------------------------------------------
    def create_sharded_set(self, name: str, records: np.ndarray,
                           key_fn: Callable[[np.ndarray], np.ndarray],
                           partitions_per_node: int = 4,
                           page_size: Optional[int] = None,
                           replication_factor: Optional[int] = None,
                           attrs_factory: Optional[Callable] = None,
                           partition_key: Optional[str] = None,
                           node_ids: Optional[Sequence[int]] = None,
                           ) -> ShardedSet:
        if name in self.catalog:
            raise ValueError(f"sharded set {name!r} already exists")
        factor = (self.replication_factor if replication_factor is None
                  else replication_factor)
        page_size = page_size or self.page_size
        domain = (list(node_ids) if node_ids is not None
                  else self.alive_node_ids())
        if not domain:
            raise DeadNodeError("no alive nodes to place a sharded set on")
        if factor >= len(domain):
            raise ValueError(f"replication factor {factor} needs more than "
                             f"{len(domain)} nodes")
        scheme = PartitionScheme(partition_key or name, key_fn,
                                 partitions_per_node * len(domain),
                                 len(domain))
        sset = ShardedSet(name, records.dtype, scheme, page_size, factor,
                          node_ids=domain)
        if attrs_factory is None and self._pagelog_dir is not None:
            attrs_factory = user_data_attrs
        kind = _attrs_kind(attrs_factory)
        sset.attrs_factory = attrs_factory
        slots = sset.scheme.node_of_records(records)
        order, _counts, offsets = dispatch_plan(slots, len(domain))
        routed = records[order]
        epoch = self.stats.event_seq
        # One engine job per destination write: sends to different node
        # processes overlap, so the durable tier's per-page fsyncs (and any
        # spill) pay once per node in wall-clock, not once per write — the
        # in-process backend necessarily serializes this loop.  Replicas
        # chain off their primary and stream child-to-child through sibling
        # shm (the driver never re-ships the bytes), CRC-verified in the
        # holder's process.
        jobs = []
        for slot, nid in enumerate(domain):
            shard = routed[offsets[slot]:offsets[slot + 1]]
            info = ShardInfo(node_id=nid,
                             set_name=sset.primary_set_name(nid),
                             num_records=len(shard),
                             checksum=shard_checksum(shard),
                             content_checksum=record_content_checksum(shard),
                             epoch=epoch)
            primary = self.transfer.submit(
                self._send_records, nid, info.set_name, shard, sset.dtype,
                page_size, kind, label=f"{name}/shard{nid}")
            jobs.append(primary)
            for hslot in replica_nodes(slot, len(domain), factor):
                holder = domain[hslot]
                rep_name = sset.replica_set_name(nid, holder)
                jobs.append(self.transfer.submit(
                    self._copy_set, nid, info.set_name, holder, rep_name,
                    sset.dtype, page_size, kind, info.checksum,
                    after=(primary,),
                    label=f"{name}/replica{nid}@{holder}"))
                info.replicas.append((holder, rep_name))
            sset.shards[nid] = info
        for fut in jobs:
            fut.result()
        self.catalog[name] = sset
        self.stats.register_replica(name, Cluster._replica_info(self, sset))
        self.stats.note_event()
        return sset

    def read_shard_from(self, sset: ShardedSet,
                        node_id: int) -> Tuple[int, np.ndarray]:
        info = sset.shards[node_id]
        mismatches: List[str] = []
        for holder, set_name in self.scheduler.read_sources(sset, node_id):
            recs, crc = self._fetch_set(holder, set_name, sset.dtype)
            if holder == node_id or crc == info.checksum:
                return holder, recs
            mismatches.append(f"{set_name}@{holder}")
        detail = (f" (checksum mismatch on {', '.join(mismatches)})"
                  if mismatches else "")
        raise DeadNodeError(
            f"node {node_id} is down and no verified replica of "
            f"{sset.name!r} shard {node_id} survives{detail}")

    def read_shard(self, sset: ShardedSet, node_id: int) -> np.ndarray:
        return self.read_shard_from(sset, node_id)[1]

    def read_sharded(self, sset: ShardedSet) -> np.ndarray:
        parts = [self.read_shard(sset, n) for n in sorted(sset.shards)]
        return np.concatenate(parts) if parts else np.empty(0, sset.dtype)

    def drop_sharded_set(self, sset: ShardedSet) -> None:
        for n, info in sset.shards.items():
            targets = [(n, info.set_name)] + list(info.replicas)
            for holder, set_name in targets:
                handle = self.nodes[holder]
                if handle.alive and set_name in handle.set_mirror:
                    handle.call("drop_set", name=set_name)
                    handle.set_mirror.discard(set_name)
        self.catalog.pop(sset.name, None)
        self.stats.note_event()

    # -- recovery -------------------------------------------------------------
    def recover_node(self, node_id: int) -> RecoveryReport:
        """Same recovery walk as the in-process backend — warm log adoption
        first when the scheduler costs it cheapest, else replica copies
        (node-to-node through sibling shm, CRC-verified in the destination
        process)."""
        t0 = time.perf_counter()
        report = RecoveryReport(node_id=node_id)
        report.fenced_sets = self.revive_node(node_id)
        for sset in self.catalog.values():
            kind = _attrs_kind(sset.attrs_factory)
            info = sset.shards.get(node_id)
            if info is not None:
                if not self._recover_shard(sset, info, node_id, kind,
                                           report):
                    report.checksum_failures.append(
                        f"{sset.name}: no surviving replica of shard "
                        f"{node_id}")
            for owner, oinfo in sset.shards.items():
                if owner == node_id:
                    continue
                for holder, rep_name in oinfo.replicas:
                    if holder != node_id:
                        continue
                    if self._warm_restore(node_id, rep_name, sset,
                                          oinfo.checksum, kind):
                        report.warm_replicas += 1
                        report.replicas_rebuilt += 1
                        continue
                    try:
                        report.bytes_transferred += self._copy_set(
                            owner, oinfo.set_name, node_id, rep_name,
                            sset.dtype, sset.page_size, kind,
                            expect_crc=oinfo.checksum)
                    except Exception:
                        report.checksum_failures.append(
                            f"{sset.name}: checksum mismatch on replica of "
                            f"shard {owner} at {node_id}")
                    report.replicas_rebuilt += 1
        report.seconds = time.perf_counter() - t0
        return report

    def _warm_restore(self, node_id: int, set_name: str, sset: ShardedSet,
                      expect_crc: int, kind: str) -> bool:
        if self._pagelog_dir is None:
            return False
        handle = self.nodes[node_id]
        if not handle.alive:
            return False
        rep, _ = handle.call("warm_restore", name=set_name,
                             page_size=sset.page_size,
                             dtype=_dtype_to_wire(sset.dtype),
                             expect_crc=int(expect_crc), kind=kind)
        if rep["adopted"]:
            handle.set_mirror.add(set_name)
            return True
        return False

    def _recover_shard(self, sset: ShardedSet, info: ShardInfo,
                       node_id: int, kind: str,
                       report: RecoveryReport) -> bool:
        for src in self.scheduler.recovery_plan(sset, node_id, node_id):
            if src.kind == "pagelog":
                if self._warm_restore(node_id, info.set_name, sset,
                                      info.checksum, kind):
                    report.sources[f"{sset.name}:{node_id}"] = "pagelog"
                    report.shards_recovered += 1
                    report.warm_shards += 1
                    return True
                continue
            if src.kind == "rebuild":
                # heterogeneous-replica rebuild is inproc-only (the proc
                # backend never registers replica pairs)
                continue
            try:
                report.bytes_transferred += self._copy_set(
                    src.holder, src.set_name, node_id, info.set_name,
                    sset.dtype, sset.page_size, kind,
                    expect_crc=info.checksum)
            except Exception:
                report.checksum_failures.append(
                    f"{sset.name}: checksum mismatch on shard {node_id} "
                    f"from {src.kind}@{src.holder}")
                self.nodes[node_id].call("drop_set", name=info.set_name)
                self.nodes[node_id].set_mirror.discard(info.set_name)
                continue
            report.sources[f"{sset.name}:{node_id}"] = \
                f"{src.kind}@{src.holder}"
            report.shards_recovered += 1
            return True
        return False

    # -- shuffles -------------------------------------------------------------
    def shuffle(self, name: str, num_reducers: int, dtype: np.dtype,
                page_size: Optional[int] = None,
                admission: Optional[bool] = None,
                columnar: bool = False) -> "ProcShuffle":
        return ProcShuffle(self, name, num_reducers, dtype,
                           page_size=page_size, admission=admission,
                           columnar=columnar)

    # -- observability --------------------------------------------------------
    def pressure_report(self) -> Dict[int, dict]:
        return {n: h.memory.pressure_report()
                for n, h in sorted(self.nodes.items()) if h.alive}

    def pagelog_report(self) -> Dict[int, dict]:
        return {n: h.call("log_report")[0]
                for n, h in sorted(self.nodes.items()) if h.alive}

    # -- lifecycle ------------------------------------------------------------
    def close(self) -> CleanupReport:
        """Graceful teardown + the leak audit the tests assert on: no node
        process survives, no shm segment remains linked."""
        if self._closed:
            return self._last_report or CleanupReport()
        self._closed = True
        if self._transfer is not None:
            self._transfer.shutdown()
        for handle in self.nodes.values():
            if handle.alive:
                try:
                    handle.call("close")
                except (DeadNodeError, Exception):
                    pass
            handle.mark_dead()
            if handle.proc is not None:
                handle.proc.join(5)
                if handle.proc.is_alive():
                    handle.proc.terminate()
                    handle.proc.join(2)
                if handle.proc.is_alive():  # pragma: no cover
                    handle.proc.kill()
                    handle.proc.join(2)
            handle._unlink_arenas()
        orphans = [h.node_id for h in self.nodes.values()
                   if h.proc is not None and h.proc.is_alive()]
        leaked = [name for name in self._segments if segment_exists(name)]
        self._last_report = CleanupReport(orphan_processes=orphans,
                                          leaked_segments=leaked)
        return self._last_report

    def shutdown(self) -> CleanupReport:
        return self.close()

    def __enter__(self) -> "ProcCluster":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class ProcShuffle:
    """Driver-side orchestration of a shuffle across node processes.

    Map tasks are one RPC per shard, submitted as transfer-engine jobs:
    the worker thread blocks in ``recv`` (GIL released) while the node
    process partitions, writes, throttles on admission, and spills — so on
    N nodes those phases genuinely overlap, where the in-process
    ``map_sharded`` runs them through one serial driver loop.  Partition
    pulls move whole page images node-to-node through sibling outbox
    frames; the driver only relays descriptors."""

    def __init__(self, cluster: ProcCluster, name: str, num_reducers: int,
                 dtype: np.dtype, page_size: Optional[int] = None,
                 admission: Optional[bool] = None, columnar: bool = False):
        self.cluster = cluster
        self.name = name
        self.num_reducers = num_reducers
        self.dtype = np.dtype(dtype)
        self.page_size = page_size or cluster.page_size
        self.columnar = columnar
        self.admission = (cluster.admission if admission is None
                          else admission)
        self.scheduler = cluster.scheduler
        self.placement: Optional[Dict[int, int]] = None
        self.diversions: Dict[int, Tuple[int, int]] = {}
        self._lock = tracked_lock("proc.shuffle")
        self._begun: set = set()
        # worker node -> [(sset, shard_id, key_field, batch, n)]
        self._work: Dict[int, List[tuple]] = {}
        self._finished: set = set()
        self._done_pairs: set = set()  # (reducer, src) moved to its reducer
        self._landed: set = set()      # reducers fully landed
        self._dead_handled: set = set()

    # -- map side -------------------------------------------------------------
    def _ensure_begun(self, node_id: int) -> None:
        with self._lock:
            if node_id in self._begun:
                return
            self._begun.add(node_id)
        self.cluster.node(node_id).call(
            "shuffle_begin", shuffle=self.name,
            num_reducers=self.num_reducers,
            dtype=_dtype_to_wire(self.dtype), page_size=self.page_size,
            columnar=self.columnar, admission=self.admission)

    def map_shard(self, sset: ShardedSet, shard_id: int,
                  key_field: Optional[str] = None,
                  batch: int = 65536) -> int:
        sources = self.scheduler.read_sources(sset, shard_id)
        if not sources:
            raise DeadNodeError(
                f"no surviving copy of {sset.name!r} shard {shard_id}")
        worker, set_name = sources[0]
        self._ensure_begun(worker)
        rep, _ = self.cluster.node(worker).call(
            "map_set", shuffle=self.name, set_name=set_name,
            key_field=key_field, batch=batch)
        with self._lock:
            self._work.setdefault(worker, []).append(
                (sset, shard_id, key_field, batch, int(rep["records"])))
        return worker

    def map_sharded(self, sset: ShardedSet, key_field: Optional[str] = None,
                    batch: int = 65536) -> None:
        """Map every shard concurrently — one engine job per shard, each a
        blocking RPC into the shard holder's process.  A node process dying
        mid-map is re-executed from a replica holder (same recovery rule as
        the in-process straggler path)."""
        jobs = [(n, self.cluster.transfer.submit(
                    self.map_shard, sset, n, key_field, batch,
                    label=f"{self.name}/map{n}"))
                for n in sorted(sset.shards)]
        for shard_id, fut in jobs:
            try:
                fut.result()
            except NodeDiedError as exc:
                self._recover_dead(getattr(exc, "node_id", shard_id))
                self.map_shard(sset, shard_id, key_field, batch)

    def _finish_one(self, node_id: int) -> None:
        rep, _ = self.cluster.node(node_id).call("map_finish",
                                                 shuffle=self.name)
        for r, nbytes in enumerate(rep["partition_bytes"]):
            self.cluster.stats.record_shuffle_bytes(self.name, r, node_id,
                                                    int(nbytes))
        self.cluster.stats.record_node_pressure(node_id,
                                                float(rep["pressure"]))
        with self._lock:
            self._finished.add(node_id)

    def finish_maps(self) -> None:
        jobs = [(n, self.cluster.transfer.submit(
                    self._finish_one, n, label=f"{self.name}/finish{n}"))
                for n in sorted(self._work)]
        for node_id, fut in jobs:
            try:
                fut.result()
            except NodeDiedError as exc:
                self._recover_dead(getattr(exc, "node_id", node_id))

    # -- placement ------------------------------------------------------------
    def reducer_node(self, reducer: int) -> int:
        if self.placement is not None and reducer in self.placement:
            node = self.placement[reducer]
            if self.cluster.nodes[node].alive:
                return node
        alive = self.cluster.alive_node_ids()
        return alive[reducer % len(alive)]

    def assign_placement(self, placement: Dict[int, int]) -> None:
        self.placement = dict(placement)

    def place_reducers_locally(self) -> Dict[int, int]:
        if self.admission:
            plan = self.scheduler.place_reducers_admitted(
                self.name, self.num_reducers,
                deadline_s=self.cluster.admission_deadline_s)
            self.diversions = dict(plan.diversions)
            self.assign_placement(plan.placement)
        else:
            self.assign_placement(self.scheduler.place_reducers(
                self.name, self.num_reducers))
        return self.placement

    # -- death mid-shuffle ----------------------------------------------------
    def _recover_dead(self, dead: int) -> None:
        """Ride the replica recovery path for a SIGKILLed mapper: its map
        output died with its pool, so its shards re-map on surviving copy
        holders and the byte statistics re-publish (``record_shuffle_bytes``
        overwrites).  Only legal before any partition landed — afterwards
        surviving services were already partially drained, and a re-map
        would double-count records into pulled partitions."""
        with self._lock:
            if dead in self._dead_handled:
                return
            self._dead_handled.add(dead)
            items = self._work.pop(dead, [])
            refinish = dead in self._finished
        if self._done_pairs:
            raise DeadNodeError(
                f"node {dead} died after reduce pulls began; the shuffle "
                f"must re-run")
        for r in range(self.num_reducers):
            self.cluster.stats.record_shuffle_bytes(self.name, r, dead, 0)
        touched: set = set()
        for (sset, shard_id, key_field, batch, _n) in items:
            worker = self.map_shard(sset, shard_id, key_field, batch)
            touched.add(worker)
        if refinish:
            for worker in sorted(touched):
                self._finish_one(worker)
        if self.placement is not None:
            for r, node in list(self.placement.items()):
                if node == dead:
                    ranked, _total = self.scheduler._rank_candidates(
                        [self.name], r, self.reducer_node(r))
                    self.placement[r] = ranked[0]

    # -- reduce side ----------------------------------------------------------
    def _move_partition(self, src_id: int, dst_id: int, reducer: int) -> None:
        src = self.cluster.node(src_id)
        dst = self.cluster.node(dst_id)
        while True:
            rep, raw = src.call("export_part", shuffle=self.name,
                                reducer=reducer,
                                max_bytes=self.cluster.rpc_chunk_bytes)
            desc = rep.get("desc")
            fields = {"shuffle": self.name, "reducer": reducer,
                      "src_node": src_id, "sizes": rep["sizes"],
                      "crc": rep["crc"], "done": rep["done"], "desc": desc}
            if desc is not None:
                fields.update(seg=src.outbox.name,
                              frame_size=src.outbox.frame_size,
                              num_frames=src.outbox.num_frames)
            if not self.columnar:
                fields["small_page"] = rep["small_page"]
            elif rep["done"]:
                fields["crcs"] = rep.get("crcs", [])
            try:
                dst.call("import_part", raw=raw, **fields)
            finally:
                if desc is not None:
                    try:
                        src.call("free", desc=desc)
                    except DeadNodeError:
                        pass
            self.cluster.add_net_bytes(int(rep["nbytes"]))
            if rep["done"]:
                break
        src.call("release_part", shuffle=self.name, reducer=reducer)

    def _land(self, reducer: int) -> int:
        """Move partition ``reducer`` from every map node to its reducer
        node (page images through sibling shm; the driver relays only
        descriptors).  Returns the destination node id."""
        if reducer in self._landed:
            return self.reducer_node(reducer)
        attempts = 0
        while True:
            try:
                for n in sorted(self._work):
                    if not self.cluster.nodes[n].alive:
                        self._recover_dead(n)
                dst_id = self.reducer_node(reducer)
                dst = self.cluster.node(dst_id)
                self._ensure_begun(dst_id)
                for src_id in sorted(self._work):
                    if (reducer, src_id) in self._done_pairs:
                        continue
                    if src_id == dst_id:
                        rep, _ = dst.call("local_attach", shuffle=self.name,
                                          reducer=reducer)
                        self.cluster.add_local_bytes(int(rep["nbytes"]))
                    else:
                        self._move_partition(src_id, dst_id, reducer)
                    self._done_pairs.add((reducer, src_id))
                self._landed.add(reducer)
                return dst_id
            except NodeDiedError as exc:
                attempts += 1
                if attempts > 2:
                    raise
                dead = getattr(exc, "node_id", None)
                if dead is not None:
                    self._recover_dead(dead)
                # else: the dead-node sweep at the top of the retry finds it

    def pull(self, reducer: int) -> np.ndarray:
        """Land partition ``reducer`` on its reducer node, then materialize
        it driver-side (record chunks in source-node order — the same
        concatenation order as the in-process backend's ``pull``)."""
        dst_id = self._land(reducer)
        dst = self.cluster.node(dst_id)
        parts: List[np.ndarray] = []
        cursor = None
        while True:
            fields = {"shuffle": self.name, "reducer": reducer,
                      "max_bytes": self.cluster.rpc_chunk_bytes}
            if cursor is not None:
                fields["cursor"] = cursor
            rep, raw = dst.call("reduce_read", **fields)
            parts.append(dst.fetch_reply(rep, raw))
            if rep["done"]:
                break
            cursor = rep["cursor"]
        whole = np.concatenate(parts) if parts else np.empty(0, np.uint8)
        return whole.view(self.dtype)

    def pull_remote(self, reducer: int) -> dict:
        """Land the partition and verify it where it lies: the reducer node
        computes count + content checksum in-process, so reduce-side work
        overlaps landing and nothing rides the driver socket but a dict."""
        dst_id = self._land(reducer)
        rep, _ = self.cluster.node(dst_id).call(
            "reduce_stats", shuffle=self.name, reducer=reducer)
        return {"node": dst_id, "num_records": int(rep["num_records"]),
                "content_crc": int(rep["content_crc"])}

    def pull_async(self, reducer: int, after: Sequence = ()):
        return self.cluster.transfer.submit(
            self.pull_remote, reducer, after=after,
            label=f"{self.name}/pull{reducer}",
            dest=lambda: self.reducer_node(reducer),
            nbytes=lambda: sum(self.cluster.stats.shuffle_partition_bytes(
                self.name, reducer).values()))

    def release_reducer(self, reducer: int) -> None:
        try:
            self.cluster.node(self.reducer_node(reducer)).call(
                "reduce_release", shuffle=self.name, reducer=reducer)
        except DeadNodeError:
            pass
