"""Multi-node cluster runtime over unified buffer pools — paper §2, §7–§9.

This is the layer that turns the single-node mechanisms (TLSF arena, unified
buffer pool, data-aware paging, services) into the system the paper evaluates:

* ``StorageNode`` — one storage service instance: its own ``BufferPool`` +
  spill store, holding the node's locality sets.
* ``Cluster`` — N nodes plus the manager-side catalog (``StatisticsDB``).
  Sharded locality sets are routed across nodes by hash partition
  (``PartitionScheme``); each shard is also chain-replicated to
  ``replication_factor`` other nodes through the node-to-node transfer path,
  with CRC32 checksums recorded in the catalog.
* ``ClusterShuffle`` — the distributed shuffle service: map-side output is
  written as job-data pages into each mapper's *local* pool (one virtual
  shuffle buffer per reducer, paper §8); reducers pull their partition from
  every map node over the transfer path, then the map output's lifetime is
  ended so its pages become free eviction victims (paper §6).
* ``cluster_hash_aggregate`` — the paper §9 Spark-comparison workload:
  shuffle-by-key-hash to R reducers, per-reducer ``HashService`` aggregation
  in the local pool, disjoint merge at the driver.
* Replica-based recovery — ``kill_node`` loses a pool wholesale;
  ``recover_node`` re-materializes the node's primary shards from surviving
  replicas and re-replicates what the node hosted for others, verifying every
  rebuilt shard against its cataloged checksum.

Everything moves through buffer pools: a "network transfer" is a paged read
from the source pool streamed into a sequential write on the destination pool,
with byte accounting standing in for the wire.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from ..core.attributes import AttributeSet
from ..core.buffer_pool import BufferPool, SpillStore
from ..core.locality_set import LocalitySet
from ..core.replication import (PartitionScheme, replica_nodes,
                                shard_checksum)
from ..core.services import (HashService, PageIterator, SequentialWriter,
                             ShuffleService, job_data_attrs, read_all)
from ..core.statistics import ReplicaInfo, StatisticsDB


def _host_dispatch_plan(partition_ids: np.ndarray, num_partitions: int):
    """Host-side analogue of ``kernels/shuffle_dispatch``'s slot assignment;
    the device kernel version is preferred when importable."""
    order = np.argsort(partition_ids, kind="stable")
    counts = np.bincount(partition_ids, minlength=num_partitions)
    offsets = np.concatenate([[0], np.cumsum(counts)])
    return order, counts, offsets


_dispatch_plan_impl = None


def dispatch_plan(partition_ids: np.ndarray, num_partitions: int):
    """Group a batch by destination partition in one stable pass. Mirrors the
    MoE shuffle-dispatch slot assignment (``kernels/shuffle_dispatch``), whose
    host-side helper is used when available; records land contiguously per
    partition: ``order[offsets[p]:offsets[p+1]]`` are partition ``p``'s rows."""
    global _dispatch_plan_impl
    if _dispatch_plan_impl is None:
        # resolve once: a failed import is not cached by Python, so retrying
        # per batch would re-run the whole failing jax import each call
        try:
            from ..kernels.shuffle_dispatch.ops import host_dispatch_plan
            _dispatch_plan_impl = host_dispatch_plan
        except ImportError:  # kernels need jax; the cluster runtime must not
            _dispatch_plan_impl = _host_dispatch_plan
    return _dispatch_plan_impl(partition_ids, num_partitions)


class DeadNodeError(RuntimeError):
    """Raised when touching a node that has been killed and not recovered."""


class StorageNode:
    """One Pangea storage service: a unified buffer pool plus its spill store
    (paper §2 — every node runs one storage process owning all its data)."""

    def __init__(self, node_id: int, capacity: int,
                 spill_dir: Optional[str] = None):
        self.node_id = node_id
        self.capacity = capacity
        self.pool = BufferPool(capacity, SpillStore(spill_dir))
        self.alive = True

    def write_records(self, set_name: str, records: np.ndarray,
                      dtype: np.dtype, page_size: int,
                      attrs: Optional[AttributeSet] = None) -> LocalitySet:
        ls = self.pool.create_set(set_name, page_size, attrs)
        w = SequentialWriter(self.pool, ls, dtype)
        if len(records):
            w.append_batch(records)
        w.close()
        return ls

    def read_records(self, set_name: str, dtype: np.dtype) -> np.ndarray:
        return read_all(self.pool, self.pool.get_set(set_name), dtype)


@dataclass
class ShardInfo:
    """Catalog entry for one primary shard of a sharded locality set."""

    node_id: int
    set_name: str
    num_records: int
    checksum: int
    replicas: List[Tuple[int, str]] = field(default_factory=list)


class ShardedSet:
    """A logical dataset hash-partitioned across the cluster's pools.

    ``shards[n]`` describes node ``n``'s primary shard; replicas live on the
    chain successors. All placement follows ``scheme`` (fib-hash of the key,
    partitions folded onto nodes), so any node can compute routing locally.
    """

    def __init__(self, name: str, dtype: np.dtype, scheme: PartitionScheme,
                 page_size: int, replication_factor: int):
        self.name = name
        self.dtype = np.dtype(dtype)
        self.scheme = scheme
        self.page_size = page_size
        self.replication_factor = replication_factor
        self.shards: Dict[int, ShardInfo] = {}

    def primary_set_name(self, node_id: int) -> str:
        return f"{self.name}/shard{node_id}"

    def replica_set_name(self, owner: int, holder: int) -> str:
        return f"{self.name}/shard{owner}/replica@{holder}"


@dataclass
class RecoveryReport:
    node_id: int
    shards_recovered: int = 0
    replicas_rebuilt: int = 0
    bytes_transferred: int = 0
    checksum_failures: List[str] = field(default_factory=list)
    seconds: float = 0.0

    @property
    def ok(self) -> bool:
        return not self.checksum_failures


class Cluster:
    """N storage nodes + the manager node's catalog (paper §2 architecture).

    The manager here is in-process: ``catalog`` maps sharded-set names to
    their shard/replica/checksum metadata, and ``stats`` is the paper's
    statistics database used by query planning (``best_replica``).
    """

    def __init__(self, num_nodes: int, node_capacity: int = 32 << 20,
                 page_size: int = 1 << 18, replication_factor: int = 1,
                 spill_dir: Optional[str] = None):
        if num_nodes < 2:
            raise ValueError("a cluster needs at least 2 nodes")
        self.num_nodes = num_nodes
        self.node_capacity = node_capacity
        self.page_size = page_size
        self.replication_factor = replication_factor
        self._spill_dir = spill_dir
        self.nodes: Dict[int, StorageNode] = {
            n: StorageNode(n, node_capacity, self._node_spill_dir(n))
            for n in range(num_nodes)
        }
        self.stats = StatisticsDB()
        self.catalog: Dict[str, ShardedSet] = {}
        self.net_bytes = 0          # bytes that crossed node boundaries
        self.local_bytes = 0        # bytes moved pool->pool on one node

    def _node_spill_dir(self, node_id: int) -> Optional[str]:
        if self._spill_dir is None:
            return None
        return f"{self._spill_dir}/node{node_id}"

    # -- membership -----------------------------------------------------------
    def node(self, node_id: int) -> StorageNode:
        node = self.nodes[node_id]
        if not node.alive:
            raise DeadNodeError(f"node {node_id} is down")
        return node

    def alive_node_ids(self) -> List[int]:
        return [n for n, node in self.nodes.items() if node.alive]

    def kill_node(self, node_id: int) -> None:
        """Simulate a machine loss: the node's pool, spill store, and every
        locality set on it are gone."""
        node = self.nodes[node_id]
        node.alive = False
        node.pool = None  # drop the arena; nothing on this node survives

    # -- node-to-node transfer path -------------------------------------------
    def transfer_records(self, src_id: int, src_set: str, dst_id: int,
                         dst_set: str, dtype: np.dtype,
                         page_size: Optional[int] = None,
                         attrs: Optional[AttributeSet] = None) -> int:
        """Stream one locality set between pools page by page (the cluster's
        "network": paged reads on the source, sequential writes on the
        destination). Returns bytes moved; cross-node bytes are tallied as
        network traffic, same-node as pool-local copies."""
        src = self.node(src_id)
        dst = self.node(dst_id)
        dtype = np.dtype(dtype)
        ls_src = src.pool.get_set(src_set)
        ls_dst = dst.pool.create_set(dst_set, page_size or self.page_size,
                                     attrs)
        writer = SequentialWriter(dst.pool, ls_dst, dtype)
        moved = 0
        for recs in PageIterator(src.pool, ls_src, dtype, sorted(ls_src.pages)):
            writer.append_batch(recs)
            moved += recs.nbytes
        writer.close()
        if src_id == dst_id:
            self.local_bytes += moved
        else:
            self.net_bytes += moved
        return moved

    # -- sharded locality sets ------------------------------------------------
    def create_sharded_set(self, name: str, records: np.ndarray,
                           key_fn: Callable[[np.ndarray], np.ndarray],
                           partitions_per_node: int = 4,
                           page_size: Optional[int] = None,
                           replication_factor: Optional[int] = None,
                           attrs_factory: Optional[Callable[[], AttributeSet]] = None,
                           ) -> ShardedSet:
        """Hash-partition ``records`` across every node's pool and
        chain-replicate each shard (paper §7 applied at page level: the
        replica IS another locality set, just on a different node). Requires
        all nodes alive — the scheme routes over the full membership;
        recover dead nodes first (shrinking placement to survivors is the
        elastic-remesh follow-up in ROADMAP.md)."""
        if name in self.catalog:
            raise ValueError(f"sharded set {name!r} already exists")
        factor = (self.replication_factor if replication_factor is None
                  else replication_factor)
        page_size = page_size or self.page_size
        scheme = PartitionScheme(name, key_fn,
                                 partitions_per_node * self.num_nodes,
                                 self.num_nodes)
        sset = ShardedSet(name, records.dtype, scheme, page_size, factor)
        placement = scheme.node_of_records(records)
        order, counts, offsets = dispatch_plan(placement, self.num_nodes)
        routed = records[order]
        for n in range(self.num_nodes):
            shard = routed[offsets[n]:offsets[n + 1]]
            attrs = attrs_factory() if attrs_factory else None
            self.node(n).write_records(sset.primary_set_name(n), shard,
                                       sset.dtype, page_size, attrs)
            info = ShardInfo(node_id=n, set_name=sset.primary_set_name(n),
                             num_records=len(shard),
                             checksum=shard_checksum(shard))
            for holder in replica_nodes(n, self.num_nodes, factor):
                rep_name = sset.replica_set_name(n, holder)
                self.transfer_records(n, info.set_name, holder, rep_name,
                                      sset.dtype, page_size)
                info.replicas.append((holder, rep_name))
            sset.shards[n] = info
        self.catalog[name] = sset
        self.stats.register_replica(name, ReplicaInfo(
            set_name=name, partition_key=scheme.name,
            num_partitions=scheme.num_partitions, num_nodes=self.num_nodes,
            page_size=page_size, extra={"replication_factor": factor}))
        return sset

    def read_shard(self, sset: ShardedSet, node_id: int) -> np.ndarray:
        return self.node(node_id).read_records(
            sset.primary_set_name(node_id), sset.dtype)

    def read_sharded(self, sset: ShardedSet) -> np.ndarray:
        """Gather every primary shard (raises DeadNodeError if an owner is
        down and unrecovered — exactly what recovery exists to prevent)."""
        parts = [self.read_shard(sset, n) for n in sorted(sset.shards)]
        return np.concatenate(parts) if parts else np.empty(0, sset.dtype)

    def drop_sharded_set(self, sset: ShardedSet) -> None:
        for n, info in sset.shards.items():
            node = self.nodes[n]
            if node.alive and info.set_name in node.pool.paging.sets:
                node.pool.drop_set(node.pool.get_set(info.set_name))
            for holder, rep_name in info.replicas:
                hnode = self.nodes[holder]
                if hnode.alive and rep_name in hnode.pool.paging.sets:
                    hnode.pool.drop_set(hnode.pool.get_set(rep_name))
        self.catalog.pop(sset.name, None)

    # -- replica-based recovery (paper §7) ------------------------------------
    def recover_node(self, node_id: int) -> RecoveryReport:
        """Bring a fresh node up under the failed node's identity and rebuild
        its state through the buffer pools:

        1. every primary shard it owned is re-materialized from a surviving
           chain replica and verified against the cataloged CRC32;
        2. every replica it held for other owners is re-replicated from the
           (alive) primary, restoring the replication factor.
        """
        t0 = time.perf_counter()
        report = RecoveryReport(node_id=node_id)
        node = self.nodes[node_id]
        if node.alive:
            raise ValueError(f"node {node_id} is alive; nothing to recover")
        node.pool = BufferPool(node.capacity,
                               SpillStore(self._node_spill_dir(node_id)))
        node.alive = True
        for sset in self.catalog.values():
            info = sset.shards.get(node_id)
            if info is not None:
                source = next(
                    ((holder, rep) for holder, rep in info.replicas
                     if self.nodes[holder].alive), None)
                if source is None:
                    report.checksum_failures.append(
                        f"{sset.name}: no surviving replica of shard "
                        f"{node_id}")
                else:
                    holder, rep_name = source
                    report.bytes_transferred += self.transfer_records(
                        holder, rep_name, node_id, info.set_name, sset.dtype,
                        sset.page_size)
                    rebuilt = self.read_shard(sset, node_id)
                    if shard_checksum(rebuilt) != info.checksum:
                        report.checksum_failures.append(
                            f"{sset.name}: checksum mismatch on shard "
                            f"{node_id}")
                    report.shards_recovered += 1
            # replicas this node held for other owners
            for owner, oinfo in sset.shards.items():
                if owner == node_id:
                    continue
                for holder, rep_name in oinfo.replicas:
                    if holder != node_id:
                        continue
                    report.bytes_transferred += self.transfer_records(
                        owner, oinfo.set_name, node_id, rep_name, sset.dtype,
                        sset.page_size)
                    rebuilt = self.nodes[node_id].read_records(rep_name,
                                                               sset.dtype)
                    if shard_checksum(rebuilt) != oinfo.checksum:
                        report.checksum_failures.append(
                            f"{sset.name}: checksum mismatch on replica of "
                            f"shard {owner} at {node_id}")
                    report.replicas_rebuilt += 1
        report.seconds = time.perf_counter() - t0
        return report

    # -- accounting -----------------------------------------------------------
    def memory_report(self) -> Dict[int, Dict[str, Dict[str, int]]]:
        return {n: node.pool.memory_report()
                for n, node in self.nodes.items() if node.alive}


# ---------------------------------------------------------------------------
# Distributed shuffle (paper §8 across nodes)
# ---------------------------------------------------------------------------
class ClusterShuffle:
    """Map-side: each node's ``ShuffleService`` writes one virtual shuffle
    buffer per *global* reducer into the node-local pool (concurrent-write
    job data). Reduce-side: reducer ``r`` (hosted on node ``r % N``) pulls
    partition ``r`` from every map node through the transfer path, after
    which the map output's lifetime is ended and its pages dropped."""

    def __init__(self, cluster: Cluster, name: str, num_reducers: int,
                 dtype: np.dtype, page_size: Optional[int] = None):
        self.cluster = cluster
        self.name = name
        self.num_reducers = num_reducers
        self.dtype = np.dtype(dtype)
        self.page_size = page_size or cluster.page_size
        self._services: Dict[int, ShuffleService] = {}
        self._pulled: Dict[int, str] = {}  # reducer -> reduce-set name

    def reducer_node(self, reducer: int) -> int:
        return reducer % self.cluster.num_nodes

    def _service(self, node_id: int) -> ShuffleService:
        if node_id not in self._services:
            self._services[node_id] = ShuffleService(
                self.cluster.node(node_id).pool,
                f"{self.name}/map{node_id}", self.num_reducers, self.dtype,
                page_size=self.page_size,
                attrs_factory=job_data_attrs)
        return self._services[node_id]

    def partition_of_keys(self, keys: np.ndarray) -> np.ndarray:
        # deliberately NOT the storage-placement hash (PartitionScheme's
        # golden-ratio multiplier): reusing it
        # would silently co-locate every record with its reducer and the
        # shuffle would never exercise the transfer path. Locality-aware
        # reducer placement is an explicit optimization (see ROADMAP), not a
        # hash collision.
        h = keys.astype(np.uint64) * np.uint64(0xC2B2AE3D27D4EB4F)
        h ^= h >> np.uint64(29)
        return (h % np.uint64(self.num_reducers)).astype(np.int64)

    def map_batch(self, node_id: int, records: np.ndarray,
                  key_fn: Callable[[np.ndarray], np.ndarray]) -> None:
        """Partition ``records`` on node ``node_id`` into its local virtual
        shuffle buffers, one contiguous slice per reducer (dispatch plan)."""
        if len(records) == 0:
            return
        parts = self.partition_of_keys(key_fn(records))
        order, counts, offsets = dispatch_plan(parts, self.num_reducers)
        routed = records[order]
        svc = self._service(node_id)
        for r in range(self.num_reducers):
            chunk = routed[offsets[r]:offsets[r + 1]]
            if len(chunk):
                svc.get_buffer(node_id, r).add_batch(chunk)

    def map_sharded(self, sset: ShardedSet,
                    key_fn: Callable[[np.ndarray], np.ndarray],
                    batch: int = 65536) -> None:
        """Run the map side over every shard of a sharded set, reading
        through each owner's pool (sequential read service)."""
        for n in sorted(sset.shards):
            shard = self.cluster.read_shard(sset, n)
            for i in range(0, len(shard), batch):
                self.map_batch(n, shard[i:i + batch], key_fn)

    def finish_maps(self) -> None:
        for svc in self._services.values():
            svc.finish_writes()

    def pull(self, reducer: int) -> np.ndarray:
        """Reduce-side fetch: gather partition ``reducer`` from every map
        node into the reducer node's pool, then release the map-side pages
        (lifetime ended — paper §6's cheapest victims)."""
        dst = self.reducer_node(reducer)
        reduce_set = f"{self.name}/reduce{reducer}"
        dst_pool = self.cluster.node(dst).pool
        ls = dst_pool.create_set(reduce_set, self.page_size, job_data_attrs())
        writer = SequentialWriter(dst_pool, ls, self.dtype)
        for node_id, svc in sorted(self._services.items()):
            part = svc.read_partition(reducer)
            if len(part):
                writer.append_batch(part)
                if node_id == dst:
                    self.cluster.local_bytes += part.nbytes
                else:
                    self.cluster.net_bytes += part.nbytes
            svc.release_partition(reducer)
        writer.close()
        self._pulled[reducer] = reduce_set
        return self.cluster.node(dst).read_records(reduce_set, self.dtype)

    def release_reducer(self, reducer: int) -> None:
        """Drop a pulled reduce partition once the reducer has consumed it."""
        name = self._pulled.pop(reducer, None)
        if name is None:
            return
        pool = self.cluster.node(self.reducer_node(reducer)).pool
        if name in pool.paging.sets:
            ls = pool.get_set(name)
            ls.end_lifetime(pool.clock)
            pool.drop_set(ls)


# ---------------------------------------------------------------------------
# End-to-end hash aggregation (paper §9's Spark comparison)
# ---------------------------------------------------------------------------
def cluster_hash_aggregate(cluster: Cluster, sset: ShardedSet,
                           key_field: str, val_field: str,
                           num_reducers: Optional[int] = None,
                           num_root_partitions: int = 4,
                           hash_page_size: int = 1 << 16,
                           ) -> Tuple[np.ndarray, np.ndarray]:
    """SELECT key, SUM(val) GROUP BY key over a sharded set: map-side shuffle
    by key hash, per-reducer HashService aggregation in the local pool,
    disjoint merge. Reducer outputs are disjoint by construction (keys are
    routed by hash), so the merge is a concatenate + sort."""
    num_reducers = num_reducers or cluster.num_nodes
    pair = HashService.PAIR_DTYPE
    sh = ClusterShuffle(cluster, f"{sset.name}.agg", num_reducers, pair)

    def to_pairs(records: np.ndarray) -> np.ndarray:
        out = np.empty(len(records), pair)
        out["key"] = records[key_field]
        out["val"] = records[val_field]
        return out

    for n in sorted(sset.shards):
        shard = cluster.read_shard(sset, n)
        sh.map_batch(n, to_pairs(shard), key_fn=lambda p: p["key"])
    sh.finish_maps()

    keys_out: List[np.ndarray] = []
    vals_out: List[np.ndarray] = []
    for r in range(num_reducers):
        node = cluster.node(sh.reducer_node(r))
        pulled = sh.pull(r)
        hs = HashService(node.pool, f"{sset.name}.agg/hash{r}",
                         num_root_partitions=num_root_partitions,
                         page_size=hash_page_size)
        if len(pulled):
            hs.insert(pulled["key"], pulled["val"])
        k, v = hs.finalize()
        hs.close()
        node.pool.drop_set(hs.ls)
        sh.release_reducer(r)
        keys_out.append(k)
        vals_out.append(v)
    keys = np.concatenate(keys_out)
    vals = np.concatenate(vals_out)
    order = np.argsort(keys)
    return keys[order], vals[order]
