"""Multi-node cluster runtime over unified buffer pools — paper §2, §7–§9.

This is the layer that turns the single-node mechanisms (TLSF arena, unified
buffer pool, data-aware paging, services) into the system the paper evaluates.
Since PR 2 it is split into three layers:

* **Mechanics (this module)** — ``StorageNode`` (one storage service: a
  ``BufferPool`` + spill store), ``Cluster`` (N nodes + the manager-side
  catalog/``StatisticsDB``), ``ShardedSet`` (hash-partitioned locality sets
  with chain replicas + CRC32 checksums), ``ClusterShuffle`` (map-side
  job-data pages, reducer pull, lifetime-ended release), replica-based
  ``recover_node``, and elastic ``remesh_degrade``.
* **Policy (``runtime/scheduler.py``)** — every placement decision is
  delegated to a ``ClusterScheduler``: reducer ``r`` lands on the node already
  holding the most map-output bytes for partition ``r``; reads of a dead
  owner's shard are routed to a CRC-verified surviving replica; a
  co-partitioned input elides the shuffle entirely (``stats.best_replica``);
  stragglers flagged by ``watchdog.StepTimer`` are re-executed from replica
  holders.
* **Wire (``runtime/transfer.py``)** — all inter-pool movement goes through
  ``copy_set`` and the threaded ``TransferEngine``; ``Cluster.transfer_records``
  is one client of it, and reducer pulls are engine jobs that overlap map
  finalization and each other.

Since PR 5 the pressure signal is *enforced* as admission control: map
writers, pull chunks, and remesh streams pace themselves against the
destination MemoryManager's staging grant (``try_reserve``), the transfer
engine caps in-flight bytes per destination, and reducer placement re-routes
partitions whose planned node refuses admission past the deadline
(``place_reducers_admitted``; diversions recorded on
``ClusterShuffle.diversions``). ``Cluster(admission=False)`` restores the
always-grant behavior.

On unrecoverable node loss (no replacement machine), ``Cluster.remesh_degrade``
falls through to ``elastic.plan_remesh``: the cluster shrinks to the surviving
membership and every sharded set is re-partitioned over it from the freshest
surviving copies, instead of raising.

Everything moves through buffer pools: a "network transfer" is a paged read
from the source pool streamed into a sequential write on the destination pool,
with byte accounting standing in for the wire.
"""
from __future__ import annotations

import os
import shutil
import threading
import time
import zlib
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from ..core.attributes import AttributeSet, StorageScheme
from ..core.buffer_pool import BufferPool, SpillStore
from ..core.columnar import (ColumnarWriter, ColumnLayout, _field_layout,
                             columns_crc32, columns_to_records,
                             fused_partition_crc, iter_column_blocks,
                             read_all_columnar, read_block,
                             records_to_columns, route_partition_ids,
                             segment_sum)
from ..core.locality_set import LocalitySet
from ..core.memory_manager import MemoryManager, derive_staging_cap
from ..core.pagelog import PageLog
from ..core.sanitizer import tracked_lock
from ..core.replication import (DistributedSet, PartitionScheme,
                                ReplicaRegistration,
                                combine_content_checksums,
                                record_content_checksum,
                                recover_target_shard, replica_nodes,
                                shard_checksum)
from ..core.services import (_HEADER, ColumnarShuffleService, HashService,
                             PageIterator, SequentialWriter, ShuffleService,
                             columnar_job_data_attrs, is_columnar,
                             job_data_attrs, read_all, user_data_attrs)
from ..core.statistics import ReplicaInfo, StatisticsDB
from .elastic import plan_remesh, remesh_partition_plan, surviving_node_ids
from .scheduler import ClusterScheduler
from .transfer import TransferEngine, copy_set
from .watchdog import StepTimer


def _host_dispatch_plan(partition_ids: np.ndarray, num_partitions: int):
    """Host-side analogue of ``kernels/shuffle_dispatch``'s slot assignment;
    the device kernel version is preferred when importable."""
    order = np.argsort(partition_ids, kind="stable")
    counts = np.bincount(partition_ids, minlength=num_partitions)
    offsets = np.empty(len(counts) + 1, np.int64)
    offsets[0] = 0
    np.cumsum(counts, out=offsets[1:])
    return order, counts, offsets


_dispatch_plan_impl = None
_dispatch_impl_name = "unresolved"


def _resolve_dispatch_plan():
    """Resolve the dispatch-plan implementation exactly once. A failed import
    is not cached by Python, so retrying per batch would re-run the whole
    failing jax import on every call — the PR-7 bugfix also records *which*
    implementation won, so benchmarks can report it instead of the resolution
    being silently swallowed."""
    global _dispatch_plan_impl, _dispatch_impl_name
    if _dispatch_plan_impl is None:
        try:
            from ..kernels.shuffle_dispatch.ops import host_dispatch_plan
            _dispatch_plan_impl = host_dispatch_plan
            _dispatch_impl_name = "kernels.shuffle_dispatch"
        except ImportError:  # kernels need jax; the cluster runtime must not
            _dispatch_plan_impl = _host_dispatch_plan
            _dispatch_impl_name = "host-fallback"
    return _dispatch_plan_impl


def dispatch_impl() -> str:
    """Which dispatch-plan implementation is active:
    ``"kernels.shuffle_dispatch"`` (the kernel package imported cleanly) or
    ``"host-fallback"`` (this module's numpy copy). Resolves on first call."""
    _resolve_dispatch_plan()
    return _dispatch_impl_name


def dispatch_plan(partition_ids: np.ndarray, num_partitions: int):
    """Group a batch by destination partition in one stable pass. Mirrors the
    MoE shuffle-dispatch slot assignment (``kernels/shuffle_dispatch``), whose
    host-side helper is used when available; records land contiguously per
    partition: ``order[offsets[p]:offsets[p+1]]`` are partition ``p``'s rows."""
    return _resolve_dispatch_plan()(partition_ids, num_partitions)


_partition_crc_impl = None
_partition_crc_name = "unresolved"


def _resolve_partition_crc():
    """Same once-only resolution for the fused hash-partition + CRC pass:
    prefer the kernel package's export, fall back to the numpy implementation
    in ``core.columnar`` (they are the same host pass — the fallback exists so
    the cluster runtime never needs the kernels package's jax import)."""
    global _partition_crc_impl, _partition_crc_name
    if _partition_crc_impl is None:
        try:
            from ..kernels.shuffle_dispatch.ops import host_partition_crc
            _partition_crc_impl = host_partition_crc
            _partition_crc_name = "kernels.shuffle_dispatch"
        except ImportError:
            _partition_crc_impl = fused_partition_crc
            _partition_crc_name = "core.columnar"
    return _partition_crc_impl


def partition_crc_impl() -> str:
    """Which fused partition+CRC implementation is active (for benchmarks)."""
    _resolve_partition_crc()
    return _partition_crc_name


class DeadNodeError(RuntimeError):
    """Raised when touching a node that has been killed and not recovered,
    and no surviving replica can stand in for it."""


def reducer_hash(keys: np.ndarray, num_reducers: int) -> np.ndarray:
    """The shuffle's reducer-routing hash — ``int64 keys -> reducer ids``.

    Deliberately NOT the storage-placement hash (PartitionScheme's
    golden-ratio multiplier): reusing it would silently co-locate every
    record with its reducer and the shuffle would never exercise the
    transfer path. Shuffle-free execution is an explicit scheduler decision
    (plan_aggregation / plan_join), not a hash collision.

    Module-level (rather than a ``ClusterShuffle`` method) because every
    map site must route bit-identically — including map tasks running
    inside remote node processes (``runtime/node_proc``), which never see
    the driver's shuffle object."""
    h = keys.astype(np.uint64) * np.uint64(0xC2B2AE3D27D4EB4F)
    h ^= h >> np.uint64(29)
    return (h % np.uint64(num_reducers)).astype(np.int64)


def _iter_record_chunks(pool, ls, dtype: np.dtype) -> Iterator[np.ndarray]:
    """Stream a locality set as record-array chunks regardless of its storage
    scheme: row pages decode in place (``PageIterator``), columnar pages
    materialize each block's columns into rows. The scheme-neutral read path
    the remesh stream and CRC verifiers share."""
    if is_columnar(ls):
        for cols, n in iter_column_blocks(pool, ls, dtype):
            yield columns_to_records(cols, dtype, n)
    else:
        yield from PageIterator(pool, ls, dtype, sorted(ls.pages))


def sharded_set_is_columnar(sset: "ShardedSet") -> bool:
    """Whether a sharded set's shards are columnar (the storage-scheme
    dimension of its remembered attrs factory; no factory means row)."""
    if sset.attrs_factory is None:
        return False
    return sset.attrs_factory().storage is StorageScheme.COLUMNAR


class StorageNode:
    """One Pangea storage service: a unified buffer pool plus its memory
    manager (paper §2 — every node runs one storage process owning all its
    data). ``node.memory`` is the runtime's window into the node's eviction
    policy, spill store, and pressure accounting. With a ``pagelog_dir`` the
    node also owns a durable page log — the tier below scratch spill that
    write-through sets page against and that survives the node's death."""

    def __init__(self, node_id: int, capacity: int,
                 spill_dir: Optional[str] = None,
                 policy: str = "data-aware",
                 pressure_watermark: float = 0.85,
                 pagelog_dir: Optional[str] = None,
                 epoch_fn=None,
                 pagelog_fsync: str = "none",
                 pagelog_compact_threshold: Optional[float] = None):
        self.node_id = node_id
        self.capacity = capacity
        self.pressure_watermark = pressure_watermark
        self.spill_dir = spill_dir
        self.policy = policy
        self.pagelog_dir = pagelog_dir
        self.epoch_fn = epoch_fn
        self.pagelog_fsync = pagelog_fsync
        self.pagelog_compact_threshold = pagelog_compact_threshold
        self.pool = self._build_pool()
        self.alive = True

    def _build_pool(self) -> BufferPool:
        """Construct the pool, reopening the durable page log from disk when
        one is configured (construction replays its index — a revival with
        surviving log files IS the warm start)."""
        pagelog = (PageLog(self.pagelog_dir, epoch_fn=self.epoch_fn,
                           fsync_policy=self.pagelog_fsync,
                           compact_threshold=self.pagelog_compact_threshold)
                   if self.pagelog_dir else None)
        return BufferPool(self.capacity, SpillStore(self.spill_dir),
                          policy=self.policy,
                          pressure_watermark=self.pressure_watermark,
                          pagelog=pagelog)

    def revive(self) -> None:
        """Bring a killed node back with a fresh pool (and a reopened,
        replayed page log when durable storage is configured)."""
        self.pool = self._build_pool()
        self.alive = True

    @property
    def memory(self) -> Optional[MemoryManager]:
        """The node's MemoryManager (None once the node is dead)."""
        return self.pool.memory if self.pool is not None else None

    def write_records(self, set_name: str, records: np.ndarray,
                      dtype: np.dtype, page_size: int,
                      attrs: Optional[AttributeSet] = None) -> LocalitySet:
        ls = self.pool.create_set(set_name, page_size, attrs)
        if attrs is not None and attrs.storage is StorageScheme.COLUMNAR:
            w = ColumnarWriter(self.pool, ls, dtype)
        else:
            w = SequentialWriter(self.pool, ls, dtype)
        if len(records):
            w.append_batch(records)
        w.close()
        return ls

    def read_records(self, set_name: str, dtype: np.dtype) -> np.ndarray:
        ls = self.pool.get_set(set_name)
        if ls.attrs.storage is StorageScheme.COLUMNAR:
            return read_all_columnar(self.pool, ls, dtype)
        return read_all(self.pool, ls, dtype)


@dataclass
class ShardInfo:
    """Catalog entry for one primary shard of a sharded locality set.

    ``checksum`` is the order-exact CRC32 of the shard's record bytes
    (page-for-page copies must match it); ``content_checksum`` is the
    order-independent fingerprint (``record_content_checksum``) that also
    certifies shards re-assembled in a different record order — the
    co-partitioned rebuild path and the streaming remesh verify against it."""

    node_id: int
    set_name: str
    num_records: int
    checksum: int
    content_checksum: int = 0
    replicas: List[Tuple[int, str]] = field(default_factory=list)
    # topology/job event counter (StatisticsDB.event_seq) when this shard's
    # bytes were last (re)written — page-log replay is fenced against it, so
    # a shard dropped or rebuilt elsewhere while its node was dead cannot be
    # resurrected from the dead node's stale log entries
    epoch: int = 0


class ShardedSet:
    """A logical dataset hash-partitioned across the cluster's pools.

    ``shards[n]`` describes node ``n``'s primary shard; replicas live on the
    chain successors. Placement follows ``scheme`` over the set's placement
    domain ``node_ids`` (slot ``s`` of the scheme maps to ``node_ids[s]``) —
    the full membership at creation time, or the surviving membership after an
    elastic remesh. Any node can compute routing locally.
    """

    def __init__(self, name: str, dtype: np.dtype, scheme: PartitionScheme,
                 page_size: int, replication_factor: int,
                 node_ids: Optional[Sequence[int]] = None):
        self.name = name
        self.dtype = np.dtype(dtype)
        self.scheme = scheme
        self.page_size = page_size
        self.replication_factor = replication_factor
        self.node_ids: List[int] = (list(node_ids) if node_ids is not None
                                    else list(range(scheme.num_nodes)))
        # how to build each shard's AttributeSet; remembered so re-sharding
        # (remesh_degrade) re-creates shards under the same attributes
        self.attrs_factory: Optional[Callable[[], AttributeSet]] = None
        self.shards: Dict[int, ShardInfo] = {}

    @property
    def partition_key(self) -> str:
        """What this set is partitioned on (the scheme name registered in the
        statistics DB; co-partition detection compares it to a query's key)."""
        return self.scheme.name

    def node_of_records(self, records: np.ndarray) -> np.ndarray:
        """Actual node id (not scheme slot) each record routes to."""
        slots = self.scheme.node_of_records(records)
        return np.asarray(self.node_ids, dtype=np.int64)[slots]

    def primary_set_name(self, node_id: int) -> str:
        return f"{self.name}/shard{node_id}"

    def replica_set_name(self, owner: int, holder: int) -> str:
        return f"{self.name}/shard{owner}/replica@{holder}"


@dataclass
class ConflictGuard:
    """Paper §7's conflicting objects, cluster-level: when the same logical
    dataset is registered under two partitionings and node ``node`` holds a
    shard under BOTH, the records routed to ``node`` by both schemes exist
    nowhere else once that node dies — a factor-0 pair could then never
    rebuild either shard from the other. The guard is a copy of exactly
    those records, placed on the ring successor, consulted by the
    co-partitioned rebuild when the conflicted node's alternate shard is
    unreadable."""

    node: int           # the conflicted node both schemes route to
    holder: int         # where the guard copy lives
    set_name: str
    num_records: int
    checksum: int       # order-exact CRC32 of the guard records
    epoch: int = 0


@dataclass
class RecoveryReport:
    node_id: int
    shards_recovered: int = 0
    replicas_rebuilt: int = 0
    bytes_transferred: int = 0
    checksum_failures: List[str] = field(default_factory=list)
    # "<set>:<shard>" -> the recovery source the scheduler chose
    # ("replica@2", "rebuild<-other_set", "pagelog", ...)
    sources: Dict[str, str] = field(default_factory=dict)
    seconds: float = 0.0
    # durable-tier warm recovery (PR 6)
    warm_shards: int = 0        # primary shards restored from the local log
    warm_replicas: int = 0      # held replicas restored from the local log
    fenced_sets: List[str] = field(default_factory=list)  # stale log sets purged

    @property
    def ok(self) -> bool:
        return not self.checksum_failures


@dataclass
class RemeshReport:
    """What ``Cluster.remesh_degrade`` did: the elastic plan plus the
    re-sharding work (paper's recovery story when no replacement node
    exists — shrink instead of fail)."""

    dead_nodes: List[int]
    node_ids: List[int]                 # surviving placement domain
    plan: dict = field(default_factory=dict)
    resharded: List[str] = field(default_factory=list)
    lost: List[str] = field(default_factory=list)
    bytes_transferred: int = 0
    streamed: bool = False              # shard-to-shard streaming path used
    driver_peak_bytes: int = 0          # driver staging HWM during the remesh
    seconds: float = 0.0

    @property
    def ok(self) -> bool:
        return not self.lost


class Cluster:
    """N storage nodes + the manager node's catalog (paper §2 architecture).

    The manager here is in-process: ``catalog`` maps sharded-set names to
    their shard/replica/checksum metadata, ``stats`` is the paper's statistics
    database used by query planning (``best_replica``, shuffle byte maps),
    ``scheduler`` owns placement policy, and ``transfer`` is the lazy threaded
    engine every inter-pool byte rides through.

    ``backend`` selects the data plane: ``"inproc"`` (default) keeps every
    node an object in this process — fast to build, fully deterministic, the
    test fallback; ``"proc"`` re-platforms each node onto its own OS process
    with a socket control plane and a shared-memory page path
    (``runtime/node_proc.ProcCluster`` — same catalog/scheduler/shuffle
    surface, real wall-clock overlap).
    """

    def __new__(cls, *args, backend: str = "inproc", **kwargs):
        if cls is Cluster and backend == "proc":
            from .node_proc import ProcCluster
            return ProcCluster(*args, **kwargs)
        if backend not in ("inproc", "proc"):
            raise ValueError(f"unknown cluster backend {backend!r}")
        return super().__new__(cls)

    def __init__(self, num_nodes: int, node_capacity: int = 32 << 20,
                 page_size: int = 1 << 18, replication_factor: int = 1,
                 spill_dir: Optional[str] = None,
                 transfer_workers: int = 4, policy: str = "data-aware",
                 admission: bool = True,
                 admission_deadline_s: float = 0.05,
                 admission_timeout_s: float = 0.2,
                 pressure_watermark: float = 0.85,
                 pagelog_dir: Optional[str] = None,
                 pagelog_fsync: str = "none",
                 pagelog_compact_threshold: Optional[float] = None,
                 backend: str = "inproc"):
        if num_nodes < 2:
            raise ValueError("a cluster needs at least 2 nodes")
        self.num_nodes = num_nodes
        self.node_capacity = node_capacity
        self.page_size = page_size
        self.replication_factor = replication_factor
        self.policy = policy
        # admission knobs (PR 5): ``admission=False`` restores the PR-3
        # always-grant behavior (writers never throttle, placement never
        # re-routes) — the benchmark baseline. The deadline bounds how long
        # the scheduler waits for a refusing node before diverting a
        # reducer; the timeout bounds how long a paced writer waits for a
        # staging grant before it is forced through.
        self.admission = admission
        self.admission_deadline_s = admission_deadline_s
        self.admission_timeout_s = admission_timeout_s
        self.pressure_watermark = pressure_watermark
        self._spill_dir = spill_dir
        # durable tier (PR 6): per-node page-log directories under
        # ``pagelog_dir``. Configuring it makes sharded sets write-through
        # by default (their pages land in the log) and node recovery
        # warm-start from the revived node's replayed local index.
        self._pagelog_dir = pagelog_dir
        # durability-vs-throughput knob forwarded to every node's PageLog
        # (``core/pagelog.FSYNC_POLICIES``); "none" is the original behavior
        self._pagelog_fsync = pagelog_fsync
        # amplification threshold for background log compaction (None = off)
        self._pagelog_compact_threshold = pagelog_compact_threshold
        # warm the dispatch-plan kernel at boot so the first map batch is
        # not charged with resolving (and possibly importing jax for) it
        _resolve_dispatch_plan()
        # stats must exist before the nodes: every node's page log stamps
        # its records with the cluster's topology/job event counter
        self.stats = StatisticsDB()
        self.nodes: Dict[int, StorageNode] = {
            n: StorageNode(n, node_capacity, self._node_spill_dir(n),
                           policy=policy,
                           pressure_watermark=pressure_watermark,
                           pagelog_dir=self._node_pagelog_dir(n),
                           epoch_fn=self.stats.current_epoch,
                           pagelog_fsync=pagelog_fsync,
                           pagelog_compact_threshold=pagelog_compact_threshold)
            for n in range(num_nodes)
        }
        # the manager/driver process's own memory authority: pure accounting
        # (no arena) for bytes staged driver-side — remesh streaming chunks,
        # loader prefetch windows. Its high-water marks are what the
        # O(page)-driver-memory guarantees are asserted against.
        self.driver_memory = MemoryManager(node_capacity, policy=policy)
        self.catalog: Dict[str, ShardedSet] = {}
        # paper-§7 conflicting-object guards (satellite bugfix):
        # (base_name, other_name) -> {conflicted node -> ConflictGuard}
        self.conflict_guards: Dict[Tuple[str, str],
                                   Dict[int, ConflictGuard]] = {}
        # durable blobs: plain (non-sharded) pool sets that live in a node's
        # page log — checkpoint streams, mostly. name -> (node_id, epoch);
        # the revival fence treats registered blobs as valid log state.
        self.durable_blobs: Dict[str, Tuple[int, int]] = {}
        self.scheduler = ClusterScheduler(self)
        self._transfer_workers = transfer_workers
        self._transfer: Optional[TransferEngine] = None
        self._acct_lock = tracked_lock("cluster.acct")
        self.net_bytes = 0          # bytes that crossed node boundaries
        self.local_bytes = 0        # bytes moved pool->pool on one node

    def _node_spill_dir(self, node_id: int) -> Optional[str]:
        if self._spill_dir is None:
            return None
        return f"{self._spill_dir}/node{node_id}"

    def _node_pagelog_dir(self, node_id: int) -> Optional[str]:
        if self._pagelog_dir is None:
            return None
        return f"{self._pagelog_dir}/node{node_id}"

    # -- membership -----------------------------------------------------------
    def node(self, node_id: int) -> StorageNode:
        node = self.nodes[node_id]
        if not node.alive:
            raise DeadNodeError(f"node {node_id} is down")
        return node

    def alive_node_ids(self) -> List[int]:
        return [n for n, node in self.nodes.items() if node.alive]

    def dead_node_ids(self) -> List[int]:
        return [n for n, node in self.nodes.items() if not node.alive]

    def kill_node(self, node_id: int) -> None:
        """Simulate a machine loss: the node's pool, spill store, and every
        locality set on it are gone. The memory manager deletes every spill
        image it wrote — a dead machine's local disk is gone with it, and
        leaving the files behind leaked them under a real ``spill_dir``."""
        node = self.nodes[node_id]
        node.alive = False
        if node.pool is not None:
            node.pool.memory.close()
        node.pool = None  # drop the arena; nothing on this node survives
        # topology event: recorded pressure snapshots are now stale
        self.stats.note_event()

    def revive_node(self, node_id: int,
                    warm: Optional[bool] = None) -> List[str]:
        """Bring a dead node's identity back up with a fresh pool. With the
        durable tier configured a *warm* revival (the default) reopens the
        node's local page log — replaying its index — and then fences it:
        replayed sets the catalog no longer names on this node, or whose
        cataloged epoch is newer than the log's (dropped or re-sharded while
        the node was dead), are purged rather than resurrected (satellite
        bugfix — the fence rides ``StatisticsDB.note_event``'s counter,
        stamped into every log record at write time). ``warm=False`` models
        losing the machine's disk along with it: the log directory is wiped
        before the pool reopens, so recovery must pull every byte from
        replicas — the cold baseline the benchmark measures against.
        Returns the fenced (purged) set names."""
        node = self.nodes[node_id]
        if node.alive:
            raise ValueError(f"node {node_id} is alive; nothing to revive")
        if warm is None:
            warm = self._pagelog_dir is not None
        log_dir = self._node_pagelog_dir(node_id)
        if not warm and log_dir is not None and os.path.isdir(log_dir):
            shutil.rmtree(log_dir, ignore_errors=True)
        node.revive()
        self.stats.note_event()  # topology event: node re-joined
        return self._fence_pagelog(node_id)

    def _fence_pagelog(self, node_id: int) -> List[str]:
        """Purge replayed page-log state that no longer describes the
        catalog. Valid log sets are the node's cataloged primaries, the
        replicas it holds for other owners, its conflict-guard copies, and
        registered durable blobs — each at the epoch the catalog stamped
        when the bytes were (re)written. Anything else in the replayed
        index is stale history from before the node died."""
        pool = self.nodes[node_id].pool
        log = pool.memory.pagelog if pool is not None else None
        if log is None:
            return []
        valid: Dict[str, int] = {}
        for sset in self.catalog.values():
            info = sset.shards.get(node_id)
            if info is not None:
                valid[info.set_name] = info.epoch
            for oinfo in sset.shards.values():
                for holder, rep_name in oinfo.replicas:
                    if holder == node_id:
                        valid[rep_name] = oinfo.epoch
        for guards in self.conflict_guards.values():
            for g in guards.values():
                if g.holder == node_id:
                    valid[g.set_name] = g.epoch
        for name, (nid, epoch) in self.durable_blobs.items():
            if nid == node_id:
                valid[name] = epoch
        fenced = [name for name in log.set_names()
                  if name not in valid or log.set_epoch(name) < valid[name]]
        for name in fenced:
            log.drop_set(name)
        return sorted(fenced)

    # -- durable blobs (checkpoint streams and other non-sharded log sets) ----
    def register_durable_blob(self, name: str, node_id: int) -> None:
        self.durable_blobs[name] = (node_id, self.stats.event_seq)

    def unregister_durable_blob(self, name: str) -> None:
        self.durable_blobs.pop(name, None)

    # -- byte accounting (thread-safe: pulls run on engine workers) -----------
    def add_net_bytes(self, n: int) -> None:
        with self._acct_lock:
            self.net_bytes += n

    def add_local_bytes(self, n: int) -> None:
        with self._acct_lock:
            self.local_bytes += n

    # -- node-to-node transfer path -------------------------------------------
    @property
    def transfer(self) -> TransferEngine:
        """The cluster's transfer engine, spawned on first use (its workers
        exit when idle, so short-lived clusters don't accumulate threads).
        With admission on, the engine caps in-flight bytes per destination
        node at the watermark-derived staging budget, so overlapped pulls
        can't stampede one reducer node."""
        if self._transfer is None:
            cap = (derive_staging_cap(self.node_capacity,
                                      self.pressure_watermark)
                   if self.admission else None)
            self._transfer = TransferEngine(self._transfer_workers,
                                            name="transfer",
                                            dest_inflight_cap=cap)
        return self._transfer

    def _stream_records(self, src_id: int, src_set: str, dst_id: int,
                        dst_set: str, dtype: np.dtype,
                        page_size: Optional[int] = None,
                        attrs: Optional[AttributeSet] = None) -> int:
        src = self.node(src_id)
        dst = self.node(dst_id)
        moved = copy_set(src.pool, src_set, dst.pool, dst_set, dtype,
                         page_size or self.page_size, attrs)
        if src_id == dst_id:
            self.add_local_bytes(moved)
        else:
            self.add_net_bytes(moved)
        return moved

    def transfer_records(self, src_id: int, src_set: str, dst_id: int,
                         dst_set: str, dtype: np.dtype,
                         page_size: Optional[int] = None,
                         attrs: Optional[AttributeSet] = None) -> int:
        """Stream one locality set between pools (the cluster's "network":
        ``transfer.copy_set`` under the engine). Returns bytes moved;
        cross-node bytes are tallied as network traffic, same-node as
        pool-local copies."""
        if threading.current_thread().name.startswith("transfer"):
            # already on an engine worker: run inline rather than submitting a
            # job we would then block on (a full pool of waiters would wedge)
            return self._stream_records(src_id, src_set, dst_id, dst_set,
                                        dtype, page_size, attrs)
        return self.transfer_records_async(src_id, src_set, dst_id, dst_set,
                                           dtype, page_size, attrs).result()

    def transfer_records_async(self, src_id: int, src_set: str, dst_id: int,
                               dst_set: str, dtype: np.dtype,
                               page_size: Optional[int] = None,
                               attrs: Optional[AttributeSet] = None):
        return self.transfer.submit(
            self._stream_records, src_id, src_set, dst_id, dst_set, dtype,
            page_size, attrs, label=f"{src_set}->{dst_set}")

    # -- raw byte blobs (serving KV slabs and other unsharded payloads) -------
    def store_bytes(self, node_id: int, name: str, data: bytes) -> int:
        """Land a raw byte blob as a uint8 locality set on one node
        (drop-before-rewrite: a same-name re-store replaces the old copy).
        The serving tier ships KV page slabs through this — on the proc
        backend the bytes live in the node's OS process, so replica copies
        genuinely survive a SIGKILL of the primary and genuinely die with
        their own node. Returns the bytes stored."""
        node = self.node(node_id)
        if name in node.pool.paging.sets:
            node.pool.drop_set(node.pool.get_set(name))
        recs = np.frombuffer(bytes(data), dtype=np.uint8)
        node.write_records(name, recs, np.dtype(np.uint8), self.page_size)
        return len(recs)

    def load_bytes(self, node_id: int, name: str) -> bytes:
        """Read a blob back (raises ``DeadNodeError`` for a dead holder,
        ``KeyError`` when the node never got the blob)."""
        node = self.node(node_id)
        if name not in node.pool.paging.sets:
            raise KeyError(name)
        return node.read_records(name, np.dtype(np.uint8)).tobytes()

    def drop_bytes(self, node_id: int, name: str) -> None:
        node = self.nodes[node_id]
        if (node.alive and node.pool is not None
                and name in node.pool.paging.sets):
            node.pool.drop_set(node.pool.get_set(name))

    def has_bytes(self, node_id: int, name: str) -> bool:
        node = self.nodes[node_id]
        return bool(node.alive and node.pool is not None
                    and name in node.pool.paging.sets)

    # -- sharded locality sets ------------------------------------------------
    def create_sharded_set(self, name: str, records: np.ndarray,
                           key_fn: Callable[[np.ndarray], np.ndarray],
                           partitions_per_node: int = 4,
                           page_size: Optional[int] = None,
                           replication_factor: Optional[int] = None,
                           attrs_factory: Optional[Callable[[], AttributeSet]] = None,
                           partition_key: Optional[str] = None,
                           node_ids: Optional[Sequence[int]] = None,
                           ) -> ShardedSet:
        """Hash-partition ``records`` across the placement domain (every alive
        node by default) and chain-replicate each shard (paper §7 applied at
        page level: the replica IS another locality set, just on a different
        node). ``partition_key`` names what the set is partitioned on (e.g.
        the key field) so ``stats.best_replica`` can match co-partitioned
        queries and skip their shuffles; it defaults to the set name, which
        never matches and preserves the always-shuffle behavior."""
        if name in self.catalog:
            raise ValueError(f"sharded set {name!r} already exists")
        factor = (self.replication_factor if replication_factor is None
                  else replication_factor)
        page_size = page_size or self.page_size
        domain = list(node_ids) if node_ids is not None else self.alive_node_ids()
        if not domain:
            raise DeadNodeError("no alive nodes to place a sharded set on")
        if factor >= len(domain):
            raise ValueError(f"replication factor {factor} needs more than "
                             f"{len(domain)} nodes")
        scheme = PartitionScheme(partition_key or name, key_fn,
                                 partitions_per_node * len(domain),
                                 len(domain))
        sset = ShardedSet(name, records.dtype, scheme, page_size, factor,
                          node_ids=domain)
        if attrs_factory is None and self._pagelog_dir is not None:
            # durable tier configured: sharded user data is write-through by
            # default so its pages land in each node's page log and a killed
            # node can warm-start from its local index
            attrs_factory = user_data_attrs
        sset.attrs_factory = attrs_factory
        self._place_records(sset, records)
        self.catalog[name] = sset
        self.stats.register_replica(name, self._replica_info(sset))
        self.stats.note_event()  # job event: staging moved real bytes
        return sset

    def register_replica_set(self, logical_name: str,
                             sset: ShardedSet) -> None:
        """Register a sharded set as a heterogeneously partitioned replica of
        a logical dataset (paper §7 through the cluster pools): queries over
        ``logical_name`` may then be routed to whichever replica's
        partitioning matches (``scheduler.plan_aggregation``), e.g. a
        by-key replica making an aggregation shuffle-free.

        Carried bugfix (PR 3): registration is now *symmetric* — the base
        set is equally a heterogeneous replica of ``sset``, so recovery can
        rebuild in either direction — and paper §7's *conflicting objects*
        are guarded: when the same node holds a shard under BOTH
        partitionings and neither set carries chain replicas, the records
        both schemes route to that node would die with it, leaving the
        factor-0 pair unable to rebuild each other. A guard copy of exactly
        those records is written to the ring successor at registration."""
        self.stats.register_replica(logical_name, self._replica_info(sset))
        base = self.catalog.get(logical_name)
        if base is None or base is sset or base.name == sset.name:
            return
        self.stats.register_replica(sset.name, self._replica_info(base))
        self._guard_conflicting_objects(base, sset)

    def _guard_conflicting_objects(self, base: ShardedSet,
                                   other: ShardedSet) -> None:
        """Write the paper-§7 conflicting-object guards for a factor-0 pair:
        for every node holding a shard of ``other`` that ``base`` also
        routes records to, copy exactly the records both partitionings place
        there to the node's ring successor. Chain replicas already cover the
        conflict when either set carries them, so guards are only needed
        when both factors are zero."""
        if base.replication_factor > 0 or other.replication_factor > 0:
            return
        pair = (base.name, other.name)
        guards = self.conflict_guards.setdefault(pair, {})
        domain = other.node_ids
        if len(domain) < 2:
            return
        for slot, n in enumerate(domain):
            if n in guards or n not in other.shards or n not in base.shards:
                continue
            recs = self.read_shard(other, n)
            if not len(recs):
                continue
            conflicts = recs[base.node_of_records(recs) == n]
            if not len(conflicts):
                continue
            hslot = replica_nodes(slot, len(domain), 1)[0]
            holder = domain[hslot]
            gname = f"{other.name}/conflict{n}@{holder}"
            attrs = other.attrs_factory() if other.attrs_factory else None
            self.node(holder).write_records(gname, conflicts, other.dtype,
                                            other.page_size, attrs)
            self.add_net_bytes(conflicts.nbytes)
            guards[n] = ConflictGuard(
                node=n, holder=holder, set_name=gname,
                num_records=len(conflicts),
                checksum=shard_checksum(conflicts),
                epoch=self.stats.event_seq)

    def conflict_guard(self, name_a: str, name_b: str,
                       node: int) -> Optional[ConflictGuard]:
        """The live guard for the (a, b) replica pair's conflict on
        ``node``, in either registration order, or None when no guard copy
        survives on an alive holder."""
        for pair in ((name_a, name_b), (name_b, name_a)):
            g = self.conflict_guards.get(pair, {}).get(node)
            if g is not None and self.scheduler._holds(g.holder, g.set_name):
                return g
        return None

    def _replica_info(self, sset: ShardedSet) -> ReplicaInfo:
        return ReplicaInfo(
            set_name=sset.name, partition_key=sset.partition_key,
            num_partitions=sset.scheme.num_partitions,
            num_nodes=len(sset.node_ids), page_size=sset.page_size,
            extra={"replication_factor": sset.replication_factor,
                   "node_ids": list(sset.node_ids)})

    def _place_records(self, sset: ShardedSet, records: np.ndarray) -> None:
        """Write primaries + chain replicas for ``records`` over the set's
        placement domain (shared by creation and remesh re-sharding; shard
        attributes come from the set's remembered ``attrs_factory``)."""
        domain = sset.node_ids
        slots = sset.scheme.node_of_records(records)
        order, counts, offsets = dispatch_plan(slots, len(domain))
        routed = records[order]
        for slot, nid in enumerate(domain):
            shard = routed[offsets[slot]:offsets[slot + 1]]
            attrs = sset.attrs_factory() if sset.attrs_factory else None
            self.node(nid).write_records(sset.primary_set_name(nid), shard,
                                         sset.dtype, sset.page_size, attrs)
            info = ShardInfo(node_id=nid, set_name=sset.primary_set_name(nid),
                             num_records=len(shard),
                             checksum=shard_checksum(shard),
                             content_checksum=record_content_checksum(shard),
                             epoch=self.stats.event_seq)
            for hslot in replica_nodes(slot, len(domain),
                                       sset.replication_factor):
                holder = domain[hslot]
                rep_name = sset.replica_set_name(nid, holder)
                # replicas inherit the shard attributes: a write-through
                # replica lands in its holder's page log too, so a revived
                # holder warm-starts the replicas it held
                rep_attrs = sset.attrs_factory() if sset.attrs_factory else None
                self.transfer_records(nid, info.set_name, holder, rep_name,
                                      sset.dtype, sset.page_size,
                                      attrs=rep_attrs)
                info.replicas.append((holder, rep_name))
            sset.shards[nid] = info

    def read_shard_from(self, sset: ShardedSet,
                        node_id: int) -> Tuple[int, np.ndarray]:
        """Read one shard, preferring the primary but falling back to any
        surviving replica whose CRC32 matches the catalog (so a dead node with
        intact replicas never fails a read). Returns ``(holder, records)``."""
        info = sset.shards[node_id]
        mismatches: List[str] = []
        for holder, set_name in self.scheduler.read_sources(sset, node_id):
            recs = self.nodes[holder].read_records(set_name, sset.dtype)
            if holder == node_id or shard_checksum(recs) == info.checksum:
                return holder, recs
            mismatches.append(f"{set_name}@{holder}")
        detail = (f" (checksum mismatch on {', '.join(mismatches)})"
                  if mismatches else "")
        raise DeadNodeError(
            f"node {node_id} is down and no verified replica of "
            f"{sset.name!r} shard {node_id} survives{detail}")

    def read_shard(self, sset: ShardedSet, node_id: int) -> np.ndarray:
        return self.read_shard_from(sset, node_id)[1]

    def read_sharded(self, sset: ShardedSet) -> np.ndarray:
        """Gather every shard, reading dead owners' shards from surviving
        replicas (raises DeadNodeError only when a shard has no verified copy
        left — exactly what recovery and remesh exist to prevent)."""
        parts = [self.read_shard(sset, n) for n in sorted(sset.shards)]
        return np.concatenate(parts) if parts else np.empty(0, sset.dtype)

    def _drop_physical(self, sset: ShardedSet) -> None:
        for n, info in sset.shards.items():
            node = self.nodes[n]
            if node.alive and info.set_name in node.pool.paging.sets:
                node.pool.drop_set(node.pool.get_set(info.set_name))
            for holder, rep_name in info.replicas:
                hnode = self.nodes[holder]
                if hnode.alive and rep_name in hnode.pool.paging.sets:
                    hnode.pool.drop_set(hnode.pool.get_set(rep_name))

    def drop_sharded_set(self, sset: ShardedSet) -> None:
        self._drop_physical(sset)
        self.catalog.pop(sset.name, None)
        # guards exist to rebuild this set (or its pair partner) — dropping
        # the set retires every pair it participates in
        for pair in [p for p in self.conflict_guards if sset.name in p]:
            for g in self.conflict_guards[pair].values():
                hnode = self.nodes[g.holder]
                if (hnode.alive and hnode.pool is not None
                        and g.set_name in hnode.pool.paging.sets):
                    hnode.pool.drop_set(hnode.pool.get_set(g.set_name))
            del self.conflict_guards[pair]
        # a dropped set's shards are gone everywhere: any log entries left
        # on dead nodes are fenced at revival because the catalog no longer
        # names them
        self.stats.note_event()

    # -- replica-based recovery (paper §7) ------------------------------------
    def _rebuild_shard_from_replica(self, sset: ShardedSet, shard_id: int,
                                    alt_name: str) -> Tuple[np.ndarray, int]:
        """Re-materialize a shard by re-running ``sset``'s partitioner over a
        heterogeneously partitioned replica of the same logical data
        (``core/replication.recover_target_shard`` — paper §7's recovery from
        a differently partitioned replica). Returns ``(records, net_bytes)``;
        record order differs from the original, so callers verify the
        order-independent ``content_checksum``."""
        alt = self.catalog[alt_name]
        slot = sset.node_ids.index(shard_id)
        src_shards: Dict = {}
        reservations = []
        moved = 0
        try:
            for i, n in enumerate(sorted(alt.shards)):
                try:
                    holder, recs = self.read_shard_from(alt, n)
                except DeadNodeError:
                    # paper-§7 conflicting objects (carried bugfix): the
                    # alt's shard on the failed node itself may have no
                    # surviving copy — both partitionings routed those
                    # records there. The guard copy written at registration
                    # holds exactly the records this rebuild needs from it
                    # (the ones ``sset`` routes to ``shard_id``); any other
                    # unreadable alt shard is a genuine loss.
                    guard = self.conflict_guard(sset.name, alt_name, n)
                    if guard is None or n != shard_id:
                        raise
                    holder = guard.holder
                    recs = self.node(holder).read_records(guard.set_name,
                                                          sset.dtype)
                    if shard_checksum(recs) != guard.checksum:
                        raise
                # string keys: no alt shard may be skipped as "the failed
                # node" — a dead owner's shard reaches us through a replica
                src_shards[f"alt{i}"] = recs
                # the rebuild gathers the whole alt set driver-side: charge
                # it, so recovery shows up in the same pressure accounting
                # as every other stager
                reservations.append(self.driver_memory.reserve(recs.nbytes))
                if holder != shard_id:
                    moved += recs.nbytes
            reg = ReplicaRegistration(
                source=DistributedSet(f"{alt_name}.rebuild-src", None,
                                      src_shards),
                target=DistributedSet(sset.name, sset.scheme, {}),
                scheme=sset.scheme)
            return recover_target_shard(reg, slot), moved
        finally:
            for res in reservations:
                res.release()

    def _recover_shard(self, sset: ShardedSet, info: ShardInfo, node_id: int,
                       report: RecoveryReport) -> bool:
        """Execute the scheduler's cheapest viable recovery source for one
        lost primary shard. A candidate that fails verification falls through
        to the next-cheapest one; returns False when every candidate is
        exhausted."""
        pool = self.nodes[node_id].pool
        for src in self.scheduler.recovery_plan(sset, node_id, node_id):
            if src.kind == "pagelog":
                # local-disk warm restore (PR 6): adopt the replayed index
                # and stream-verify. A torn tail or stale image just falls
                # through to the next candidate — the log is best-effort,
                # replicas remain the durability truth.
                if self._warm_restore_set(node_id, info.set_name,
                                          sset.page_size, sset.dtype,
                                          info.checksum,
                                          self._shard_attrs(sset)):
                    report.sources[f"{sset.name}:{node_id}"] = "pagelog"
                    report.shards_recovered += 1
                    report.warm_shards += 1
                    return True
                continue
            if src.kind == "rebuild":
                rebuilt, moved = self._rebuild_shard_from_replica(
                    sset, node_id, src.replica_of)
                if record_content_checksum(rebuilt) != info.content_checksum:
                    report.checksum_failures.append(
                        f"{sset.name}: content mismatch rebuilding shard "
                        f"{node_id} from {src.replica_of}")
                    continue
                attrs = sset.attrs_factory() if sset.attrs_factory else None
                self.nodes[node_id].write_records(
                    info.set_name, rebuilt, sset.dtype, sset.page_size, attrs)
                self.add_net_bytes(moved)
                report.bytes_transferred += moved
                # the rebuilt order is the shard's new canonical layout:
                # re-key the order-exact CRC (and the epoch: the bytes were
                # just rewritten) and refresh surviving replicas
                info.checksum = shard_checksum(rebuilt)
                info.epoch = self.stats.event_seq
                for holder, rep_name in info.replicas:
                    hnode = self.nodes[holder]
                    if not hnode.alive:
                        continue
                    if rep_name in hnode.pool.paging.sets:
                        hnode.pool.drop_set(hnode.pool.get_set(rep_name))
                    report.bytes_transferred += self.transfer_records(
                        node_id, info.set_name, holder, rep_name, sset.dtype,
                        sset.page_size, attrs=self._shard_attrs(sset))
                report.sources[f"{sset.name}:{node_id}"] = \
                    f"rebuild<-{src.replica_of}"
                report.shards_recovered += 1
                return True
            # primary/replica: page-for-page copy, order-exact CRC check
            # (shard attrs ride along, so a cold-recovered primary is
            # write-through again and re-enters the durable tier)
            report.bytes_transferred += self.transfer_records(
                src.holder, src.set_name, node_id, info.set_name, sset.dtype,
                sset.page_size, attrs=self._shard_attrs(sset))
            rebuilt = self.read_shard(sset, node_id)
            if shard_checksum(rebuilt) != info.checksum:
                report.checksum_failures.append(
                    f"{sset.name}: checksum mismatch on shard {node_id} "
                    f"from {src.kind}@{src.holder}")
                pool.drop_set(pool.get_set(info.set_name))
                continue
            report.sources[f"{sset.name}:{node_id}"] = \
                f"{src.kind}@{src.holder}"
            report.shards_recovered += 1
            return True
        return False

    def _shard_attrs(self, sset: ShardedSet) -> Optional[AttributeSet]:
        return sset.attrs_factory() if sset.attrs_factory else None

    def _warm_restore_set(self, node_id: int, set_name: str, page_size: int,
                          dtype: np.dtype, expect_crc: int,
                          attrs: Optional[AttributeSet] = None) -> bool:
        """Adopt one set from the revived node's replayed page log and
        stream-verify its CRC against the catalog. The verify pass reads the
        page images straight out of the log file — sequential disk reads,
        no pool allocation — so adoption stays O(index) and the pages stay
        non-resident until something actually pins them. On mismatch (torn
        tail truncated a page, stale bytes) nothing is adopted and the
        caller falls through to a replica or rebuild source."""
        pool = self.nodes[node_id].pool
        log = pool.memory.pagelog if pool is not None else None
        if log is None or not log.entries_for(set_name):
            return False
        if set_name in pool.paging.sets:
            return True  # already adopted during this recovery
        columnar = (attrs is not None
                    and attrs.storage is StorageScheme.COLUMNAR)
        if not self._verify_log_crc(log, set_name, dtype, expect_crc,
                                    columnar=columnar):
            return False
        pool.adopt_durable_set(set_name, page_size, attrs)
        return True

    @staticmethod
    def _verify_log_crc(log, set_name: str, dtype: np.dtype,
                        expect: int, columnar: bool = False) -> bool:
        """CRC a set's record bytes directly from its durable-log page
        images (each payload is itself CRC-checked by ``PageLog.read``).
        Entries are visited in seq order — the same order adoption assigns
        page ids, so the byte stream matches ``_verify_set_crc``'s. The
        cataloged checksum is the row-major record CRC for *both* storage
        schemes, so columnar payloads are decoded block -> records before
        hashing (the layout is a pure function of dtype + page size, and a
        logged payload is a whole page image)."""
        itemsize = np.dtype(dtype).itemsize
        crc = 0
        try:
            for entry in log.entries_for(set_name):
                payload = log.read(set_name, entry.seq)
                if columnar:
                    layout = ColumnLayout.for_page(dtype, len(payload))
                    cols, n = read_block(np.frombuffer(payload, np.uint8),
                                         layout)
                    body = columns_to_records(cols, dtype, n).tobytes()
                else:
                    n = int(np.frombuffer(payload[:_HEADER], np.int64)[0])
                    body = payload[_HEADER:_HEADER + n * itemsize]
                    if len(body) != n * itemsize:
                        return False
                crc = zlib.crc32(body, crc)
        except (IOError, KeyError, ValueError):
            return False
        return (crc & 0xFFFFFFFF) == expect

    def recover_node(self, node_id: int) -> RecoveryReport:
        """Bring a fresh node up under the failed node's identity and rebuild
        its state through the buffer pools:

        1. the node revives (``revive_node``): with the durable tier its
           local page log is replayed and fenced, so the scheduler can cost
           "adopt it from local disk" against "pull replica bytes";
        2. every primary shard it owned is re-materialized from the *cheapest*
           source the scheduler can cost (``scheduler.recovery_plan``): the
           fenced local page log (CRC stream-verified, zero network bytes),
           a surviving chain replica (verified against the cataloged CRC32,
           ties broken toward the least memory-pressured holder), or — when
           no direct copy survives — a co-partitioned rebuild from a
           heterogeneously partitioned replica set (verified against the
           order-independent content checksum);
        3. every replica it held for other owners is warm-restored from the
           log when its image survives, else re-replicated from the (alive)
           primary, restoring the replication factor.
        """
        t0 = time.perf_counter()
        report = RecoveryReport(node_id=node_id)
        report.fenced_sets = self.revive_node(node_id)
        for sset in self.catalog.values():
            info = sset.shards.get(node_id)
            if info is not None:
                if not self._recover_shard(sset, info, node_id, report):
                    report.checksum_failures.append(
                        f"{sset.name}: no surviving replica of shard "
                        f"{node_id}")
            # replicas this node held for other owners
            for owner, oinfo in sset.shards.items():
                if owner == node_id:
                    continue
                for holder, rep_name in oinfo.replicas:
                    if holder != node_id:
                        continue
                    if self._warm_restore_set(node_id, rep_name,
                                              sset.page_size, sset.dtype,
                                              oinfo.checksum,
                                              self._shard_attrs(sset)):
                        report.warm_replicas += 1
                        report.replicas_rebuilt += 1
                        continue
                    report.bytes_transferred += self.transfer_records(
                        owner, oinfo.set_name, node_id, rep_name, sset.dtype,
                        sset.page_size, attrs=self._shard_attrs(sset))
                    rebuilt = self.nodes[node_id].read_records(rep_name,
                                                               sset.dtype)
                    if shard_checksum(rebuilt) != oinfo.checksum:
                        report.checksum_failures.append(
                            f"{sset.name}: checksum mismatch on replica of "
                            f"shard {owner} at {node_id}")
                    report.replicas_rebuilt += 1
        report.seconds = time.perf_counter() - t0
        return report

    # -- elastic degrade (ROADMAP follow-up: shrink instead of fail) ----------
    def _verify_set_crc(self, holder: int, set_name: str, dtype: np.dtype,
                        expect: int) -> bool:
        """Streaming CRC pass over a candidate source set before it feeds the
        remesh: one page pinned at a time, O(page) driver memory, no gather."""
        pool = self.nodes[holder].pool
        ls = pool.get_set(set_name)
        crc = 0
        for chunk in _iter_record_chunks(pool, ls, dtype):
            crc = zlib.crc32(np.ascontiguousarray(chunk).tobytes(), crc)
        return (crc & 0xFFFFFFFF) == expect

    def _remesh_set_gather(self, sset: ShardedSet, alive: List[int],
                           report: RemeshReport) -> bool:
        """The PR-2 path: gather the whole set at the driver, re-place it.
        Kept as the reference implementation (the streaming path must produce
        byte-identical shards) — its driver reservation is the whole set."""
        try:
            records = self.read_sharded(sset)
        except DeadNodeError:
            return False
        base_net = self.net_bytes
        with self.driver_memory.reserve(records.nbytes):
            per_node, num_parts = remesh_partition_plan(
                sset.scheme.num_partitions, len(sset.node_ids), alive)
            self._drop_physical(sset)
            sset.node_ids = list(alive)
            sset.scheme = PartitionScheme(sset.scheme.name,
                                          sset.scheme.key_fn,
                                          num_parts, len(alive))
            sset.replication_factor = min(sset.replication_factor,
                                          len(alive) - 1)
            sset.shards = {}
            self._place_records(sset, records)
        report.bytes_transferred += self.net_bytes - base_net
        return True

    def _remesh_set_streaming(self, sset: ShardedSet, alive: List[int],
                              report: RemeshReport) -> bool:
        """Stream one sharded set shard-to-shard onto the survivors: every
        source shard is scanned page by page (scheduler-ranked, CRC-verified
        source), each page-sized chunk is routed by the new scheme and
        appended to per-destination sequential writers, and only that chunk
        is ever staged driver-side (charged to ``driver_memory.reserve`` so
        the O(page) claim is assertable). Per-destination CRC32 and content
        checksums accumulate as chunks land, so the new catalog entries are
        certified without ever materializing a shard at the driver."""
        # 1. pick (and for replicas, verify) a source for every old shard
        #    before writing anything, so a lost set stages no partial state
        sources: Dict[int, Tuple[int, str]] = {}
        for n in sorted(sset.shards):
            info = sset.shards[n]
            chosen = None
            for holder, set_name in self.scheduler.remesh_read_source(
                    sset, n, alive):
                if holder == n or self._verify_set_crc(
                        holder, set_name, sset.dtype, info.checksum):
                    chosen = (holder, set_name)
                    break
            if chosen is None:
                return False
            sources[n] = chosen
        # 2. stage new shards under remesh names, streaming chunk by chunk
        per_node, num_parts = remesh_partition_plan(
            sset.scheme.num_partitions, len(sset.node_ids), alive)
        new_scheme = PartitionScheme(sset.scheme.name, sset.scheme.key_fn,
                                     num_parts, len(alive))
        writers: Dict[int, object] = {}
        crc = {nid: 0 for nid in alive}
        content = {nid: 0 for nid in alive}
        counts = {nid: 0 for nid in alive}
        columnar = sharded_set_is_columnar(sset)
        for nid in alive:
            attrs = sset.attrs_factory() if sset.attrs_factory else None
            ls = self.node(nid).pool.create_set(
                f"{sset.name}/shard{nid}@remesh", sset.page_size, attrs)
            writer_cls = ColumnarWriter if columnar else SequentialWriter
            writers[nid] = writer_cls(self.node(nid).pool, ls, sset.dtype)
        base_net = self.net_bytes
        try:
            for n in sorted(sset.shards):
                holder, set_name = sources[n]
                src_pool = self.nodes[holder].pool
                ls_src = src_pool.get_set(set_name)
                for chunk in _iter_record_chunks(src_pool, ls_src,
                                                 sset.dtype):
                    # staged: the pinned chunk plus its routed copy below
                    with self.driver_memory.reserve(2 * chunk.nbytes):
                        slots = new_scheme.node_of_records(chunk)
                        order, _cnt, offsets = dispatch_plan(slots, len(alive))
                        routed = chunk[order]
                        for slot, nid in enumerate(alive):
                            sub = routed[offsets[slot]:offsets[slot + 1]]
                            if not len(sub):
                                continue
                            # pace the shard-to-shard stream against the
                            # destination survivor's admission grant — a
                            # pressured survivor throttles the remesh
                            # instead of being buried by it
                            reservation = None
                            if self.admission:
                                memory = self.nodes[nid].memory
                                if memory is not None:
                                    reservation = memory.try_reserve(
                                        sub.nbytes, urgency="required",
                                        timeout=self.admission_timeout_s)
                            try:
                                writers[nid].append_batch(sub)
                            finally:
                                if reservation is not None:
                                    reservation.release()
                            crc[nid] = zlib.crc32(
                                np.ascontiguousarray(sub).tobytes(), crc[nid])
                            content[nid] = combine_content_checksums(
                                [content[nid], record_content_checksum(sub)])
                            counts[nid] += len(sub)
                            if holder == nid:
                                self.add_local_bytes(sub.nbytes)
                            else:
                                self.add_net_bytes(sub.nbytes)
            for w in writers.values():
                w.close()
        except BaseException:
            # drop the staging sets so a failed stream (pool exhaustion on a
            # pressured survivor, a dying source) leaves the old layout
            # intact and a retried remesh doesn't trip over stale names
            for nid in alive:
                pool = self.nodes[nid].pool
                name = f"{sset.name}/shard{nid}@remesh"
                if pool is not None and name in pool.paging.sets:
                    pool.drop_set(pool.get_set(name))
            raise
        # 3. swap: drop the old layout, rename staging sets into place
        self._drop_physical(sset)
        sset.node_ids = list(alive)
        sset.scheme = new_scheme
        sset.replication_factor = min(sset.replication_factor,
                                      len(alive) - 1)
        sset.shards = {}
        for nid in alive:
            pool = self.node(nid).pool
            pool.rename_set(pool.get_set(f"{sset.name}/shard{nid}@remesh"),
                            sset.primary_set_name(nid))
            sset.shards[nid] = ShardInfo(
                node_id=nid, set_name=sset.primary_set_name(nid),
                num_records=counts[nid], checksum=crc[nid] & 0xFFFFFFFF,
                content_checksum=content[nid],
                epoch=self.stats.event_seq)
        # 4. chain replicas from the new primaries
        for slot, nid in enumerate(alive):
            info = sset.shards[nid]
            for hslot in replica_nodes(slot, len(alive),
                                       sset.replication_factor):
                holder = alive[hslot]
                rep_name = sset.replica_set_name(nid, holder)
                self.transfer_records(nid, info.set_name, holder, rep_name,
                                      sset.dtype, sset.page_size,
                                      attrs=self._shard_attrs(sset))
                info.replicas.append((holder, rep_name))
        report.bytes_transferred += self.net_bytes - base_net
        return True

    def remesh_degrade(self,
                       dead_nodes: Optional[Sequence[int]] = None,
                       streaming: bool = True) -> RemeshReport:
        """Unrecoverable node loss: no replacement machine will take the dead
        node's identity, so fall through to ``elastic.plan_remesh`` — shrink
        the membership to the survivors and re-partition every sharded set
        over it from the freshest surviving copies (primaries where alive,
        CRC-verified replicas where not). Sets with an unreadable shard are
        reported as ``lost`` rather than silently truncated. The set objects
        are updated in place, so existing handles stay valid.

        By default each set streams shard-to-shard in page-sized chunks
        (peak driver-side buffering O(page), asserted via the driver
        MemoryManager's reservation high-water mark); ``streaming=False``
        keeps the PR-2 gather-at-driver path, which produces byte-identical
        shards at O(dataset) driver memory."""
        t0 = time.perf_counter()
        for n in (dead_nodes or ()):
            if self.nodes[n].alive:
                self.kill_node(n)
        dead = self.dead_node_ids()
        alive = surviving_node_ids(self.num_nodes, dead)
        if not alive:
            raise DeadNodeError("no surviving nodes to remesh onto")
        report = RemeshReport(
            dead_nodes=dead, node_ids=alive,
            plan=plan_remesh(self.num_nodes, dead, chips_per_host=1,
                             prefer_model=1),
            streamed=streaming)
        # measure THIS remesh's driver staging peak, not lifetime history
        self.driver_memory.reset_reserved_hwm()
        for name in sorted(self.catalog):
            sset = self.catalog[name]
            remesh_set = (self._remesh_set_streaming if streaming
                          else self._remesh_set_gather)
            if remesh_set(sset, alive, report):
                self.stats.update_replica(name, self._replica_info(sset))
                report.resharded.append(name)
            else:
                report.lost.append(name)
        report.driver_peak_bytes = self.driver_memory.reserved_hwm
        self.stats.note_event()  # topology event: membership + layout changed
        report.seconds = time.perf_counter() - t0
        return report

    # -- accounting -----------------------------------------------------------
    def memory_report(self) -> Dict[int, Dict[str, Dict[str, int]]]:
        return {n: node.pool.memory_report()
                for n, node in self.nodes.items() if node.alive}

    def pressure_report(self) -> Dict[int, Dict[str, float]]:
        """Every alive node's MemoryManager pressure snapshot, plus the
        driver's own staging accounting under key ``-1``."""
        rep = {n: node.memory.pressure_report()
               for n, node in self.nodes.items() if node.alive}
        rep[-1] = self.driver_memory.pressure_report()
        return rep

    def shuffle(self, name: str, num_reducers: int, dtype: np.dtype,
                page_size: Optional[int] = None,
                admission: Optional[bool] = None,
                columnar: bool = False,
                partition_fn: Optional[Callable[[np.ndarray],
                                                np.ndarray]] = None
                ) -> "ClusterShuffle":
        """Shuffle factory — the backend-neutral entry point (the proc
        backend exposes the same signature, so callers can hold a
        ``Cluster`` of either backend and not care)."""
        return ClusterShuffle(self, name, num_reducers, dtype,
                              page_size=page_size, admission=admission,
                              columnar=columnar, partition_fn=partition_fn)

    def shutdown(self) -> None:
        """Stop the transfer engine's workers (benchmarks that build many
        clusters call this; tests can rely on idle-exit instead)."""
        if self._transfer is not None:
            self._transfer.shutdown()
            self._transfer = None


# ---------------------------------------------------------------------------
# Distributed shuffle (paper §8 across nodes)
# ---------------------------------------------------------------------------
class ClusterShuffle:
    """Map-side: each node's ``ShuffleService`` writes one virtual shuffle
    buffer per *global* reducer into the node-local pool (concurrent-write
    job data). Reduce-side: reducer ``r`` pulls partition ``r`` from every map
    node through the transfer path, after which the map output's lifetime is
    ended and its pages dropped.

    Placement is the scheduler's: ``finish_maps`` publishes per-partition
    byte counts to the statistics DB, ``place_reducers_locally`` then pins
    each reducer to the byte-heaviest map node (default placement is the
    round-robin baseline over alive nodes). ``pull_async`` runs pulls as
    transfer-engine jobs so they overlap finalization and each other, and
    ``reexecute_stragglers`` re-runs a slow mapper's work on a node holding a
    replica of its shard."""

    def __init__(self, cluster: Cluster, name: str, num_reducers: int,
                 dtype: np.dtype, page_size: Optional[int] = None,
                 scheduler: Optional[ClusterScheduler] = None,
                 partition_fn: Optional[Callable[[np.ndarray], np.ndarray]] = None,
                 admission: Optional[bool] = None,
                 columnar: bool = False):
        self.cluster = cluster
        self.name = name
        self.num_reducers = num_reducers
        self.dtype = np.dtype(dtype)
        self.page_size = page_size or cluster.page_size
        self.scheduler = scheduler or cluster.scheduler
        # columnar mode (PR 7): map output lands in per-partition columnar
        # sets via the fused hash-partition + CRC pass (``map_columns``), the
        # reducer pull moves column blocks and re-verifies the chained
        # per-partition CRC32, and ``stream_partition`` yields ``(columns,
        # n)`` views instead of record arrays. The per-partition CRC chain
        # assumes one mapper thread per node (writers on one node interleave
        # block append order otherwise).
        self.columnar = columnar
        # keys -> reducer partition override; the join path routes a shuffled
        # side by the *stationary* side's storage scheme so matching keys
        # land on the nodes whose build shards already sit there
        self.partition_fn = partition_fn
        # admission control (PR 5): map writers pace their job-data page
        # writes against the worker node's staging grant, reducer pulls pace
        # each staged chunk against the destination's grant, and placement
        # re-routes reducers whose planned node refuses admission past the
        # deadline. Defaults to the cluster-wide knob.
        self.admission = (cluster.admission if admission is None
                          else admission)
        self.placement: Optional[Dict[int, int]] = None
        # reducer -> (refused_node, placed_node) when admission diverted it
        self.diversions: Dict[int, Tuple[int, int]] = {}
        # (straggler, refused_holder, placed_holder) for every backup task
        # whose byte-local holder refused admission (carried bugfix)
        self.backup_diversions: List[Tuple[int, int, int]] = []
        self._services: Dict[int, ShuffleService] = {}
        self._svc_lock = tracked_lock("shuffle.svc")  # threaded mappers race creation
        self._pulled: Dict[int, Tuple[str, int]] = {}  # reducer -> (set, node)
        self._deferred_release: set = set()  # reducers whose map-side drop waits
        # worker node -> shard-map work items it performed, for straggler
        # re-execution: (sset, shard_id, key_fn, transform, batch)
        self._work: Dict[int, List[tuple]] = {}

    def reducer_node(self, reducer: int) -> int:
        if self.placement is not None and reducer in self.placement:
            return self.placement[reducer]
        alive = self.cluster.alive_node_ids()
        return alive[reducer % len(alive)]

    def assign_placement(self, placement: Dict[int, int]) -> None:
        self.placement = dict(placement)

    def place_reducers_locally(self) -> Dict[int, int]:
        """Adopt the scheduler's locality-aware placement (call after
        ``finish_maps`` — it needs the published byte statistics). With
        admission on, each reducer's chosen node must also admit the
        partition's landing bytes within the cluster's deadline; refused
        reducers are diverted to the next-best byte-locality candidate and
        the diversions recorded on ``self.diversions``."""
        if self.admission:
            plan = self.scheduler.place_reducers_admitted(
                self.name, self.num_reducers,
                deadline_s=self.cluster.admission_deadline_s)
            self.diversions = dict(plan.diversions)
            self.assign_placement(plan.placement)
        else:
            self.assign_placement(self.scheduler.place_reducers(
                self.name, self.num_reducers))
        return self.placement

    def _service(self, node_id: int):
        with self._svc_lock:
            if node_id not in self._services:
                if self.columnar:
                    self._services[node_id] = ColumnarShuffleService(
                        self.cluster.node(node_id).pool,
                        f"{self.name}/map{node_id}", self.num_reducers,
                        self.dtype, page_size=self.page_size,
                        attrs_factory=columnar_job_data_attrs)
                else:
                    self._services[node_id] = ShuffleService(
                        self.cluster.node(node_id).pool,
                        f"{self.name}/map{node_id}", self.num_reducers,
                        self.dtype, page_size=self.page_size,
                        attrs_factory=job_data_attrs)
            return self._services[node_id]

    def partition_of_keys(self, keys: np.ndarray) -> np.ndarray:
        if self.partition_fn is not None:
            return self.partition_fn(keys)
        return reducer_hash(keys, self.num_reducers)

    def _paced_reservation(self, node_id: int, nbytes: int):
        """Admission-paced staging grant against ``node_id`` (None when
        admission is off or the node has no manager). Writers holding a
        grant proceed; writers without headroom block until peers release
        or the timeout forces them through — bounded in-flight bytes,
        never dropped records."""
        if not self.admission:
            return None
        node = self.cluster.nodes.get(node_id)
        memory = node.memory if node is not None else None
        if memory is None:
            return None
        return memory.try_reserve(
            nbytes, urgency="required",
            timeout=self.cluster.admission_timeout_s)

    def map_batch(self, node_id: int, records: np.ndarray,
                  key_fn: Callable[[np.ndarray], np.ndarray]) -> None:
        """Partition ``records`` on node ``node_id`` into its local virtual
        shuffle buffers, one contiguous slice per reducer (dispatch plan).
        The write is paced against the node's admission grant: concurrent
        mappers feeding one pressured node throttle instead of stampeding
        its pool."""
        if len(records) == 0:
            return
        if self.columnar:
            # row-API compatibility for columnar shuffles (straggler replay
            # re-feeds shard records through here): split once, then the
            # fused column path
            self.map_columns(node_id, records_to_columns(records),
                             len(records), key_fn(records))
            return
        parts = self.partition_of_keys(key_fn(records))
        order, counts, offsets = dispatch_plan(parts, self.num_reducers)
        routed = records[order]
        svc = self._service(node_id)
        # writer identity = (node, thread): concurrent mapper threads feeding
        # one node each get their own virtual shuffle buffers (the service
        # hands out disjoint small pages), so threaded map writers are safe
        worker = (node_id, threading.get_ident())
        reservation = self._paced_reservation(node_id, routed.nbytes)
        try:
            for r in range(self.num_reducers):
                chunk = routed[offsets[r]:offsets[r + 1]]
                if len(chunk):
                    svc.get_buffer(worker, r).add_batch(chunk)
        finally:
            if reservation is not None:
                reservation.release()

    def map_columns(self, node_id: int, columns: Dict[str, np.ndarray],
                    n: int, keys: np.ndarray) -> None:
        """Columnar map hot path: one fused hash-partition + gather +
        incremental-CRC pass (``kernels.shuffle_dispatch.host_partition_crc``
        when importable, ``core.columnar.fused_partition_crc`` otherwise)
        routes a column batch, then each partition's contiguous column slice
        is memcpy'd into that reducer's column blocks — no row
        materialization anywhere on the map side. ``keys`` is the (view of
        the) key column the reducer hash runs over; a ``partition_fn``
        override (the join path's scheme routing) takes the unfused
        dispatch-plan route with the same chained CRC."""
        if not self.columnar:
            raise ValueError("map_columns requires columnar=True")
        if n == 0:
            return
        svc = self._service(node_id)
        worker = (node_id, threading.get_ident())
        nbytes = n * self.dtype.itemsize
        reservation = self._paced_reservation(node_id, nbytes)
        try:
            if self.partition_fn is None:
                # reducer hash -> narrow ids -> dispatch plan, then gather
                # each partition's rows STRAIGHT into its landing pages
                # (np.take with the page region as out) with the per-field
                # CRC chains run over the landed bytes — the fused pass with
                # zero intermediate copies (the ``fused_partition_crc``
                # kernel materializing a routed block serves the non-landing
                # callers and the roofline bench)
                h = route_partition_ids(keys, self.num_reducers)
                parts = (h.astype(np.uint8) if self.num_reducers <= 256
                         else h.astype(np.int64))
                order, counts, offsets = dispatch_plan(parts,
                                                       self.num_reducers)
                svc.add_gathered(worker, columns, order, offsets)
            else:
                parts = self.partition_fn(np.asarray(keys)[:n])
                order, counts, offsets = dispatch_plan(parts,
                                                       self.num_reducers)
                routed = {f: np.take(np.asarray(col)[:n], order, axis=0)
                          for f, col in columns.items()}
                for r in range(self.num_reducers):
                    lo, hi = int(offsets[r]), int(offsets[r + 1])
                    if hi > lo:
                        svc.partition_crcs[r] = columns_crc32(
                            routed, self.dtype, lo, hi,
                            svc.partition_crcs[r])
                svc.add_routed(worker, routed, offsets)
        finally:
            if reservation is not None:
                reservation.release()

    def map_shard(self, sset: ShardedSet, shard_id: int,
                  key_fn: Callable[[np.ndarray], np.ndarray],
                  transform: Optional[Callable[[np.ndarray], np.ndarray]] = None,
                  batch: int = 65536,
                  key_field: Optional[str] = None) -> int:
        """Run the map side for one shard on the node that holds its bytes
        (the primary owner, or a replica holder when the owner is down).
        Returns the worker node id; the work item is remembered so a
        straggler's shards can be replayed elsewhere.

        Columnar fast path: when this shuffle is columnar, the shard's
        primary is alive and stored columnar, and no record transform is
        requested, blocks stream straight off the shard's pages into the
        fused ``map_columns`` pass — ``key_field`` names the key column so
        keys never require row materialization (without it the key batch is
        materialized per block through ``key_fn``, the rest still moves as
        columns)."""
        if self.columnar and transform is None:
            info = sset.shards[shard_id]
            node = self.cluster.nodes[info.node_id]
            if (node.alive and node.pool is not None
                    and info.set_name in node.pool.paging.sets):
                ls = node.pool.get_set(info.set_name)
                if is_columnar(ls):
                    total = 0
                    for cols, n in iter_column_blocks(node.pool, ls,
                                                      sset.dtype):
                        keys = (cols[key_field] if key_field is not None
                                else key_fn(columns_to_records(
                                    cols, sset.dtype, n)))
                        self.map_columns(info.node_id, cols, n, keys)
                        total += n
                    self._work.setdefault(info.node_id, []).append(
                        (sset, shard_id, key_fn, transform, batch, total))
                    return info.node_id
        worker, records = self.cluster.read_shard_from(sset, shard_id)
        if transform is not None:
            records = transform(records)
        for i in range(0, len(records), batch):
            self.map_batch(worker, records[i:i + batch], key_fn)
        self._work.setdefault(worker, []).append(
            (sset, shard_id, key_fn, transform, batch, len(records)))
        return worker

    def map_sharded(self, sset: ShardedSet,
                    key_fn: Callable[[np.ndarray], np.ndarray],
                    batch: int = 65536,
                    step_timer: Optional[StepTimer] = None) -> None:
        """Run the map side over every shard of a sharded set, reading
        through each holder's pool (sequential read service). With a
        ``step_timer``, per-shard map times feed the straggler detector
        (attributed to the node that executed the work, which for a dead
        owner's shard is its replica holder) and flagged mappers are
        re-executed from replica holders; a single map pass per host counts
        (``min_samples=1``)."""
        for n in sorted(sset.shards):
            t0 = time.perf_counter()
            worker = self.map_shard(sset, n, key_fn, batch=batch)
            if step_timer is not None:
                step_timer.record(worker, time.perf_counter() - t0)
        if step_timer is not None:
            self.reexecute_stragglers(step_timer.stragglers(min_samples=1))

    # -- straggler re-execution (ROADMAP follow-up) ---------------------------
    def discard_map_output(self, node_id: int) -> None:
        """Throw away everything node ``node_id`` mapped (its job-data pages
        are lifetime-ended and dropped) — the straggler's partial output must
        not double-count once a backup re-executes its shards."""
        svc = self._services.pop(node_id, None)
        if svc is None:
            return
        svc.finish_writes()
        for r in range(self.num_reducers):
            svc.release_partition(r)

    def reexecute_stragglers(self,
                             stragglers: Sequence[int]) -> List[Tuple[int, int]]:
        """Re-execute every shard a straggler mapped on a node that already
        holds a copy (``scheduler.backup_source``: the alive primary when the
        straggler was only a backup, else a replica holder — paper §7's
        backup tasks applied to execution). Call between the map phase and
        ``finish_maps`` — the byte statistics published at finalization then
        reflect the re-executed layout. The slow output stands (no discard)
        when a shard has no other surviving copy, or when the node's service
        holds records fed through the raw ``map_batch`` API (untracked work
        cannot be replayed, and dropping it would lose records). Returns
        ``[(straggler, backup), ...]``.

        With admission on, each backup's landing node is chosen through
        ``scheduler.backup_source_admitted`` (carried bugfix): the holder
        must admit the shard's re-execution bytes just like reducer
        placement admits a partition's, so a pressured replica holder is
        passed over for the next surviving copy; diversions are recorded on
        ``self.backup_diversions`` as ``(straggler, refused, placed)``."""
        redone: List[Tuple[int, int]] = []
        for s in stragglers:
            items = self._work.get(s)
            svc = self._services.get(s)
            if not items or svc is None:
                continue
            tracked = sum(it[5] for it in items)
            if sum(svc.partition_records) != tracked:
                continue  # mixed provenance: raw map_batch records present
            sources = []
            for (sset, shard_id, *_rest) in items:
                if self.admission:
                    src, diversion = self.scheduler.backup_source_admitted(
                        sset, shard_id, exclude=s,
                        deadline_s=self.cluster.admission_deadline_s)
                    if src is not None and diversion is not None:
                        self.backup_diversions.append((s,) + diversion)
                else:
                    src = self.scheduler.backup_source(sset, shard_id,
                                                       exclude=s)
                sources.append(src)
            if any(src is None for src in sources):
                continue  # nowhere else to run it; slow output stands
            self.discard_map_output(s)
            self._work.pop(s, None)
            for (sset, shard_id, key_fn, transform, batch, _n), \
                    (holder, set_name) in zip(items, sources):
                records = self.cluster.nodes[holder].read_records(
                    set_name, sset.dtype)
                if transform is not None:
                    records = transform(records)
                for i in range(0, len(records), batch):
                    self.map_batch(holder, records[i:i + batch], key_fn)
                self._work.setdefault(holder, []).append(
                    (sset, shard_id, key_fn, transform, batch, len(records)))
                redone.append((s, holder))
        return redone

    # -- map finalization ------------------------------------------------------
    def _finish_node(self, node_id: int, svc: ShuffleService) -> None:
        svc.finish_writes()
        for r in range(self.num_reducers):
            self.cluster.stats.record_shuffle_bytes(
                self.name, r, node_id, svc.partition_bytes[r])
        # publish the node's memory pressure alongside its byte counts: the
        # scheduler discounts locality on nodes already spilling (their map
        # output would fault back in page by page anyway)
        node = self.cluster.nodes[node_id]
        if node.memory is not None:
            self.cluster.stats.record_node_pressure(
                node_id, node.memory.pressure_score())

    def finish_maps(self) -> None:
        """Seal every map node's shuffle buffers and publish per-partition
        byte counts plus memory pressure to the statistics DB (the
        scheduler's placement inputs)."""
        for node_id, svc in sorted(self._services.items()):
            self._finish_node(node_id, svc)

    def finish_maps_async(self, engine: Optional[TransferEngine] = None) -> list:
        """Finalize each map node as an engine job; reducer pulls submitted
        ``after=`` these futures overlap finalization across nodes."""
        engine = engine or self.cluster.transfer
        return [engine.submit(self._finish_node, node_id, svc,
                              label=f"{self.name}/finish{node_id}")
                for node_id, svc in sorted(self._services.items())]

    # -- reduce-side pulls -----------------------------------------------------
    def pull(self, reducer: int) -> np.ndarray:
        """Reduce-side fetch: stream partition ``reducer`` from every map
        node into the reducer node's pool small-page by small-page (staging
        O(small page), charged to the destination's MemoryManager — never
        the whole partition, so a pull works even when the partition exceeds
        pool headroom), then release the map-side pages (lifetime ended —
        paper §6's cheapest victims). Spilled map output faults back in
        transparently as its pages are pinned.

        Columnar shuffles stage through ``pull_columns`` (raw block moves +
        CRC re-verification) and materialize rows only here, for the
        row-API consumer."""
        if self.columnar:
            cols, n = self.pull_columns(reducer)
            return columns_to_records(cols, self.dtype, n)
        dst_node = self.cluster.node(self.reducer_node(reducer))
        dst = dst_node.node_id
        reduce_set = f"{self.name}/reduce{reducer}"
        dst_pool = dst_node.pool
        ls = dst_pool.create_set(reduce_set, self.page_size, job_data_attrs())
        writer = SequentialWriter(dst_pool, ls, self.dtype)
        for node_id, svc in sorted(self._services.items()):
            for chunk in svc.iter_partition(reducer):
                # paced against the destination's grant (concurrent pulls
                # into one reducer node throttle each other); falls back to
                # the always-grant charge with admission off
                reservation = (self._paced_reservation(dst, chunk.nbytes)
                               or dst_node.memory.reserve(chunk.nbytes))
                try:
                    writer.append_batch(chunk)
                finally:
                    reservation.release()
                if node_id == dst:
                    self.cluster.add_local_bytes(chunk.nbytes)
                else:
                    self.cluster.add_net_bytes(chunk.nbytes)
            svc.release_partition(reducer)
        writer.close()
        self._pulled[reducer] = (reduce_set, dst)
        return dst_node.read_records(reduce_set, self.dtype)

    def pull_columns(self, reducer: int, materialize: bool = True,
                     verify: bool = True
                     ) -> Tuple[Dict[str, np.ndarray], int]:
        """Columnar reduce-side fetch: stream partition ``reducer``'s column
        blocks from every map node to the reducer's node (block moves — no
        per-record decode on either end), re-verifying each map node's
        chained per-partition per-field CRC32 as the blocks drain
        (byte-identical shuffle output is checked, not assumed; pass
        ``verify=False`` to skip the second CRC pass when the caller
        verifies the output itself). ``materialize=True`` additionally lands
        the blocks in a columnar reduce set on the reducer's node so the
        partition survives ``release``-then-reread; streaming consumers
        (the vectorized aggregate) pass ``False`` and read the returned
        arrays directly. Returns the partition as concatenated
        ``(columns, n)``."""
        if not self.columnar:
            raise ValueError("pull_columns requires columnar=True")
        dst_node = self.cluster.node(self.reducer_node(reducer))
        dst = dst_node.node_id
        writer = None
        reduce_set = None
        if materialize:
            reduce_set = f"{self.name}/reduce{reducer}"
            dst_pool = dst_node.pool
            ls = dst_pool.create_set(reduce_set, self.page_size,
                                     columnar_job_data_attrs())
            writer = ColumnarWriter(dst_pool, ls, self.dtype)
        services = sorted(self._services.items())
        # the services already know the partition's exact size: preallocate
        # the output columns once and charge admission once, instead of a
        # per-block copy + reserve + final concat
        total = sum(svc.partition_records[reducer] for _, svc in services)
        fields = _field_layout(self.dtype)
        out = {name: np.empty(total, fdt) for name, fdt, _, _ in fields}
        reservation = (self._paced_reservation(dst, total * self.dtype.itemsize)
                       or dst_node.memory.reserve(total * self.dtype.itemsize))
        # streaming fast path copies raw column bytes block -> out through
        # flat uint8 views (no per-block dtype view construction)
        out_flat = {name: out[name].view(np.uint8).reshape(-1)
                    for name, _, _, _ in fields}
        pos = 0
        local_bytes = net_bytes = 0
        layout = ColumnLayout.for_page(self.dtype, self.page_size)
        try:
            for node_id, svc in services:
                crcs = [0] * len(svc.partition_crcs[reducer]) if verify \
                    else None
                pos0 = pos
                ls = svc.partition_sets[reducer]
                pool = svc.pool
                ls.infer_from_service("sequential-read", pool.clock)
                for pid in sorted(ls.pages):
                    page = ls.pages[pid]
                    view = pool.pin(page)
                    try:
                        n = int(view[:8].view(np.int64)[0])
                        if not n:
                            continue
                        if writer is not None or verify:
                            cols, n = read_block(view, layout)
                            if writer is not None:
                                writer.append_columns(cols, n)
                            if verify:
                                columns_crc32(cols, self.dtype, 0, n, crcs)
                        for name, _, _, w in fields:
                            off = layout.field_offs[name]
                            out_flat[name][pos * w:(pos + n) * w] = \
                                view[off:off + n * w]
                        pos += n
                    finally:
                        pool.unpin(page)
                nbytes = (pos - pos0) * self.dtype.itemsize
                if node_id == dst:
                    local_bytes += nbytes
                else:
                    net_bytes += nbytes
                if verify and crcs != svc.partition_crcs[reducer]:
                    want = "/".join(f"{c:#010x}"
                                    for c in svc.partition_crcs[reducer])
                    got = "/".join(f"{c:#010x}" for c in crcs)
                    raise ValueError(
                        f"{self.name}: partition {reducer} bytes from map "
                        f"node {node_id} fail CRC re-verification "
                        f"({got} != {want})")
        except BaseException:
            # a failed verify must not strand a half-built reduce set on
            # the destination — drop it so the caller can re-pull once the
            # (still intact, release is deferred) map output is repaired
            if writer is not None:
                writer.close()
                dst_node.pool.drop_set(dst_node.pool.get_set(reduce_set))
            raise
        finally:
            reservation.release()
        if local_bytes:
            self.cluster.add_local_bytes(local_bytes)
        if net_bytes:
            self.cluster.add_net_bytes(net_bytes)
        if writer is not None:
            writer.close()
        # map-side release is deferred to ``release_reducer``: the drop
        # stays off the pull critical path, and a CRC failure above leaves
        # the map output intact for a re-pull.
        self._deferred_release.add(reducer)
        self._pulled[reducer] = (reduce_set, dst)
        return out, pos

    def pull_columns_async(self, reducer: int, after: Sequence = (),
                           materialize: bool = True, verify: bool = True):
        """``pull_async``'s columnar twin: submit ``pull_columns(reducer)``
        to the transfer engine with the same lazy destination/byte
        declarations."""
        return self.cluster.transfer.submit(
            self.pull_columns, reducer, materialize, verify, after=after,
            label=f"{self.name}/pull{reducer}",
            dest=lambda: self.reducer_node(reducer),
            nbytes=lambda: sum(self.cluster.stats.shuffle_partition_bytes(
                self.name, reducer).values()))

    def stream_partition(self, reducer: int, dst_node: int) -> Iterator:
        """Stream partition ``reducer`` straight off every map node's shuffle
        service, small-page by small-page, with byte accounting against
        ``dst_node`` as the consumer — no reducer-set staging at all. This is
        the join path's probe feed: chunks go directly into the join tables.
        Row shuffles yield record arrays; columnar shuffles yield
        ``(columns, n)`` block views. Yielded arrays are views valid only
        until the next iteration (copy to retain); call ``release_partition``
        once the consumer is done."""
        for node_id, svc in sorted(self._services.items()):
            for chunk in svc.iter_partition(reducer):
                if self.columnar:
                    nbytes = chunk[1] * self.dtype.itemsize
                else:
                    nbytes = chunk.nbytes
                if node_id == dst_node:
                    self.cluster.add_local_bytes(nbytes)
                else:
                    self.cluster.add_net_bytes(nbytes)
                yield chunk

    def release_partition(self, reducer: int) -> None:
        """End the map-side lifetime of one partition on every map node
        (what ``pull`` does implicitly; ``stream_partition`` consumers call
        it explicitly once their join/aggregate has drained the chunks)."""
        for svc in self._services.values():
            svc.release_partition(reducer)

    def pull_async(self, reducer: int, after: Sequence = ()):
        """Submit ``pull(reducer)`` to the transfer engine; returns its
        future. Safe to run concurrently with other pulls: the buffer pools
        are internally locked and each pull touches its own partition.
        The job declares its destination node and landing bytes (resolved
        lazily — placement may itself be a pending engine job), so the
        engine's per-destination cap keeps overlapped pulls from stampeding
        one reducer node."""
        return self.cluster.transfer.submit(
            self.pull, reducer, after=after, label=f"{self.name}/pull{reducer}",
            dest=lambda: self.reducer_node(reducer),
            nbytes=lambda: sum(self.cluster.stats.shuffle_partition_bytes(
                self.name, reducer).values()))

    def release_reducer(self, reducer: int) -> None:
        """Drop a pulled reduce partition once the reducer has consumed it
        (plus the map-side partition pages whose release ``pull_columns``
        deferred)."""
        if reducer in self._deferred_release:
            self._deferred_release.discard(reducer)
            self.release_partition(reducer)
        name, dst = self._pulled.pop(reducer, (None, None))
        if name is None:
            return
        pool = self.cluster.node(dst).pool
        if name in pool.paging.sets:
            ls = pool.get_set(name)
            ls.end_lifetime(pool.clock)
            pool.drop_set(ls)


# ---------------------------------------------------------------------------
# End-to-end hash aggregation (paper §9's Spark comparison)
# ---------------------------------------------------------------------------
def cluster_hash_aggregate(cluster: Cluster, sset: ShardedSet,
                           key_field: str, val_field: str,
                           num_reducers: Optional[int] = None,
                           num_root_partitions: int = 4,
                           hash_page_size: int = 1 << 16,
                           scheduler: Optional[ClusterScheduler] = None,
                           async_pull: bool = True,
                           step_timer: Optional[StepTimer] = None,
                           force_shuffle: bool = False,
                           ) -> Tuple[np.ndarray, np.ndarray]:
    """SELECT key, SUM(val) GROUP BY key over a sharded set, scheduled by the
    ``ClusterScheduler``:

    * input already partitioned on ``key_field`` (``stats.best_replica``
      finds a co-partitioned replica) → the shuffle is elided: every shard is
      aggregated in the pool that holds it and the merge is disjoint; zero
      bytes cross the network (paper §9.2.2's co-partitioned result).
    * otherwise → map-side shuffle by key hash; reducer ``r`` is placed on
      the node holding the most map output for partition ``r``; pulls run as
      overlapped transfer-engine jobs (``async_pull=False`` forces the
      synchronous path — results are identical).

    Reducer outputs are disjoint by construction (keys are routed by hash),
    so the merge is a concatenate + sort.

    Columnar sharded sets take the vectorized hot path (PR 7): the map side
    streams each shard's blocks and feeds ``{key, val}`` column *views*
    through the fused partition+CRC pass (zero row materialization), pulls
    move column blocks, and the reduce is a ``segment_sum`` (``np.unique`` +
    ``np.add.at``) instead of per-record open-addressing inserts. Note the
    float accumulation order differs from ``HashService`` (exact equality
    holds for integer-valued sums)."""
    scheduler = scheduler or cluster.scheduler
    num_reducers = num_reducers or cluster.num_nodes
    pair = HashService.PAIR_DTYPE
    plan = scheduler.plan_aggregation(sset, key_field)

    def to_pairs(records: np.ndarray) -> np.ndarray:
        out = np.empty(len(records), pair)
        out["key"] = records[key_field]
        out["val"] = records[val_field]
        return out

    def aggregate(node: StorageNode, tag, pulled: np.ndarray):
        hs = HashService(node.pool, f"{sset.name}.agg/hash{tag}",
                         num_root_partitions=num_root_partitions,
                         page_size=hash_page_size)
        if len(pulled):
            hs.insert(pulled["key"], pulled["val"])
        k, v = hs.finalize()
        hs.close()
        node.pool.drop_set(hs.ls)
        return k, v

    def shard_blocks_columnar(target: ShardedSet, n: int):
        """The shard's block iterator when its alive primary is columnar
        (the zero-materialization feed), else None (row/replica fallback)."""
        info = target.shards[n]
        node = cluster.nodes[info.node_id]
        if (node.alive and node.pool is not None
                and info.set_name in node.pool.paging.sets):
            ls = node.pool.get_set(info.set_name)
            if is_columnar(ls):
                return info.node_id, iter_column_blocks(node.pool, ls,
                                                        target.dtype)
        return None

    keys_out: List[np.ndarray] = []
    vals_out: List[np.ndarray] = []
    if plan.shuffle_free and not force_shuffle:
        # co-partitioned: same key -> same shard, so shard-local aggregation
        # is complete and the merge disjoint. net_bytes does not move. The
        # scheduler may have routed us to a by-key replica of the same
        # logical data (heterogeneous replicas, paper §7/§9.2.2).
        target = (cluster.catalog.get(plan.target_name, sset)
                  if plan.target_name else sset)
        for n in sorted(target.shards):
            blocks = shard_blocks_columnar(target, n)
            if blocks is not None:
                # vectorized shard-local reduce straight off the column
                # blocks — segment_sum per block, then one more merge pass
                # over the (tiny) per-block partials
                _holder, it = blocks
                pk: List[np.ndarray] = []
                pv: List[np.ndarray] = []
                for cols, cnt in it:
                    bk, bv = segment_sum(cols[key_field], cols[val_field])
                    pk.append(bk)
                    pv.append(bv)
                k, v = (segment_sum(np.concatenate(pk), np.concatenate(pv))
                        if pk else (np.empty(0, np.int64),
                                    np.empty(0, np.float64)))
            else:
                holder, shard = cluster.read_shard_from(target, n)
                k, v = aggregate(cluster.node(holder), f"local{n}",
                                 to_pairs(shard))
            keys_out.append(k)
            vals_out.append(v)
    else:
        columnar = sharded_set_is_columnar(sset)
        sh = ClusterShuffle(cluster, f"{sset.name}.agg", num_reducers, pair,
                            scheduler=scheduler, columnar=columnar)
        for n in sorted(sset.shards):
            t0 = time.perf_counter()
            blocks = shard_blocks_columnar(sset, n) if columnar else None
            if blocks is not None:
                # fused map over {key, val} column views of each block; the
                # block writer memcpys raw bytes, so the views must already
                # carry the pair dtype's field types (cast is a no-op when
                # they match — the common case)
                worker, it = blocks
                kdt = pair.fields["key"][0]
                vdt = pair.fields["val"][0]
                total = 0
                for cols, cnt in it:
                    kc, vc = cols[key_field], cols[val_field]
                    if kc.dtype != kdt:
                        kc = kc.astype(kdt)
                    if vc.dtype != vdt:
                        vc = vc.astype(vdt)
                    sh.map_columns(worker, {"key": kc, "val": vc}, cnt, kc)
                    total += cnt
                sh._work.setdefault(worker, []).append(
                    (sset, n, lambda p: p["key"], to_pairs, 65536, total))
            else:
                worker = sh.map_shard(sset, n, key_fn=lambda p: p["key"],
                                      transform=to_pairs)
            if step_timer is not None:
                step_timer.record(worker, time.perf_counter() - t0)
        if step_timer is not None:
            sh.reexecute_stragglers(step_timer.stragglers(min_samples=1))
        if columnar:
            # the reduce consumes the pulled columns in place — skip the
            # reduce-set materialization, keep the CRC re-verification
            puller = lambda r: sh.pull_columns(r, materialize=False)
            puller_async = lambda r, after: sh.pull_columns_async(
                r, after=after, materialize=False)
        else:
            puller = sh.pull
            puller_async = lambda r, after: sh.pull_async(r, after=after)
        if async_pull:
            engine = cluster.transfer
            fin = sh.finish_maps_async(engine)
            placed = engine.submit(sh.place_reducers_locally, after=fin,
                                   label=f"{sh.name}/place")
            futures = [puller_async(r, after=[placed])
                       for r in range(num_reducers)]
            pulls = (fut.result() for fut in futures)
        else:
            sh.finish_maps()
            sh.place_reducers_locally()
            pulls = (puller(r) for r in range(num_reducers))
        for r, pulled in enumerate(pulls):
            if columnar:
                cols, cnt = pulled
                k, v = segment_sum(cols["key"][:cnt], cols["val"][:cnt])
            else:
                node = cluster.node(sh.reducer_node(r))
                k, v = aggregate(node, r, pulled)
            sh.release_reducer(r)
            keys_out.append(k)
            vals_out.append(v)
        cluster.stats.clear_shuffle(sh.name)
    keys = np.concatenate(keys_out)
    vals = np.concatenate(vals_out)
    order = np.argsort(keys)
    return keys[order], vals[order]
