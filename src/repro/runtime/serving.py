"""Distributed paged-KV serving tier (PR 9).

The analytics paths already run everything through one monolithic manager per
node — admission, paging, spill, replication, recovery.  This module points
the same machinery at the serving workload from ROADMAP §2: millions of
sequences whose KV caches contend for HBM.

* **Sharding + session affinity** — every active sequence is a KV locality
  set inside one node's ``KVShard`` (a ``PagedKVCache`` modeling that node's
  HBM page pool).  The home node is hashed from the sequence id over the
  full membership, so a session keeps landing on the node that already
  holds its pages.
* **Continuous-batching admission** — prefills probe the home node's
  ``try_reserve`` with ``urgency="low"`` (speculative: never waits).
  Refused prefills go through ``ClusterScheduler.place_sequences`` and are
  diverted to admitting nodes (``PlacementPlan.diversions``), falling back
  to the affinity node when everyone refuses — the pool spills, it does not
  drop sessions.  In-flight decode allocates new pages with
  ``urgency="required"`` (paced, never refused), exactly the shuffle
  pipeline's contract.
* **Three-level spill** — HBM pages evicted by Eq. 1 land in the shard's
  ``TieredSlabStore``: level 2 charges the node's ``MemoryManager`` (host
  pool); past the host budget, slabs overflow to a *remote* node's pool
  through the ``TransferEngine`` (level 3) and fault back on demand.
* **Failover** — every committed page slab is replicated to the session's
  replica node as a raw blob (``Cluster.store_bytes``, physically in the
  replica's pool — its own OS process on ``backend="proc"``).  When the
  serving node dies mid-stream the session rebuilds on the replica holder
  and resumes decode byte-identically; with no live replica it raises the
  same ``DeadNodeError("... must re-run")`` contract the shuffle honors.

KV content is a deterministic function of ``(seq_id, position)``
(``expected_page_slab``), so byte-identity across spill levels, backends,
and failovers is checkable, not just plausible.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from ..core.kvcache import HostSlabStore, PagedKVCache
from ..core.sanitizer import tracked_rlock
from .cluster import DeadNodeError
from .scheduler import ClusterScheduler, PlacementPlan


def token_value(seq_id: int, t: int):
    """Deterministic KV fill for token ``t`` of a sequence — the serving
    tier's byte-identity oracle."""
    return ((seq_id * 7919 + t * 104729) % 997) / 997.0


def expected_page_slab(seq_id: int, page_index: int, length: int, *,
                       num_layers: int, page_tokens: int, kv_heads: int,
                       head_dim: int, dtype=np.float32) -> np.ndarray:
    """Reference slab ``[L, page, 2, KH, D]`` for one logical page of a
    sequence at ``length`` committed tokens (zeros past the length)."""
    t = page_index * page_tokens + np.arange(page_tokens)
    vals = (((seq_id * 7919 + t * 104729) % 997) / 997.0)
    vals = np.where(t < length, vals, 0.0).astype(dtype)
    slab = np.zeros((num_layers, page_tokens, 2, kv_heads, head_dim), dtype)
    slab[:] = vals[None, :, None, None, None]
    return slab


class TieredSlabStore(HostSlabStore):
    """Levels 2 and 3 of one shard's KV spill hierarchy.

    ``put`` (an HBM eviction) charges the home node's ``MemoryManager``
    with a paced ``urgency="required"`` grant — host slabs are real memory
    the monolithic manager must see.  Past ``host_budget_bytes`` the oldest
    slabs overflow to a remote node's pool through the cluster's
    ``TransferEngine`` (async; the host copy is only dropped once the
    transfer confirms, so a spill-target death mid-transfer loses nothing).
    ``take`` faults remote slabs back; a dead level-3 holder raises
    ``DeadNodeError`` out of the restore, which the serving tier turns into
    a replica failover.
    """

    def __init__(self, tier: "ServingTier", node_id: int):
        self.tier = tier
        self.node_id = node_id
        # guards the slab maps/order/byte counter only; admission waits,
        # reservation releases, and cluster RPCs all happen outside it
        self._lock = tracked_rlock("serving.slabstore")
        self._local: Dict[int, Tuple[np.ndarray, object]] = {}
        self._order: List[int] = []          # FIFO overflow order
        self._inflight: Dict[int, Tuple[object, int]] = {}
        self._remote: Dict[int, int] = {}    # pid -> level-3 holder node
        self.host_bytes = 0
        self.stats = {"remote_spills": 0, "remote_fetches": 0,
                      "spill_failures": 0, "host_puts": 0}

    def _blob(self, page_id: int) -> str:
        return f"kvspill/{self.node_id}/{page_id}"

    def _charge(self, nbytes: int):
        memory = self.tier._memory(self.node_id)
        if memory is None or not self.tier.cluster.admission:
            return None
        try:
            return memory.try_reserve(
                nbytes, urgency="required",
                timeout=self.tier.cluster.admission_timeout_s)
        except DeadNodeError:
            return None   # node dying under us; failover will rebuild

    # -- HostSlabStore interface ---------------------------------------------
    def put(self, page_id: int, slab: np.ndarray) -> None:
        self._reap()
        # admission can wait (urgency="required" paces); never under _lock
        res = self._charge(slab.nbytes)
        with self._lock:
            prior = self._local.pop(page_id, None)
            if prior is not None:
                # superseding a live slab: drop the old entry's accounting
                # (the old code leaked its reservation and double-counted
                # host_bytes, and left a duplicate FIFO slot behind)
                self._order.remove(page_id)
                self.host_bytes -= prior[0].nbytes
            self._local[page_id] = (slab, res)
            self._order.append(page_id)
            self.host_bytes += slab.nbytes
            self.stats["host_puts"] += 1
        if prior is not None and prior[1] is not None:
            prior[1].release()   # notifies admission waiters: outside _lock
        self._maybe_overflow()

    def take(self, page_id: int) -> Optional[np.ndarray]:
        self._reap()
        with self._lock:
            entry = self._local.pop(page_id, None)
            if entry is not None:
                self._order.remove(page_id)
                self.host_bytes -= entry[0].nbytes
            holder = None if entry is not None else self._remote.get(page_id)
        if entry is not None:
            slab, res = entry
            if res is not None:
                res.release()
            # an in-flight remote copy is orphaned; _reap drops the blob
            return slab
        if holder is not None:
            self.tier._fire("during_restore")
            data = self.tier.cluster.load_bytes(holder, self._blob(page_id))
            with self._lock:
                self._remote.pop(page_id, None)
            self.tier.cluster.drop_bytes(holder, self._blob(page_id))
            self.stats["remote_fetches"] += 1
            return np.frombuffer(data, self.tier.dtype).reshape(
                self.tier.slab_shape).copy()
        return None

    def peek(self, page_id: int) -> Optional[np.ndarray]:
        self._reap()
        with self._lock:
            entry = self._local.get(page_id)
            holder = None if entry is not None else self._remote.get(page_id)
        if entry is not None:
            return entry[0]
        if holder is not None:
            data = self.tier.cluster.load_bytes(holder, self._blob(page_id))
            return np.frombuffer(data, self.tier.dtype).reshape(
                self.tier.slab_shape).copy()
        return None

    def discard(self, page_id: int) -> None:
        self._reap()
        with self._lock:
            entry = self._local.pop(page_id, None)
            if entry is not None:
                self._order.remove(page_id)
                self.host_bytes -= entry[0].nbytes
            holder = self._remote.pop(page_id, None)
        if entry is not None and entry[1] is not None:
            entry[1].release()
        if holder is not None:
            self.tier.cluster.drop_bytes(holder, self._blob(page_id))

    def __contains__(self, page_id: int) -> bool:
        with self._lock:
            return (page_id in self._local or page_id in self._inflight
                    or page_id in self._remote)

    def __len__(self) -> int:
        with self._lock:
            return len(self._local) + len(self._remote)

    # -- level-3 overflow -----------------------------------------------------
    def _maybe_overflow(self) -> None:
        budget = self.tier.host_budget_bytes
        if budget is None:
            return
        with self._lock:
            inflight = sum(self._local[p][0].nbytes for p in self._inflight
                           if p in self._local)
            excess = self.host_bytes - inflight - budget
            for pid in list(self._order):
                if excess <= 0:
                    break
                if pid in self._inflight or pid not in self._local:
                    continue
                if self._spill_one(pid):
                    excess -= self._local[pid][0].nbytes

    def _spill_one(self, page_id: int) -> bool:
        target = self.tier._spill_target(self.node_id)
        if target is None:
            return False
        slab = self._local[page_id][0]
        fut = self.tier.cluster.transfer.submit(
            self._ship, page_id, target, slab,
            label=f"kvspill:{self.node_id}:{page_id}",
            dest=target, nbytes=slab.nbytes)
        self._inflight[page_id] = (fut, target)
        return True

    def _ship(self, page_id: int, target: int, slab: np.ndarray) -> int:
        self.tier._fire("during_spill")
        self.tier.cluster.store_bytes(target, self._blob(page_id),
                                      slab.tobytes())
        return target

    def _reap(self) -> None:
        with self._lock:
            done = [(pid, fut, target)
                    for pid, (fut, target) in self._inflight.items()
                    if fut.done()]
            for pid, _fut, _target in done:
                del self._inflight[pid]
        for pid, fut, target in done:
            try:
                fut.result(timeout=0)
            except Exception:
                # spill target died mid-transfer: the host copy is still
                # here, so nothing is lost — retry elsewhere on the next put
                self.stats["spill_failures"] += 1
                continue
            with self._lock:
                entry = self._local.pop(pid, None)
                if entry is not None:
                    self._order.remove(pid)
                    self.host_bytes -= entry[0].nbytes
                    self._remote[pid] = target
            if entry is None:     # taken/discarded while the copy flew
                self.tier.cluster.drop_bytes(target, self._blob(pid))
                continue
            slab, res = entry
            if res is not None:
                res.release()
            self.stats["remote_spills"] += 1

    def close(self) -> None:
        """Release every charge and drop every level-3 blob (remote blobs
        live on *other* nodes, so this works even when the home node is
        dead — failover cleanup rides it)."""
        for pid, (fut, _t) in list(self._inflight.items()):
            try:
                fut.result(timeout=5.0)
            except Exception:
                pass
        self._reap()
        self._inflight.clear()
        for pid in list(self._remote):
            self.tier.cluster.drop_bytes(self._remote.pop(pid),
                                         self._blob(pid))
        for slab, res in self._local.values():
            if res is not None:
                res.release()
        self._local.clear()
        self._order.clear()
        self.host_bytes = 0


class KVShard:
    """One node's slice of the serving tier: a driver-side ``PagedKVCache``
    modeling that node's HBM page pool, spilling through the tiered store."""

    def __init__(self, tier: "ServingTier", node_id: int):
        self.node_id = node_id
        self.store = TieredSlabStore(tier, node_id)
        self.cache = PagedKVCache(
            num_layers=tier.num_layers, hbm_pages=tier.hbm_pages_per_node,
            page_size=tier.page_tokens, kv_heads=tier.kv_heads,
            head_dim=tier.head_dim, dtype=tier.dtype, host_store=self.store)


@dataclass
class Session:
    seq_id: int
    node: int                     # current primary (home) node
    replica: Optional[int]        # replica holder (None = degraded)
    length: int = 0               # committed tokens (replica in sync)
    prompt_len: int = 0


class ServingTier:
    """The cluster-wide serving front end: admission, decode, spill,
    replication, and failover for paged-KV sequences."""

    def __init__(self, cluster, *, num_layers: int = 2, page_tokens: int = 4,
                 kv_heads: int = 2, head_dim: int = 4,
                 hbm_pages_per_node: int = 16,
                 host_budget_bytes: Optional[int] = None,
                 dtype=np.float32, replicate: bool = True,
                 prefill_deadline_s: Optional[float] = None):
        self.cluster = cluster
        self.scheduler = ClusterScheduler(cluster)
        self.num_layers = num_layers
        self.page_tokens = page_tokens
        self.kv_heads = kv_heads
        self.head_dim = head_dim
        self.hbm_pages_per_node = hbm_pages_per_node
        self.host_budget_bytes = host_budget_bytes
        self.dtype = np.dtype(dtype)
        self.replicate = replicate
        self.prefill_deadline_s = (cluster.admission_deadline_s
                                   if prefill_deadline_s is None
                                   else prefill_deadline_s)
        self.sessions: Dict[int, Session] = {}
        self._shards: Dict[int, KVShard] = {}
        self._hooks: Dict[str, Callable[[], None]] = {}
        self.stats = {"admitted": 0, "diverted": 0, "prefill_refusals": 0,
                      "failovers": 0, "decode_steps": 0}

    # -- geometry -------------------------------------------------------------
    @property
    def slab_shape(self) -> Tuple[int, ...]:
        return (self.num_layers, self.page_tokens, 2, self.kv_heads,
                self.head_dim)

    @property
    def slab_nbytes(self) -> int:
        return int(np.prod(self.slab_shape)) * self.dtype.itemsize

    def _pages_for(self, tokens: int) -> int:
        return -(-tokens // self.page_tokens)

    def _expected_slab(self, seq_id: int, page_index: int,
                       length: int) -> np.ndarray:
        return expected_page_slab(
            seq_id, page_index, length, num_layers=self.num_layers,
            page_tokens=self.page_tokens, kv_heads=self.kv_heads,
            head_dim=self.head_dim, dtype=self.dtype)

    # -- fault-injection hooks (tests SIGKILL nodes at phase boundaries) ------
    def add_fault_hook(self, phase: str, fn: Callable[[], None]) -> None:
        """Register a one-shot callback fired at a serving phase boundary:
        ``after_admit`` | ``mid_decode`` | ``during_restore`` |
        ``during_spill``."""
        self._hooks[phase] = fn

    def _fire(self, phase: str) -> None:
        fn = self._hooks.pop(phase, None)
        if fn is not None:
            fn()

    # -- topology helpers -----------------------------------------------------
    def _alive(self, node_id: int) -> bool:
        node = self.cluster.nodes.get(node_id)
        return bool(node is not None and node.alive)

    def _memory(self, node_id: int):
        node = self.cluster.nodes.get(node_id)
        return node.memory if node is not None and node.alive else None

    def _affinity(self, seq_id: int) -> int:
        """Session affinity: hash over the FULL membership (stable while
        nodes bounce), walking forward past dead nodes."""
        ids = sorted(self.cluster.nodes)
        h = zlib.crc32(f"seq{seq_id}".encode()) % len(ids)
        for k in range(len(ids)):
            node = ids[(h + k) % len(ids)]
            if self._alive(node):
                return node
        raise DeadNodeError("no alive nodes to serve on")

    def _next_alive(self, after: int, *exclude: int) -> Optional[int]:
        ids = sorted(self.cluster.nodes)
        start = ids.index(after) if after in ids else 0
        for k in range(1, len(ids) + 1):
            node = ids[(start + k) % len(ids)]
            if node not in exclude and node != after and self._alive(node):
                return node
        return None

    def _replica_for(self, primary: int) -> Optional[int]:
        return self._next_alive(primary) if self.replicate else None

    def _spill_target(self, home: int) -> Optional[int]:
        return self._next_alive(home)

    def _shard(self, node_id: int) -> KVShard:
        shard = self._shards.get(node_id)
        if shard is None:
            shard = self._shards[node_id] = KVShard(self, node_id)
        return shard

    def _drop_shard(self, node_id: int) -> None:
        shard = self._shards.pop(node_id, None)
        if shard is not None:
            shard.store.close()

    # -- admission (continuous-batching front end) ----------------------------
    def admit(self, prompts: Dict[int, int]) -> PlacementPlan:
        """Admit a batch of prefills: ``prompts`` maps ``seq_id -> prompt
        tokens``.  Each prefill probes its affinity node with a speculative
        ``urgency="low"`` grant; refused prefills are placed through
        ``place_sequences`` and may be diverted to admitting nodes.  Returns
        the placement plan (``plan.diversions`` names the re-routes)."""
        plan = PlacementPlan(placement={}, diversions={})
        asks: Dict[int, Tuple[int, int]] = {}
        for seq_id, prompt_len in prompts.items():
            if seq_id in self.sessions:
                raise ValueError(f"sequence {seq_id} already active")
            nbytes = self._pages_for(prompt_len) * self.slab_nbytes
            affinity = self._affinity(seq_id)
            if not self.cluster.admission:
                plan.placement[seq_id] = affinity    # always-grant baseline
                continue
            memory = self._memory(affinity)
            probe = None
            if memory is not None:
                try:
                    probe = memory.try_reserve(nbytes, urgency="low")
                except DeadNodeError:
                    probe = None
            if probe is not None:
                probe.release()   # probe only; prefill re-charges when it runs
                plan.placement[seq_id] = affinity
            else:
                self.stats["prefill_refusals"] += 1
                asks[seq_id] = (affinity, nbytes)
        if asks:
            routed = self.scheduler.place_sequences(
                asks, deadline_s=self.prefill_deadline_s)
            plan.placement.update(routed.placement)
            plan.diversions.update(routed.diversions)
            plan.refusals += routed.refusals
            self.stats["diverted"] += routed.diverted
        for seq_id, prompt_len in prompts.items():
            self._start_session(seq_id, prompt_len, plan.placement[seq_id])
            self.stats["admitted"] += 1
        return plan

    def _start_session(self, seq_id: int, prompt_len: int, node: int) -> None:
        last: Optional[DeadNodeError] = None
        for _attempt in range(len(self.cluster.nodes) + 1):
            if not self._alive(node):
                node = self._affinity(seq_id)
            try:
                self._prefill(seq_id, prompt_len, node)
                return
            except DeadNodeError as e:
                last = e
                self._abort_partial(seq_id, node)
                nxt = self._next_alive(node)
                if nxt is None:
                    break
                node = nxt
        raise last or DeadNodeError("no alive nodes to prefill on")

    def _prefill(self, seq_id: int, prompt_len: int, node: int) -> None:
        shard = self._shard(node)
        nbytes = self._pages_for(prompt_len) * self.slab_nbytes
        res = None
        if self.cluster.admission:
            memory = self._memory(node)
            if memory is None:
                raise DeadNodeError(f"node {node} died before prefill")
            res = memory.try_reserve(
                nbytes, urgency="required",
                timeout=self.cluster.admission_timeout_s)
        try:
            shard.cache.start_sequence(seq_id)
            sess = Session(seq_id, node, None, 0, prompt_len)
            self.sessions[seq_id] = sess
            self._fire("after_admit")
            shard.cache.ensure_capacity(seq_id, prompt_len)
            shard.cache.advance(seq_id, prompt_len)
            for k in range(self._pages_for(prompt_len)):
                shard.cache.write_page(
                    seq_id, k, self._expected_slab(seq_id, k, prompt_len))
            sess.replica = self._replica_for(node)
            self._replicate_all(sess)
            sess.length = prompt_len
            if not self._alive(node):
                raise DeadNodeError(f"node {node} died during prefill")
        finally:
            if res is not None:
                res.release()

    def _abort_partial(self, seq_id: int, node: int) -> None:
        """Unwind a prefill that died half way: free the partial locality
        set (or the whole shard if its node is gone) and the replica blobs."""
        sess = self.sessions.pop(seq_id, None)
        shard = self._shards.get(node)
        if shard is not None and not self._alive(node):
            self._drop_shard(node)
        elif shard is not None and seq_id in shard.cache.active_sequences():
            shard.cache.finish_sequence(seq_id)
        if sess is not None and sess.replica is not None:
            for k in range(self._pages_for(sess.prompt_len)):
                self.cluster.drop_bytes(sess.replica, self._rep_name(seq_id, k))

    # -- replication ----------------------------------------------------------
    def _rep_name(self, seq_id: int, page_index: int) -> str:
        return f"kvrep/{seq_id}/{page_index}"

    def _replicate_all(self, sess: Session) -> None:
        """Ship every current page slab of the sequence to its replica
        holder; on replica death, re-pick and retry once (degrading to
        no-replica only when no other node is alive)."""
        for _attempt in (0, 1):
            if sess.replica is None:
                return
            try:
                shard = self._shard(sess.node)
                npages = shard.cache.num_pages(sess.seq_id)
                for k in range(npages):
                    slab = shard.cache.read_page(sess.seq_id, k)
                    self.cluster.store_bytes(
                        sess.replica, self._rep_name(sess.seq_id, k),
                        slab.tobytes())
                return
            except DeadNodeError:
                sess.replica = self._replica_for(sess.node)
        sess.replica = None

    def _sync_replica(self, sess: Session, page_index: int,
                      slab: np.ndarray) -> None:
        if sess.replica is None:
            return
        try:
            self.cluster.store_bytes(
                sess.replica, self._rep_name(sess.seq_id, page_index),
                slab.tobytes())
        except DeadNodeError:
            sess.replica = self._replica_for(sess.node)
            self._replicate_all(sess)

    # -- decode ---------------------------------------------------------------
    def decode(self, seq_ids: List[int], steps: int = 1) -> Dict[int, int]:
        """Run ``steps`` decode iterations over the batch (continuous
        batching: each sequence advances independently, surviving node
        deaths via replica failover).  Returns ``seq_id -> new length``."""
        out = {}
        for _ in range(steps):
            for seq_id in seq_ids:
                out[seq_id] = self._decode_one(seq_id)
        return out

    def _decode_one(self, seq_id: int) -> int:
        last: Optional[DeadNodeError] = None
        for _attempt in range(len(self.cluster.nodes) + 1):
            sess = self.sessions[seq_id]
            if not self._alive(sess.node):
                self._failover(seq_id)
                continue
            try:
                self._decode_commit(sess)
                if not self._alive(sess.node):
                    raise DeadNodeError(
                        f"serving node {sess.node} died mid-decode")
                self.stats["decode_steps"] += 1
                return sess.length
            except DeadNodeError as e:
                last = e
                self._failover(seq_id)
        raise last or DeadNodeError(f"decode of sequence {seq_id} failed")

    def _decode_commit(self, sess: Session) -> None:
        seq_id = sess.seq_id
        shard = self._shard(sess.node)
        new_len = sess.length + 1
        needs_page = self._pages_for(new_len) > shard.cache.num_pages(seq_id)
        self._fire("mid_decode")
        res = None
        if needs_page and self.cluster.admission:
            memory = self._memory(sess.node)
            if memory is None:
                raise DeadNodeError(f"node {sess.node} died mid-decode")
            # in-flight decode must not stall out: forced through, paced
            # against the node's grant exactly like shuffle reducer pulls
            res = memory.try_reserve(
                self.slab_nbytes, urgency="required",
                timeout=self.cluster.admission_timeout_s)
        try:
            shard.cache.ensure_capacity(seq_id, new_len - sess.length)
            shard.cache.advance(seq_id, new_len - sess.length)
            p = (new_len - 1) // self.page_tokens
            slab = self._expected_slab(seq_id, p, new_len)
            shard.cache.write_page(seq_id, p, slab)
            self._sync_replica(sess, p, slab)
            sess.length = new_len
        finally:
            if res is not None:
                res.release()

    # -- failover -------------------------------------------------------------
    def _failover(self, seq_id: int) -> None:
        """Re-home a session whose primary died (or whose restore path
        failed): rebuild the sequence on the replica holder from its
        replicated page slabs and resume byte-identically.  Without a live
        replica the session honors the shuffle contract and demands a
        re-run."""
        sess = self.sessions[seq_id]
        old = sess.node
        shard = self._shards.get(old)
        if shard is not None and not self._alive(old):
            self._drop_shard(old)
        elif (shard is not None
              and seq_id in shard.cache.active_sequences()):
            shard.cache.finish_sequence(seq_id)
        rep = sess.replica
        if rep is None or not self._alive(rep):
            raise DeadNodeError(
                f"serving node {old} died with no live replica for "
                f"sequence {seq_id}; the session must re-run")
        npages = self._pages_for(sess.length)
        try:
            slabs = [np.frombuffer(
                self.cluster.load_bytes(rep, self._rep_name(seq_id, k)),
                self.dtype).reshape(self.slab_shape).copy()
                for k in range(npages)]
        except KeyError as e:
            raise DeadNodeError(
                f"replica of sequence {seq_id} is missing page {e}; "
                f"the session must re-run")
        new_shard = self._shard(rep)
        new_shard.cache.start_sequence(seq_id)
        new_shard.cache.ensure_capacity(seq_id, sess.length)
        new_shard.cache.advance(seq_id, sess.length)
        for k, slab in enumerate(slabs):
            new_shard.cache.write_page(seq_id, k, slab)
        sess.node = rep
        sess.replica = self._replica_for(rep)
        self._replicate_all(sess)
        for k in range(npages):      # the new primary stops holding blobs
            self.cluster.drop_bytes(rep, self._rep_name(seq_id, k))
        self.stats["failovers"] += 1

    # -- reads ----------------------------------------------------------------
    def _live_session(self, seq_id: int) -> Session:
        sess = self.sessions[seq_id]
        if not self._alive(sess.node):
            self._failover(seq_id)
            sess = self.sessions[seq_id]
        return sess

    def block_table(self, seq_id: int,
                    max_pages: Optional[int] = None) -> np.ndarray:
        sess = self._live_session(seq_id)
        shard = self._shard(sess.node)
        mp = (shard.cache.num_pages(seq_id) if max_pages is None
              else max_pages)
        return shard.cache.block_table(seq_id, mp)

    def sequence_slabs(self, seq_id: int) -> List[np.ndarray]:
        sess = self._live_session(seq_id)
        return self._shard(sess.node).cache.sequence_slabs(seq_id)

    def expected_slabs(self, seq_id: int) -> List[np.ndarray]:
        sess = self.sessions[seq_id]
        return [self._expected_slab(seq_id, k, sess.length)
                for k in range(self._pages_for(sess.length))]

    def verify(self, seq_id: int) -> bool:
        """Byte-identity of the session's KV against the deterministic
        oracle, across every spill level and after any failover."""
        got = self.sequence_slabs(seq_id)
        want = self.expected_slabs(seq_id)
        return (len(got) == len(want)
                and all(a.tobytes() == b.tobytes()
                        for a, b in zip(got, want)))

    def attend(self, seq_ids: List[int], layer: int = 0,
               impl: str = "xla") -> Dict[int, np.ndarray]:
        """Run paged decode attention for a batch (grouped by shard — each
        shard is one device pool).  The q vectors are deterministic too, so
        outputs are comparable across backends."""
        from ..kernels.paged_attention.ops import paged_attention
        import jax.numpy as jnp
        by_shard: Dict[int, List[int]] = {}
        for s in seq_ids:
            by_shard.setdefault(self._live_session(s).node, []).append(s)
        out: Dict[int, np.ndarray] = {}
        for node, seqs in by_shard.items():
            shard = self._shard(node)
            max_pages = max(shard.cache.num_pages(s) for s in seqs)
            tables = np.stack([shard.cache.block_table(s, max_pages)
                               for s in seqs])
            lengths = np.array([self.sessions[s].length for s in seqs],
                               np.int32)
            q = np.stack([np.full((self.kv_heads, self.head_dim),
                                  token_value(s, self.sessions[s].length),
                                  self.dtype) for s in seqs])
            r = paged_attention(jnp.asarray(q), shard.cache.kv[layer],
                                jnp.asarray(tables), jnp.asarray(lengths),
                                impl=impl)
            for i, s in enumerate(seqs):
                out[s] = np.asarray(r[i])
        return out

    # -- lifecycle ------------------------------------------------------------
    def finish(self, seq_id: int) -> None:
        sess = self.sessions.pop(seq_id)
        shard = self._shards.get(sess.node)
        if (shard is not None and self._alive(sess.node)
                and seq_id in shard.cache.active_sequences()):
            shard.cache.finish_sequence(seq_id)
        elif shard is not None and not self._alive(sess.node):
            self._drop_shard(sess.node)
        if sess.replica is not None:
            for k in range(self._pages_for(sess.length)):
                self.cluster.drop_bytes(sess.replica,
                                        self._rep_name(seq_id, k))

    def close(self) -> None:
        for seq_id in list(self.sessions):
            self.finish(seq_id)
        for node_id in list(self._shards):
            self._drop_shard(node_id)
        if self.cluster._transfer is not None:
            self.cluster.transfer.drain(timeout=10.0)

    def pressure_report(self):
        return self.cluster.pressure_report()
