"""Train state + train_step factory (grad accumulation, optional compressed
cross-pod gradient reduction, loss scaling)."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .adamw import AdamWState, adamw_init, adamw_update

Pytree = Any


class TrainState(NamedTuple):
    params: Pytree
    opt: AdamWState


def make_train_state(params: Pytree, opt_dtype: str = "float32") -> TrainState:
    return TrainState(params=params, opt=adamw_init(params, opt_dtype))


def make_train_step(loss_fn: Callable[[Pytree, Any], jnp.ndarray], *,
                    lr: float = 3e-4, weight_decay: float = 0.1,
                    microbatches: int = 1,
                    donate: bool = True) -> Callable:
    """Build a pure train_step(state, batch) -> (state, metrics).

    ``microbatches > 1`` accumulates gradients over batch slices with a
    lax.scan (sequential microbatching — the standard memory/throughput
    trade; the per-microbatch forward+backward stays inside one XLA while
    loop so the HLO stays compact).
    """

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def train_step(state: TrainState, batch) -> tuple:
        params = state.params
        if microbatches == 1:
            loss, grads = grads_of(params, batch)
        else:
            def slice_mb(x, i):
                mb = x.shape[0] // microbatches
                return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)

            def body(carry, i):
                acc, loss_acc = carry
                mb = jax.tree.map(lambda x: slice_mb(x, i), batch)
                loss, g = grads_of(params, mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc, loss_acc + loss), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            (gsum, loss_sum), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)),
                jnp.arange(microbatches))
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = loss_sum / microbatches

        new_params, new_opt = adamw_update(params, grads, state.opt,
                                           lr=lr, weight_decay=weight_decay)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "step": new_opt.step}
        return TrainState(new_params, new_opt), metrics

    return train_step
