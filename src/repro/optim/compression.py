"""Gradient compression for the cross-pod all-reduce: per-tensor int8
quantization with error feedback (the residual is carried to the next step so
the compression is unbiased over time). Used on the slow DCN ("pod") axis —
a distributed-optimization trick from the large-scale-runnability checklist."""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


def compress_int8(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """-> (int8 tensor, f32 scale)."""
    amax = jnp.max(jnp.abs(g)).astype(jnp.float32)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_allreduce(grads: Pytree, axis_name: Optional[str],
                         error: Optional[Pytree] = None
                         ) -> Tuple[Pytree, Pytree]:
    """psum(int8-quantized grads) with error feedback.

    Inside shard_map/pmap over ``axis_name``; with axis_name=None it applies
    quantize→dequantize locally (used in tests and for the single-pod path).
    Returns (averaged grads, new error residuals).
    """
    if error is None:
        error = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, scale = compress_int8(corrected)
        deq = decompress_int8(q, scale)
        new_e = corrected - deq
        if axis_name is not None:
            deq = jax.lax.pmean(deq, axis_name)
        return deq.astype(g.dtype), new_e

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))
