from .adamw import AdamWState, adamw_init, adamw_update
from .compression import compress_int8, decompress_int8, compressed_allreduce
from .train_state import TrainState, make_train_step

__all__ = ["AdamWState", "TrainState", "adamw_init", "adamw_update",
           "compress_int8", "compressed_allreduce", "decompress_int8",
           "make_train_step"]
