"""AdamW with configurable moment dtype (bf16 moments for the 314B/72B archs
so optimizer state fits HBM — DESIGN.md §6)."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Pytree = Any


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Pytree
    v: Pytree


def adamw_init(params: Pytree, dtype: str = "float32") -> AdamWState:
    dt = jnp.dtype(dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def adamw_update(params: Pytree, grads: Pytree, state: AdamWState, *,
                 lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.1):
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        update = (mf / bc1) / (jnp.sqrt(vf / bc2) + eps)
        if p.ndim >= 2:  # decay matrices only (standard practice)
            update = update + weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * update
        return newp.astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)
