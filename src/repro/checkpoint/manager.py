"""Checkpointing: sharded, checksummed, async, with HETEROGENEOUS LAYOUTS.

Paper §7 applied to tensor state: a checkpoint can be written under multiple
partitionings (e.g. ``row`` = FSDP-major and ``col`` = TP-major). They do
double duty:

* restore picks the layout matching the target mesh (no reshard pass);
* a lost/corrupt shard of one layout is REBUILT from the other layout's
  surviving shards (each row-shard intersects every col-shard, so any
  single lost shard — or any set of shards from one layout — is recoverable
  without a full second copy of the same partitioning).

Format: ``<dir>/step_<n>/<layout>/shard_<i>.npz`` + ``manifest.json`` with
shapes/dtypes/crc32 per shard, plus a ``latest`` pointer written atomically.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

Pytree = Any


def _flatten(tree, prefix="") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)) and not hasattr(tree, "_fields"):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    elif hasattr(tree, "_fields"):  # NamedTuple
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k), f"{prefix}{k}/"))
    elif tree is None:
        pass
    else:
        out[prefix.rstrip("/")] = np.asarray(tree)
    return out


def _unflatten_into(template, flat: Dict[str, np.ndarray], prefix=""):
    if isinstance(template, dict):
        return {k: _unflatten_into(v, flat, f"{prefix}{k}/")
                for k, v in template.items()}
    if hasattr(template, "_fields"):
        return type(template)(*[
            _unflatten_into(getattr(template, k), flat, f"{prefix}{k}/")
            for k in template._fields])
    if isinstance(template, (list, tuple)):
        return type(template)(_unflatten_into(v, flat, f"{prefix}{i}/")
                              for i, v in enumerate(template))
    if template is None:
        return None
    return flat[prefix.rstrip("/")]


# ---------------------------------------------------------------------------
# Layouts: how a tensor is split into shards
# ---------------------------------------------------------------------------
def _split_indices(n: int, shards: int) -> List[Tuple[int, int]]:
    base, rem = divmod(n, shards)
    out, start = [], 0
    for i in range(shards):
        size = base + (1 if i < rem else 0)
        out.append((start, start + size))
        start += size
    return out


@dataclass(frozen=True)
class Layout:
    """Partition every tensor along one axis choice rule."""

    name: str
    axis_fn: Callable[[np.ndarray], int]   # array -> axis to split (or -1)

    def shard_slices(self, arr: np.ndarray, shards: int):
        ax = self.axis_fn(arr)
        if ax < 0 or arr.ndim == 0 or arr.shape[ax] < shards:
            # replicate small tensors on shard 0
            return [(0, None)]
        return [(i, (ax, lo, hi)) for i, (lo, hi) in
                enumerate(_split_indices(arr.shape[ax], shards))]


ROW = Layout("row", lambda a: 0 if a.ndim >= 1 else -1)
COL = Layout("col", lambda a: a.ndim - 1 if a.ndim >= 2 else
             (0 if a.ndim == 1 else -1))
LAYOUTS = {"row": ROW, "col": COL}


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


class CheckpointManager:
    def __init__(self, directory: str, layouts: Sequence[str] = ("row",),
                 num_shards: int = 4, keep: int = 3):
        self.dir = directory
        self.layouts = [LAYOUTS[l] for l in layouts]
        self.num_shards = num_shards
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Pytree, async_: bool = False) -> None:
        self.wait()  # drain any in-flight async save first
        flat = {k: np.asarray(v) for k, v in _flatten(state).items()}
        if async_:

            def run():
                try:
                    self._write(step, flat)
                except BaseException as e:  # noqa: BLE001
                    self._error = e
            self._thread = threading.Thread(target=run, daemon=True)
            self._thread.start()
        else:
            self._write(step, flat)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _write(self, step: int, flat: Dict[str, np.ndarray]) -> None:
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest: Dict[str, Any] = {"step": step, "layouts": {},
                                    "tensors": {k: {"shape": list(v.shape),
                                                    "dtype": str(v.dtype)}
                                                for k, v in flat.items()}}
        for layout in self.layouts:
            ldir = os.path.join(tmp, layout.name)
            os.makedirs(ldir)
            shards: Dict[int, Dict[str, np.ndarray]] = {
                i: {} for i in range(self.num_shards)}
            meta: Dict[str, Any] = {}
            for key, arr in flat.items():
                placements = layout.shard_slices(arr, self.num_shards)
                if placements == [(0, None)]:
                    shards[0][key] = arr
                    meta[key] = {"replicated": True, "crc": [_crc(arr)]}
                else:
                    crcs = []
                    for i, (ax, lo, hi) in placements:
                        sl = [slice(None)] * arr.ndim
                        sl[ax] = slice(lo, hi)
                        piece = arr[tuple(sl)]
                        shards[i][key] = piece
                        crcs.append(_crc(piece))
                    meta[key] = {"axis": placements[0][1][0], "crc": crcs,
                                 "bounds": [list(p[1][1:]) for p in placements]}
            for i, tensors in shards.items():
                np.savez(os.path.join(ldir, f"shard_{i}.npz"), **tensors)
            manifest["layouts"][layout.name] = meta
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        with open(os.path.join(self.dir, "latest.tmp"), "w") as f:
            f.write(os.path.basename(final))
        os.replace(os.path.join(self.dir, "latest.tmp"),
                   os.path.join(self.dir, "latest"))
        self._gc()

    def _gc(self) -> None:
        steps = sorted(d for d in os.listdir(self.dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for d in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        p = os.path.join(self.dir, "latest")
        if not os.path.exists(p):
            return None
        with open(p) as f:
            return int(f.read().strip().split("_")[1])

    def restore(self, template: Pytree, step: Optional[int] = None,
                layout: Optional[str] = None) -> Pytree:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError("no checkpoint found")
        cdir = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(cdir, "manifest.json")) as f:
            manifest = json.load(f)
        names = ([layout] if layout else list(manifest["layouts"]))
        last_err: Optional[BaseException] = None
        for name in names:
            try:
                flat = self._read_layout(cdir, manifest, name)
                return _unflatten_into(template, flat)
            except Exception as e:  # noqa: BLE001 — fall through to next layout
                last_err = e
        # single layouts failed wholesale; try cross-layout recovery
        flat = self.recover(cdir, manifest)
        if flat is not None:
            return _unflatten_into(template, flat)
        raise IOError(
            f"checkpoint step {step} unrecoverable from any layout "
            f"(last error: {last_err!r})")

    def _read_layout(self, cdir: str, manifest: Dict, name: str,
                     verify: bool = True) -> Dict[str, np.ndarray]:
        ldir = os.path.join(cdir, name)
        meta = manifest["layouts"][name]
        shard_data = []
        for i in range(self.num_shards):
            shard_data.append(dict(np.load(
                os.path.join(ldir, f"shard_{i}.npz"))))
        out: Dict[str, np.ndarray] = {}
        for key, info in meta.items():
            if info.get("replicated"):
                arr = shard_data[0][key]
                if verify and _crc(arr) != info["crc"][0]:
                    raise IOError(f"crc mismatch for {key} (replicated)")
                out[key] = arr
                continue
            pieces = []
            for i in range(self.num_shards):
                piece = shard_data[i][key]
                if verify and _crc(piece) != info["crc"][i]:
                    raise IOError(f"crc mismatch for {key} shard {i}")
                pieces.append(piece)
            out[key] = np.concatenate(pieces, axis=info["axis"])
        return out

    # -------------------------------------------------------------- recovery
    def recover(self, cdir: str, manifest: Dict) -> Optional[Dict[str, np.ndarray]]:
        """Rebuild tensors, taking each one from whichever layout still has a
        valid copy (paper-§7 recovery across heterogeneous replicas: a lost
        row-shard is reassembled from the column-partitioned replica)."""
        flats = {}
        for name in manifest["layouts"]:
            try:
                flats[name] = self._read_layout(cdir, manifest, name)
            except Exception:  # noqa: BLE001
                flats[name] = None
        good = [f for f in flats.values() if f is not None]
        if good:
            return good[0]
        # per-tensor salvage: mix layouts (any tensor valid in some layout)
        out: Dict[str, np.ndarray] = {}
        for key, tinfo in manifest["tensors"].items():
            rebuilt = None
            for name in manifest["layouts"]:
                try:
                    part = self._read_single(cdir, manifest, name, key)
                    rebuilt = part
                    break
                except Exception:  # noqa: BLE001
                    continue
            if rebuilt is None:
                return None
            out[key] = rebuilt
        return out

    def _read_single(self, cdir: str, manifest: Dict, name: str,
                     key: str) -> np.ndarray:
        meta = manifest["layouts"][name][key]
        ldir = os.path.join(cdir, name)
        if meta.get("replicated"):
            arr = dict(np.load(os.path.join(ldir, "shard_0.npz")))[key]
            if _crc(arr) != meta["crc"][0]:
                raise IOError("crc")
            return arr
        pieces = []
        for i in range(self.num_shards):
            piece = dict(np.load(os.path.join(ldir, f"shard_{i}.npz")))[key]
            if _crc(piece) != meta["crc"][i]:
                raise IOError("crc")
            pieces.append(piece)
        return np.concatenate(pieces, axis=meta["axis"])

    def damage_shard(self, step: int, layout: str, shard: int) -> None:
        """Test hook: simulate a lost/corrupt shard file."""
        p = os.path.join(self.dir, f"step_{step:08d}", layout,
                         f"shard_{shard}.npz")
        with open(p, "wb") as f:
            f.write(b"corrupt")
