"""Checkpointing: sharded, checksummed, async, with HETEROGENEOUS LAYOUTS.

Paper §7 applied to tensor state: a checkpoint can be written under multiple
partitionings (e.g. ``row`` = FSDP-major and ``col`` = TP-major). They do
double duty:

* restore picks the layout matching the target mesh (no reshard pass);
* a lost/corrupt shard of one layout is REBUILT from the other layout's
  surviving shards (each row-shard intersects every col-shard, so any
  single lost shard — or any set of shards from one layout — is recoverable
  without a full second copy of the same partitioning).

Two backends share the encode/verify/recover logic:

* **File mode** (``CheckpointManager(directory)``): the original format —
  ``<dir>/step_<n>/<layout>/shard_<i>.npz`` + ``manifest.json`` with
  shapes/dtypes/crc32 per shard, plus a ``latest`` pointer written
  atomically.
* **Pool mode** (``CheckpointManager(cluster=...)``, PR 6): every blob is a
  write-through locality set streamed through a node's buffer pool, so the
  bytes land in that node's durable page log — checkpoints ride the same
  storage tier as user data, survive a node restart, and warm-restore from
  the replayed log without touching the network. Blob placement is recorded
  in ``Cluster.durable_blobs`` so the revival fence keeps them.
"""
from __future__ import annotations

import io
import json
import os
import shutil
import threading
import zlib
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.services import user_data_attrs

Pytree = Any


def _flatten(tree, prefix="") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)) and not hasattr(tree, "_fields"):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    elif hasattr(tree, "_fields"):  # NamedTuple
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k), f"{prefix}{k}/"))
    elif tree is None:
        pass
    else:
        out[prefix.rstrip("/")] = np.asarray(tree)
    return out


def _unflatten_into(template, flat: Dict[str, np.ndarray], prefix=""):
    if isinstance(template, dict):
        return {k: _unflatten_into(v, flat, f"{prefix}{k}/")
                for k, v in template.items()}
    if hasattr(template, "_fields"):
        return type(template)(*[
            _unflatten_into(getattr(template, k), flat, f"{prefix}{k}/")
            for k in template._fields])
    if isinstance(template, (list, tuple)):
        return type(template)(_unflatten_into(v, flat, f"{prefix}{i}/")
                              for i, v in enumerate(template))
    if template is None:
        return None
    return flat[prefix.rstrip("/")]


# ---------------------------------------------------------------------------
# Layouts: how a tensor is split into shards
# ---------------------------------------------------------------------------
def _split_indices(n: int, shards: int) -> List[Tuple[int, int]]:
    base, rem = divmod(n, shards)
    out, start = [], 0
    for i in range(shards):
        size = base + (1 if i < rem else 0)
        out.append((start, start + size))
        start += size
    return out


@dataclass(frozen=True)
class Layout:
    """Partition every tensor along one axis choice rule."""

    name: str
    axis_fn: Callable[[np.ndarray], int]   # array -> axis to split (or -1)

    def shard_slices(self, arr: np.ndarray, shards: int):
        ax = self.axis_fn(arr)
        if ax < 0 or arr.ndim == 0 or arr.shape[ax] < shards:
            # replicate small tensors on shard 0
            return [(0, None)]
        return [(i, (ax, lo, hi)) for i, (lo, hi) in
                enumerate(_split_indices(arr.shape[ax], shards))]


ROW = Layout("row", lambda a: 0 if a.ndim >= 1 else -1)
COL = Layout("col", lambda a: a.ndim - 1 if a.ndim >= 2 else
             (0 if a.ndim == 1 else -1))
LAYOUTS = {"row": ROW, "col": COL}


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


def _npz_bytes(tensors: Dict[str, np.ndarray]) -> bytes:
    buf = io.BytesIO()
    np.savez(buf, **tensors)
    return buf.getvalue()


class CheckpointManager:
    def __init__(self, directory: Optional[str] = None,
                 layouts: Sequence[str] = ("row",),
                 num_shards: int = 4, keep: int = 3,
                 cluster=None, page_size: int = 1 << 16,
                 prefix: str = "ckpt"):
        if (directory is None) == (cluster is None):
            raise ValueError(
                "exactly one of directory= (file mode) or cluster= "
                "(pool mode) must be given")
        self.dir = directory
        self.cluster = cluster
        self.page_size = page_size
        self.prefix = prefix
        self.layouts = [LAYOUTS[l] for l in layouts]
        self.num_shards = num_shards
        self.keep = keep
        if directory is not None:
            os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Pytree, async_: bool = False) -> None:
        self.wait()  # drain any in-flight async save first
        flat = {k: np.asarray(v) for k, v in _flatten(state).items()}
        if async_:

            def run():
                try:
                    self._write(step, flat)
                except BaseException as e:  # noqa: BLE001
                    self._error = e
            self._thread = threading.Thread(target=run, daemon=True)
            self._thread.start()
        else:
            self._write(step, flat)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _encode(self, step: int,
                flat: Dict[str, np.ndarray]) -> Dict[str, bytes]:
        """Shard the flattened state under every layout. Returns relative
        blob name -> bytes, with ``manifest.json`` describing every shard's
        shape/dtype/crc32 (both backends publish exactly these blobs)."""
        manifest: Dict[str, Any] = {"step": step, "layouts": {},
                                    "tensors": {k: {"shape": list(v.shape),
                                                    "dtype": str(v.dtype)}
                                                for k, v in flat.items()}}
        blobs: Dict[str, bytes] = {}
        for layout in self.layouts:
            shards: Dict[int, Dict[str, np.ndarray]] = {
                i: {} for i in range(self.num_shards)}
            meta: Dict[str, Any] = {}
            for key, arr in flat.items():
                placements = layout.shard_slices(arr, self.num_shards)
                if placements == [(0, None)]:
                    shards[0][key] = arr
                    meta[key] = {"replicated": True, "crc": [_crc(arr)]}
                else:
                    crcs = []
                    for i, (ax, lo, hi) in placements:
                        sl = [slice(None)] * arr.ndim
                        sl[ax] = slice(lo, hi)
                        piece = arr[tuple(sl)]
                        shards[i][key] = piece
                        crcs.append(_crc(piece))
                    meta[key] = {"axis": placements[0][1][0], "crc": crcs,
                                 "bounds": [list(p[1][1:]) for p in placements]}
            for i, tensors in shards.items():
                blobs[f"{layout.name}/shard_{i}.npz"] = _npz_bytes(tensors)
            manifest["layouts"][layout.name] = meta
        blobs["manifest.json"] = json.dumps(manifest).encode()
        return blobs

    def _write(self, step: int, flat: Dict[str, np.ndarray]) -> None:
        step_name = f"step_{step:08d}"
        blobs = self._encode(step, flat)
        if self.cluster is not None:
            self._publish_pool(step_name, blobs)
        else:
            self._publish_files(step_name, blobs)
        self._gc()

    def _publish_files(self, step_name: str, blobs: Dict[str, bytes]) -> None:
        final = os.path.join(self.dir, step_name)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for rel, data in blobs.items():
            path = os.path.join(tmp, rel)
            os.makedirs(os.path.dirname(path), exist_ok=True)
            with open(path, "wb") as f:
                f.write(data)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        with open(os.path.join(self.dir, "latest.tmp"), "w") as f:
            f.write(step_name)
        os.replace(os.path.join(self.dir, "latest.tmp"),
                   os.path.join(self.dir, "latest"))

    def _publish_pool(self, step_name: str, blobs: Dict[str, bytes]) -> None:
        """Stream every blob through a node's buffer pool as a write-through
        set (its pages persist into the node's durable page log on unpin —
        paper §4's write-through, PR 6's tier). The manifest lands last as
        the commit point; the latest pointer flips after it."""
        shard_blobs = sorted(r for r in blobs if r != "manifest.json")
        for rel in shard_blobs + ["manifest.json"]:
            self._put_blob(f"{self.prefix}/{step_name}/{rel}", blobs[rel])
        self._put_blob(f"{self.prefix}/latest", step_name.encode())

    def _gc(self) -> None:
        for name in self._list_steps()[:-self.keep]:
            self._delete_step(name)

    # ------------------------------------------------------- blob primitives
    def _blob_names(self) -> List[str]:
        return [n for n in self.cluster.durable_blobs
                if n.startswith(f"{self.prefix}/")]

    def _put_blob(self, name: str, data: bytes) -> None:
        cluster = self.cluster
        if name in cluster.durable_blobs:
            self._del_blob(name)
        alive = cluster.alive_node_ids()
        node_id = alive[zlib.crc32(name.encode()) % len(alive)]
        records = np.frombuffer(data, dtype=np.uint8)
        cluster.nodes[node_id].write_records(
            name, records, np.dtype(np.uint8), self.page_size,
            user_data_attrs())
        cluster.register_durable_blob(name, node_id)

    def _get_blob(self, name: str) -> bytes:
        loc = self.cluster.durable_blobs.get(name)
        if loc is None:
            raise FileNotFoundError(f"no blob {name!r}")
        node = self.cluster.node(loc[0])  # DeadNodeError while it is down
        pool = node.pool
        if name not in pool.paging.sets:
            # warm restore: the set is not registered in the fresh pool but
            # its page images survive in the replayed durable log
            log = pool.memory.pagelog
            if log is None or not log.entries_for(name):
                raise IOError(f"blob {name!r} lost with node {loc[0]}")
            pool.adopt_durable_set(name, self.page_size, user_data_attrs())
        return node.read_records(name, np.dtype(np.uint8)).tobytes()

    def _del_blob(self, name: str) -> None:
        loc = self.cluster.durable_blobs.get(name)
        self.cluster.unregister_durable_blob(name)
        if loc is None:
            return
        node = self.cluster.nodes[loc[0]]
        if (node.alive and node.pool is not None
                and name in node.pool.paging.sets):
            node.pool.drop_set(node.pool.get_set(name))

    def _read_rel(self, step_name: str, rel: str) -> bytes:
        if self.cluster is not None:
            return self._get_blob(f"{self.prefix}/{step_name}/{rel}")
        with open(os.path.join(self.dir, step_name, rel), "rb") as f:
            return f.read()

    def _list_steps(self) -> List[str]:
        if self.cluster is not None:
            pre = f"{self.prefix}/"
            return sorted({n[len(pre):].split("/")[0]
                           for n in self._blob_names()
                           if n[len(pre):].startswith("step_")})
        return sorted(d for d in os.listdir(self.dir)
                      if d.startswith("step_") and not d.endswith(".tmp"))

    def _delete_step(self, step_name: str) -> None:
        if self.cluster is not None:
            pre = f"{self.prefix}/{step_name}/"
            for name in [n for n in self._blob_names()
                         if n.startswith(pre)]:
                self._del_blob(name)
            return
        shutil.rmtree(os.path.join(self.dir, step_name), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        if self.cluster is not None:
            if f"{self.prefix}/latest" not in self.cluster.durable_blobs:
                return None
            pointer = self._get_blob(f"{self.prefix}/latest").decode()
            return int(pointer.strip().split("_")[1])
        p = os.path.join(self.dir, "latest")
        if not os.path.exists(p):
            return None
        with open(p) as f:
            return int(f.read().strip().split("_")[1])

    def restore(self, template: Pytree, step: Optional[int] = None,
                layout: Optional[str] = None) -> Pytree:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError("no checkpoint found")
        step_name = f"step_{step:08d}"
        manifest = json.loads(self._read_rel(step_name, "manifest.json"))
        names = ([layout] if layout else list(manifest["layouts"]))
        last_err: Optional[BaseException] = None
        for name in names:
            try:
                flat = self._read_layout(step_name, manifest, name)
                return _unflatten_into(template, flat)
            except Exception as e:  # noqa: BLE001 — fall through to next layout
                last_err = e
        # single layouts failed wholesale; try cross-layout recovery
        flat = self.recover(step_name, manifest)
        if flat is not None:
            return _unflatten_into(template, flat)
        raise IOError(
            f"checkpoint step {step} unrecoverable from any layout "
            f"(last error: {last_err!r})")

    def _load_shard(self, step_name: str, layout: str,
                    shard: int) -> Dict[str, np.ndarray]:
        data = self._read_rel(step_name, f"{layout}/shard_{shard}.npz")
        return dict(np.load(io.BytesIO(data)))

    def _read_layout(self, step_name: str, manifest: Dict, name: str,
                     verify: bool = True) -> Dict[str, np.ndarray]:
        meta = manifest["layouts"][name]
        shard_data = [self._load_shard(step_name, name, i)
                      for i in range(self.num_shards)]
        out: Dict[str, np.ndarray] = {}
        for key, info in meta.items():
            if info.get("replicated"):
                arr = shard_data[0][key]
                if verify and _crc(arr) != info["crc"][0]:
                    raise IOError(f"crc mismatch for {key} (replicated)")
                out[key] = arr
                continue
            pieces = []
            for i in range(self.num_shards):
                piece = shard_data[i][key]
                if verify and _crc(piece) != info["crc"][i]:
                    raise IOError(f"crc mismatch for {key} shard {i}")
                pieces.append(piece)
            out[key] = np.concatenate(pieces, axis=info["axis"])
        return out

    # -------------------------------------------------------------- recovery
    def recover(self, step_name: str,
                manifest: Dict) -> Optional[Dict[str, np.ndarray]]:
        """Rebuild tensors, taking each one from whichever layout still has a
        valid copy (paper-§7 recovery across heterogeneous replicas: a lost
        row-shard is reassembled from the column-partitioned replica)."""
        flats = {}
        for name in manifest["layouts"]:
            try:
                flats[name] = self._read_layout(step_name, manifest, name)
            except Exception:  # noqa: BLE001
                flats[name] = None
        good = [f for f in flats.values() if f is not None]
        if good:
            return good[0]
        # per-tensor salvage: mix layouts (any tensor valid in some layout)
        out: Dict[str, np.ndarray] = {}
        for key, tinfo in manifest["tensors"].items():
            rebuilt = None
            for name in manifest["layouts"]:
                try:
                    part = self._read_single(step_name, manifest, name, key)
                    rebuilt = part
                    break
                except Exception:  # noqa: BLE001
                    continue
            if rebuilt is None:
                return None
            out[key] = rebuilt
        return out

    def _read_single(self, step_name: str, manifest: Dict, name: str,
                     key: str) -> np.ndarray:
        meta = manifest["layouts"][name][key]
        if meta.get("replicated"):
            arr = self._load_shard(step_name, name, 0)[key]
            if _crc(arr) != meta["crc"][0]:
                raise IOError("crc")
            return arr
        pieces = []
        for i in range(self.num_shards):
            piece = self._load_shard(step_name, name, i)[key]
            if _crc(piece) != meta["crc"][i]:
                raise IOError("crc")
            pieces.append(piece)
        return np.concatenate(pieces, axis=meta["axis"])

    def damage_shard(self, step: int, layout: str, shard: int) -> None:
        """Test hook: simulate a lost/corrupt shard (file or blob)."""
        if self.cluster is not None:
            name = (f"{self.prefix}/step_{step:08d}/{layout}/"
                    f"shard_{shard}.npz")
            self._del_blob(name)
            self._put_blob(name, b"corrupt")
            return
        p = os.path.join(self.dir, f"step_{step:08d}", layout,
                         f"shard_{shard}.npz")
        with open(p, "wb") as f:
            f.write(b"corrupt")
