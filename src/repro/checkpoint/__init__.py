from .manager import COL, LAYOUTS, ROW, CheckpointManager, Layout

__all__ = ["COL", "CheckpointManager", "LAYOUTS", "Layout", "ROW"]
