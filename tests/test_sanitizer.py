"""Runtime sanitizer (core/sanitizer.py): lock-order cycles, blocking while
holding, condition-wait suspension — plus regression tests for the genuine
violations PR 10's lint surfaced (page-log fsync under the index lock,
serving slab-store double-put reservation leak) and the counter-reset hooks."""
import numpy as np
import pytest

from repro.core import sanitizer
from repro.core.memory_manager import MemoryManager
from repro.core.pagelog import PageLog
from repro.core.sanitizer import (blocking_region, note_blocking,
                                  sanitizer_report, tracked_condition,
                                  tracked_lock, tracked_rlock)
from repro.core.shm_arena import ShmArena, arena_name
from repro.runtime import rpc
from repro.runtime.serving import TieredSlabStore


@pytest.fixture
def sanitize():
    prev = sanitizer.enabled()
    sanitizer.enable(True)
    sanitizer.reset()
    yield sanitizer
    sanitizer.reset()
    sanitizer.enable(prev)


# -- lock-order graph ---------------------------------------------------------
def test_lock_inversion_reported_as_cycle_by_name(sanitize):
    """Negative path: seed the classic A->B / B->A inversion and assert the
    report names exactly the two locks involved."""
    a = tracked_lock("inv.alpha")
    b = tracked_lock("inv.beta")
    with a:
        with b:
            pass
    with b:
        with a:
            pass
    report = sanitizer_report()
    assert ["inv.alpha", "inv.beta"] in report["cycles"]
    assert report["violations"] >= 1
    edges = {(e[0], e[1]) for e in report["edges"]}
    assert ("inv.alpha", "inv.beta") in edges
    assert ("inv.beta", "inv.alpha") in edges


def test_consistent_order_is_not_a_cycle(sanitize):
    a = tracked_lock("ord.alpha")
    b = tracked_lock("ord.beta")
    for _ in range(3):
        with a:
            with b:
                pass
    assert sanitizer_report()["cycles"] == []


def test_rlock_reentry_is_not_an_edge(sanitize):
    r = tracked_rlock("re.lock")
    with r:
        with r:
            pass
    report = sanitizer_report()
    assert report["cycles"] == []
    assert report["acquires"]["re.lock"] == 1  # one hold, depth-counted


def test_two_instances_of_one_name_are_a_self_cycle(sanitize):
    l1 = tracked_lock("dup.name")
    l2 = tracked_lock("dup.name")
    with l1:
        with l2:
            pass
    assert ["dup.name"] in sanitizer_report()["cycles"]


# -- blocking while holding ---------------------------------------------------
def test_blocking_region_records_held_locks(sanitize):
    lk = tracked_lock("blk.lock")
    with lk:
        note_blocking("disk.io")
    events = sanitizer_report()["blocking_while_holding"]
    assert len(events) == 1
    assert events[0]["op"] == "disk.io"
    assert events[0]["held"] == ["blk.lock"]


def test_blocking_region_allow_list_suppresses(sanitize):
    lk = tracked_lock("blk.sanctioned")
    with lk:
        with blocking_region("disk.io", allow=("blk.sanctioned",)):
            pass
    assert sanitizer_report()["blocking_while_holding"] == []


def test_blocking_with_no_lock_held_is_clean(sanitize):
    note_blocking("disk.io")
    assert sanitizer_report()["violations"] == 0


# -- condition-wait suspension ------------------------------------------------
def test_wait_on_own_condition_is_sanctioned(sanitize):
    cv = tracked_condition("cv.own")
    with cv:
        cv.wait(timeout=0.01)
    report = sanitizer_report()
    assert report["blocking_while_holding"] == []
    assert report["violations"] == 0


def test_wait_while_holding_another_lock_is_flagged(sanitize):
    outer = tracked_lock("cv.outer")
    cv = tracked_condition("cv.inner")
    with outer:
        with cv:
            cv.wait(timeout=0.01)
    events = sanitizer_report()["blocking_while_holding"]
    assert any(e["held"] == ["cv.outer"] for e in events)


def test_hold_frame_restored_after_wait(sanitize):
    cv = tracked_condition("cv.restore")
    with cv:
        cv.wait(timeout=0.01)
        assert "cv.restore.lock" in sanitizer.held_lock_names()
    assert sanitizer.held_lock_names() == []


# -- bookkeeping --------------------------------------------------------------
def test_hold_times_and_reset(sanitize):
    lk = tracked_lock("ht.lock")
    with lk:
        pass
    report = sanitizer_report()
    assert report["longest_holds"] and report["longest_holds"][0][0] == "ht.lock"
    sanitizer.reset()
    report = sanitizer_report()
    assert report["longest_holds"] == [] and report["acquires"] == {}


def test_disabled_mode_records_nothing():
    prev = sanitizer.enabled()
    sanitizer.enable(False)
    try:
        sanitizer.reset()
        lk = tracked_lock("off.lock")
        with lk:
            note_blocking("disk.io")
        report = sanitizer_report()
        assert report["acquires"] == {}
        assert report["violations"] == 0
    finally:
        sanitizer.enable(prev)


def test_assert_clean_raises_on_violation(sanitize):
    lk = tracked_lock("ac.lock")
    with lk:
        note_blocking("disk.io")
    with pytest.raises(AssertionError, match="violation"):
        sanitizer.assert_clean("test")
    sanitizer.reset()
    sanitizer.assert_clean("test")  # clean after reset


# -- regression: page-log fsync no longer runs under the index lock -----------
def test_pagelog_always_policy_fsyncs_outside_index_lock(tmp_path, sanitize):
    log = PageLog(str(tmp_path), fsync_policy="always")
    for i in range(3):
        log.append("set", bytes([i]) * 64)
    log.close()
    assert log.fsync_count >= 3
    events = sanitizer_report()["blocking_while_holding"]
    held = [n for e in events for n in e["held"]]
    assert "pagelog" not in held, events  # index lock released before fsync
    assert sanitizer_report()["violations"] == 0


def test_pagelog_group_policy_still_batches(tmp_path):
    log = PageLog(str(tmp_path), fsync_policy="group", group_bytes=4096)
    for _ in range(8):
        log.append("s", b"x" * 256)
    assert log.fsync_count == 0   # under the batch threshold
    log.append("s", b"y" * 4096)  # pushes the tail past group_bytes
    assert log.fsync_count == 1
    log.append("s", b"z" * 128)   # small tail left unsynced...
    log.close()
    assert log.fsync_count == 2   # ...drained by close


# -- regression: slab-store double put superseded the charged reservation ----
class _StubCluster:
    admission = True
    admission_timeout_s = 0.2


class _StubTier:
    """The minimum surface TieredSlabStore touches for local-only puts."""
    host_budget_bytes = None
    dtype = np.float32

    def __init__(self, memory):
        self._mem = memory
        self.cluster = _StubCluster()

    def _memory(self, node_id):
        return self._mem

    def _fire(self, event):
        pass


def test_slabstore_double_put_releases_prior_reservation():
    memory = MemoryManager(capacity=64 << 20)
    store = TieredSlabStore(_StubTier(memory), node_id=0)
    slab1 = np.ones(1024, dtype=np.float32)
    slab2 = np.ones(2048, dtype=np.float32)
    store.put(7, slab1)
    assert memory.reserved_bytes == slab1.nbytes
    store.put(7, slab2)   # supersedes: old charge must be released
    assert memory.reserved_bytes == slab2.nbytes
    assert store.host_bytes == slab2.nbytes
    assert store._order.count(7) == 1
    assert len(store) == 1
    out = store.take(7)
    assert out is slab2
    assert memory.reserved_bytes == 0
    assert store.host_bytes == 0


def test_slabstore_discard_releases_charge():
    memory = MemoryManager(capacity=64 << 20)
    store = TieredSlabStore(_StubTier(memory), node_id=0)
    store.put(1, np.ones(512, dtype=np.float32))
    assert memory.reserved_bytes > 0
    store.discard(1)
    assert memory.reserved_bytes == 0


# -- counter-reset hooks (order-independent assertions) -----------------------
def test_rpc_reset_counters_zeroes_process_globals():
    rpc._counters["messages"] += 3
    rpc._counters["pickle_fallbacks"] += 1
    rpc.reset_counters()
    assert rpc.wire_counters() == {"messages": 0, "raw_bytes": 0,
                                   "pickle_fallbacks": 0}
    assert rpc.pickle_fallbacks() == 0


def test_arena_reset_counters_keeps_live_accounting():
    arena = ShmArena(arena_name("sanit"), frame_size=4096, num_frames=4,
                     create=True, owner=True)
    try:
        desc = arena.put(b"x" * 100)
        assert arena.puts == 1 and arena.bytes_put == 100
        arena.reset_counters()
        assert arena.puts == 0 and arena.bytes_put == 0
        assert arena.frames_in_use == 1       # live accounting untouched
        assert arena.peak_frames == 1         # re-seeded from in-use
        arena.free(desc)
        assert arena.frames_in_use == 0
    finally:
        arena.close()
        arena.unlink()
